//! Baseline grouping policies (§4.1): mLoRA, Megatron, and the tLoRA
//! ablations. Each implements [`PolicyHooks`] — the same interface as
//! the tLoRA Adapter Scheduler (runnable candidates in, executable
//! groups out, plus the elastic-admission choice) — so the simulation
//! engine swaps policies without branching on them.

use crate::config::Policy;
use crate::scheduler::grouping::{schedule, GroupState, ScheduleOutcome};
use crate::scheduler::predictor::{GroupPerf, Predictor};
use crate::scheduler::{Candidate, NodeView, PolicyHooks};
use crate::config::SchedulerConfig;
use crate::workload::JobSpec;

/// mLoRA-style grouping: first-come-first-served, pack jobs into a group
/// "as long as memory capacity permits" — no heterogeneity awareness,
/// no throughput prediction, no per-job slowdown guarantees.
pub fn mlora_schedule(
    mut candidates: Vec<Candidate>,
    predictor: &mut Predictor,
    cfg: &SchedulerConfig,
) -> ScheduleOutcome {
    let probes0 = predictor.probes;
    let hits0 = predictor.cache_hits();
    // FIFO: submission order
    candidates.sort_by(|a, b| {
        crate::util::f64_cmp(a.job.submit_time, b.job.submit_time)
    });

    let mut groups: Vec<GroupState> = vec![];
    'next: for c in candidates {
        // try to append to the first open group with the same backbone
        // whose memory still fits (the only check mLoRA performs).
        // mLoRA batches adapters onto a shared pipeline, so appends are
        // confined to groups it shares a node with — it does not gang
        // arbitrary cross-node allocations together.
        let c_nodes = c.alloc.nodes();
        for g in groups.iter_mut() {
            if g.jobs[0].base_model != c.job.base_model {
                continue;
            }
            if g.jobs.len() >= cfg.max_group_size {
                continue;
            }
            if !g.alloc.nodes().iter().any(|n| c_nodes.contains(n)) {
                continue;
            }
            let mut jobs = g.jobs.clone();
            jobs.push(c.job.clone());
            let alloc = g.alloc.union(&c.alloc);
            // memory feasibility == plan exists
            if predictor.group_perf(&jobs, &alloc).is_some() {
                g.jobs = jobs;
                g.alloc = alloc;
                continue 'next;
            }
        }
        groups.push(GroupState {
            jobs: vec![c.job],
            alloc: c.alloc,
            urgency: c.urgency,
            residual: c.residual,
        });
    }

    let merges = groups
        .iter()
        .map(|g| g.jobs.len().saturating_sub(1))
        .sum::<usize>();
    let mut out = vec![];
    for g in groups {
        if let Some(perf) = predictor.group_perf(&g.jobs, &g.alloc) {
            out.push((g, perf));
        }
    }
    ScheduleOutcome {
        groups: out,
        merges_intra: merges,
        merges_inter: 0,
        predictor_probes: predictor.probes - probes0,
        plan_cache_hits: predictor.cache_hits() - hits0,
    }
}

/// Megatron baseline: every job runs isolated on its own allocation
/// (efficient model parallelism, zero co-location).
pub fn megatron_schedule(
    candidates: Vec<Candidate>,
    predictor: &mut Predictor,
) -> ScheduleOutcome {
    let probes0 = predictor.probes;
    let hits0 = predictor.cache_hits();
    let mut out = vec![];
    for c in candidates {
        let g = GroupState {
            jobs: vec![c.job],
            alloc: c.alloc,
            urgency: c.urgency,
            residual: c.residual,
        };
        if let Some(perf) = predictor.group_perf(&g.jobs, &g.alloc) {
            out.push((g, perf));
        }
    }
    ScheduleOutcome {
        groups: out,
        merges_intra: 0,
        merges_inter: 0,
        predictor_probes: predictor.probes - probes0,
        plan_cache_hits: predictor.cache_hits() - hits0,
    }
}

/// tLoRA's hooks: Adapter-Scheduler dispatch (§3.4, Algorithm 1) and
/// throughput-maximizing elastic admission under every member's Δ^max.
/// `aimd: false` is the tLoRA-w/o-Kernel-Fuser ablation.
pub struct TloraHooks {
    pub aimd: bool,
}

impl PolicyHooks for TloraHooks {
    fn dispatch(
        &self,
        candidates: Vec<Candidate>,
        predictor: &mut Predictor,
        cfg: &SchedulerConfig,
    ) -> ScheduleOutcome {
        schedule(candidates, predictor, cfg)
    }

    fn aimd_enabled(&self) -> bool {
        self.aimd
    }

    fn straggler_aware(&self) -> bool {
        // tLoRA's scheduler is residual-capacity-aware (§3.4): a
        // suspected straggler has *negative* effective residual, so
        // detection slots naturally into its grouping decisions.
        // (Whether detection actually runs is gated by
        // `stragglers.detect` in the engine.)
        true
    }

    fn shrinks_in_place(&self) -> bool {
        // The fused super-model is elastic by construction (§3.2):
        // losing one device re-shards the shared backbone at the
        // surviving width instead of tearing the gang down. Whether
        // shrink scenarios actually run is gated by `faults.shrink`
        // in the engine; Megatron/mLoRA keep evict-whole-gang
        // semantics (no override).
        true
    }

    fn elastic_admit(
        &self,
        job: &JobSpec,
        groups: &[(GroupState, GroupPerf)],
        view: &NodeView,
        predictor: &mut Predictor,
        cfg: &SchedulerConfig,
    ) -> Option<usize> {
        // best group by predicted merged throughput, subject to the
        // *existing* members' Δ^max (progress guard); the newcomer is
        // queued — any progress beats zero, so its own slowdown bound
        // cannot veto admission (starvation avoidance, §3.4)
        let mut choice: Option<(usize, f64)> = None;
        for (gi, (g, perf)) in groups.iter().enumerate() {
            if g.jobs.len() >= cfg.max_group_size
                || g.jobs[0].base_model != job.base_model
            {
                continue;
            }
            // never place a new rider on a suspected straggler: the
            // predictor's gain estimate assumes nominal node speed,
            // and a degraded gang drags the rider down with it
            if view.suspects_alloc(&g.alloc) {
                continue;
            }
            let mut jobs2 = g.jobs.clone();
            jobs2.push(job.clone());
            let Some(merged) = predictor.group_perf(&jobs2, &g.alloc)
            else {
                continue;
            };
            if !merged.within_slowdown(&g.jobs) {
                continue;
            }
            let gain = merged.throughput_samples_s
                / perf.throughput_samples_s;
            if gain <= 1.0 {
                continue;
            }
            if choice.map_or(true, |(_, g0)| gain > g0) {
                choice = Some((gi, gain));
            }
        }
        choice.map(|(gi, _)| gi)
    }
}

/// mLoRA's hooks: FIFO memory packing and first-fit elastic admission
/// (no heterogeneity awareness, no slowdown guard). `aimd: true` is
/// the tLoRA-w/o-Scheduler ablation (mLoRA grouping, tLoRA kernels).
pub struct MloraHooks {
    pub aimd: bool,
}

impl PolicyHooks for MloraHooks {
    fn dispatch(
        &self,
        candidates: Vec<Candidate>,
        predictor: &mut Predictor,
        cfg: &SchedulerConfig,
    ) -> ScheduleOutcome {
        mlora_schedule(candidates, predictor, cfg)
    }

    fn aimd_enabled(&self) -> bool {
        self.aimd
    }

    fn elastic_admit(
        &self,
        job: &JobSpec,
        groups: &[(GroupState, GroupPerf)],
        _view: &NodeView,
        predictor: &mut Predictor,
        cfg: &SchedulerConfig,
    ) -> Option<usize> {
        // first group whose memory fits (FIFO), regardless of the
        // slowdown it inflicts on the members — and oblivious to
        // stragglers (no `straggler_aware`): mLoRA packs onto a
        // degraded node as happily as onto a healthy one
        for (gi, (g, _)) in groups.iter().enumerate() {
            if g.jobs.len() >= cfg.max_group_size
                || g.jobs[0].base_model != job.base_model
            {
                continue;
            }
            let mut jobs2 = g.jobs.clone();
            jobs2.push(job.clone());
            if predictor.group_perf(&jobs2, &g.alloc).is_some() {
                return Some(gi);
            }
        }
        None
    }
}

/// Megatron's hooks: every job isolated, never shares.
pub struct MegatronHooks;

impl PolicyHooks for MegatronHooks {
    fn dispatch(
        &self,
        candidates: Vec<Candidate>,
        predictor: &mut Predictor,
        _cfg: &SchedulerConfig,
    ) -> ScheduleOutcome {
        megatron_schedule(candidates, predictor)
    }

    fn aimd_enabled(&self) -> bool {
        false
    }

    fn elastic_admit(
        &self,
        _job: &JobSpec,
        _groups: &[(GroupState, GroupPerf)],
        _view: &NodeView,
        _predictor: &mut Predictor,
        _cfg: &SchedulerConfig,
    ) -> Option<usize> {
        None
    }
}

/// The hooks implementation for `policy`.
///
/// * tLoRA / tLoRA-w/o-Kernel-Fuser → the Adapter Scheduler (§3.4)
/// * tLoRA-w/o-Scheduler / mLoRA → mLoRA's FIFO memory packing
/// * Megatron → isolated
///
/// (The kernel choice — fused vs unfused — is carried by the
/// `Predictor`'s [`crate::planner::PlanOptions::fused_kernel`].)
pub fn hooks_for(policy: Policy) -> Box<dyn PolicyHooks> {
    match policy {
        Policy::TLora => Box::new(TloraHooks { aimd: true }),
        Policy::TLoraNoKernel => Box::new(TloraHooks { aimd: false }),
        Policy::TLoraNoSched => Box::new(MloraHooks { aimd: true }),
        Policy::MLora => Box::new(MloraHooks { aimd: false }),
        Policy::Megatron => Box::new(MegatronHooks),
    }
}

/// Dispatch a scheduling round for `policy` (convenience over
/// [`hooks_for`] for callers without a hooks instance).
pub fn dispatch(
    policy: Policy,
    candidates: Vec<Candidate>,
    predictor: &mut Predictor,
    cfg: &SchedulerConfig,
) -> ScheduleOutcome {
    hooks_for(policy).dispatch(candidates, predictor, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Allocator, ClusterSpec};
    use crate::planner::PlanOptions;
    use crate::workload::JobSpec;

    fn job(id: u64, rank: usize, batch: usize, gpus: usize) -> JobSpec {
        JobSpec {
            id,
            base_model: "llama3-8b".into(),
            rank,
            batch_size: batch,
            seq_len: 512,
            gpus,
            total_steps: 100,
            submit_time: id as f64,
            max_slowdown: 2.0,
        }
    }

    fn mk(
        jobs: Vec<JobSpec>,
    ) -> (Vec<Candidate>, Predictor, SchedulerConfig) {
        let spec = ClusterSpec::default_128();
        let mut alloc = Allocator::new(spec.clone());
        let mut pred = Predictor::new(spec, PlanOptions::default());
        let cands = jobs
            .into_iter()
            .map(|j| {
                let a = alloc.allocate(j.gpus).unwrap();
                let residual = pred.residual(&j, &a).unwrap_or(0.5);
                Candidate {
                    job: j,
                    alloc: a,
                    urgency: 0.0,
                    residual,
                }
            })
            .collect();
        (cands, pred, SchedulerConfig::default())
    }

    #[test]
    fn megatron_never_groups() {
        let (cands, mut pred, _) =
            mk((0..5).map(|i| job(i, 8, 4, 1)).collect());
        let out = megatron_schedule(cands, &mut pred);
        assert_eq!(out.groups.len(), 5);
        assert!(out.groups.iter().all(|(g, _)| g.jobs.len() == 1));
    }

    #[test]
    fn mlora_groups_fifo_until_memory() {
        let (cands, mut pred, cfg) =
            mk((0..4).map(|i| job(i, 8, 4, 1)).collect());
        let out = mlora_schedule(cands, &mut pred, &cfg);
        // 8B model + small adapters easily fit: mLoRA packs everything
        assert_eq!(out.groups.len(), 1);
        assert_eq!(out.groups[0].0.jobs.len(), 4);
        // FIFO order preserved inside the group
        let ids: Vec<u64> =
            out.groups[0].0.jobs.iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn mlora_ignores_slowdown_constraints() {
        // a tiny job packed with a heavy one: the tiny job's step is
        // tied to the heavy job's cadence (huge slowdown). tLoRA's Δ^max
        // guard refuses this; mLoRA happily packs it — the §4.2 "mLoRA
        // often underperforms Megatron" mechanism
        let mut a = job(0, 2, 1, 1);
        a.seq_len = 256;
        a.max_slowdown = 1.2;
        let mut b = job(1, 16, 8, 1);
        b.seq_len = 1024;
        b.max_slowdown = 1.2;
        let (cands, mut pred, cfg) = mk(vec![a, b]);
        let out = mlora_schedule(cands, &mut pred, &cfg);
        assert_eq!(out.groups.len(), 1, "mLoRA packs regardless");
        let (g, perf) = &out.groups[0];
        assert!(
            !perf.within_slowdown(&g.jobs),
            "expected a slowdown violation mLoRA cannot see"
        );
    }

    #[test]
    fn mlora_respects_base_model_boundary() {
        let mut b = job(1, 8, 4, 1);
        b.base_model = "qwen3-8b".into();
        let (cands, mut pred, cfg) = mk(vec![job(0, 8, 4, 1), b]);
        let out = mlora_schedule(cands, &mut pred, &cfg);
        assert_eq!(out.groups.len(), 2);
    }

    #[test]
    fn dispatch_routes_policies() {
        let (cands, mut pred, cfg) =
            mk((0..3).map(|i| job(i, 8, 4, 1)).collect());
        let out =
            dispatch(Policy::Megatron, cands, &mut pred, &cfg);
        assert_eq!(out.groups.len(), 3);
    }

    #[test]
    fn hooks_match_policy_capabilities() {
        for p in Policy::all() {
            let h = hooks_for(p);
            assert_eq!(
                h.aimd_enabled(),
                p.uses_kernel_fuser(),
                "{p:?}"
            );
        }
    }

    /// Isolated singleton groups, as the engine's dispatch would hand
    /// the elastic-admission step.
    fn singleton_groups(
        jobs: Vec<JobSpec>,
    ) -> (Vec<(GroupState, GroupPerf)>, Predictor, SchedulerConfig)
    {
        let (cands, mut pred, cfg) = mk(jobs);
        let out = megatron_schedule(cands, &mut pred);
        (out.groups, pred, cfg)
    }

    #[test]
    fn tlora_elastic_admit_picks_gaining_group_within_slowdown() {
        // complementary pair: a queued small job absorbed into an
        // under-utilized group raises merged throughput while the
        // existing member stays within its Δ^max
        let (groups, mut pred, cfg) =
            singleton_groups(vec![job(0, 8, 4, 1)]);
        let hooks = TloraHooks { aimd: true };
        let queued = job(1, 4, 2, 1);
        let gi = hooks.elastic_admit(
            &queued,
            &groups,
            &NodeView::oblivious(),
            &mut pred,
            &cfg,
        );
        assert_eq!(gi, Some(0), "complementary absorption refused");
        // and the committed merge respects the existing member's Δ^max
        let (g, perf) = &groups[0];
        let mut jobs2 = g.jobs.clone();
        jobs2.push(queued.clone());
        let merged = pred.group_perf(&jobs2, &g.alloc).unwrap();
        assert!(merged.within_slowdown(&g.jobs));
        assert!(
            merged.throughput_samples_s > perf.throughput_samples_s
        );
    }

    #[test]
    fn tlora_elastic_admit_vetoes_on_member_slowdown() {
        // the incumbent has a Δ^max so tight that sharing its GPU with
        // a heavy job must be rejected
        let mut incumbent = job(0, 16, 8, 1);
        incumbent.seq_len = 1024;
        incumbent.max_slowdown = 1.001;
        let (groups, mut pred, cfg) =
            singleton_groups(vec![incumbent]);
        let hooks = TloraHooks { aimd: true };
        let mut heavy = job(1, 16, 8, 1);
        heavy.seq_len = 1024;
        assert_eq!(
            hooks.elastic_admit(
                &heavy,
                &groups,
                &NodeView::oblivious(),
                &mut pred,
                &cfg
            ),
            None,
            "Δ^max guard must veto the absorption"
        );
    }

    #[test]
    fn tlora_elastic_admit_respects_base_model_boundary() {
        let (groups, mut pred, cfg) =
            singleton_groups(vec![job(0, 8, 4, 1)]);
        let hooks = TloraHooks { aimd: true };
        let mut other = job(1, 4, 2, 1);
        other.base_model = "qwen3-8b".into();
        assert_eq!(
            hooks.elastic_admit(
                &other,
                &groups,
                &NodeView::oblivious(),
                &mut pred,
                &cfg
            ),
            None
        );
    }

    #[test]
    fn tlora_elastic_admit_refuses_riders_on_suspected_stragglers() {
        use crate::scheduler::NodeSpeedEstimator;
        // same complementary pair that absorbs under an oblivious
        // view — but the incumbent group's node is a suspected
        // straggler, so detection-aware tLoRA keeps the rider queued
        let (groups, mut pred, cfg) =
            singleton_groups(vec![job(0, 8, 4, 1)]);
        let hooks = TloraHooks { aimd: true };
        assert!(hooks.straggler_aware());
        let queued = job(1, 4, 2, 1);
        let node = groups[0].0.alloc.gpus[0].node;
        let mut est = NodeSpeedEstimator::new(node + 1, 0.5);
        for _ in 0..50 {
            est.observe_group(&[node], 3.0, 1.0);
        }
        let view = NodeView::new(&est, 1.5);
        assert!(view.suspected(node));
        assert_eq!(
            hooks.elastic_admit(
                &queued,
                &groups,
                &view,
                &mut pred,
                &cfg
            ),
            None,
            "rider placed on a suspected straggler"
        );
        // and the same call with an oblivious view still absorbs
        assert_eq!(
            hooks.elastic_admit(
                &queued,
                &groups,
                &NodeView::oblivious(),
                &mut pred,
                &cfg
            ),
            Some(0)
        );
    }

    #[test]
    fn baselines_stay_straggler_oblivious() {
        assert!(!MloraHooks { aimd: false }.straggler_aware());
        assert!(!MloraHooks { aimd: true }.straggler_aware());
        assert!(!MegatronHooks.straggler_aware());
        for p in Policy::all() {
            assert_eq!(
                hooks_for(p).straggler_aware(),
                p.uses_tlora_scheduler(),
                "{p:?}"
            );
        }
    }

    #[test]
    fn only_tlora_scheduler_policies_shrink_in_place() {
        assert!(!MloraHooks { aimd: false }.shrinks_in_place());
        assert!(!MloraHooks { aimd: true }.shrinks_in_place());
        assert!(!MegatronHooks.shrinks_in_place());
        for p in Policy::all() {
            assert_eq!(
                hooks_for(p).shrinks_in_place(),
                p.uses_tlora_scheduler(),
                "{p:?}"
            );
        }
    }

    #[test]
    fn mlora_elastic_admit_first_fit_ignores_slowdown() {
        // mLoRA takes the first group whose memory fits, even when the
        // merge violates the member's slowdown budget — the §4.2
        // "mLoRA often underperforms Megatron" mechanism again
        let mut incumbent = job(0, 16, 8, 1);
        incumbent.seq_len = 1024;
        incumbent.max_slowdown = 1.001;
        let (groups, mut pred, cfg) =
            singleton_groups(vec![incumbent]);
        let hooks = MloraHooks { aimd: false };
        let mut heavy = job(1, 16, 8, 1);
        heavy.seq_len = 1024;
        assert_eq!(
            hooks.elastic_admit(
                &heavy,
                &groups,
                &NodeView::oblivious(),
                &mut pred,
                &cfg
            ),
            Some(0)
        );
    }

    #[test]
    fn megatron_elastic_admit_never_shares() {
        let (groups, mut pred, cfg) =
            singleton_groups(vec![job(0, 8, 4, 1)]);
        assert_eq!(
            MegatronHooks.elastic_admit(
                &job(1, 4, 2, 1),
                &groups,
                &NodeView::oblivious(),
                &mut pred,
                &cfg
            ),
            None
        );
    }
}
