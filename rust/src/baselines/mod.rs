//! Baseline grouping policies (§4.1): mLoRA, Megatron, and the tLoRA
//! ablations. Each exposes the same interface as the tLoRA Adapter
//! Scheduler — a list of runnable candidates in, a set of executable
//! groups out — so the simulator can swap policies freely.

use crate::config::Policy;
use crate::scheduler::grouping::{schedule, GroupState, ScheduleOutcome};
use crate::scheduler::predictor::Predictor;
use crate::scheduler::Candidate;
use crate::config::SchedulerConfig;

/// mLoRA-style grouping: first-come-first-served, pack jobs into a group
/// "as long as memory capacity permits" — no heterogeneity awareness,
/// no throughput prediction, no per-job slowdown guarantees.
pub fn mlora_schedule(
    mut candidates: Vec<Candidate>,
    predictor: &mut Predictor,
    cfg: &SchedulerConfig,
) -> ScheduleOutcome {
    let probes0 = predictor.probes;
    // FIFO: submission order
    candidates.sort_by(|a, b| {
        crate::util::f64_cmp(a.job.submit_time, b.job.submit_time)
    });

    let mut groups: Vec<GroupState> = vec![];
    'next: for c in candidates {
        // try to append to the first open group with the same backbone
        // whose memory still fits (the only check mLoRA performs).
        // mLoRA batches adapters onto a shared pipeline, so appends are
        // confined to groups it shares a node with — it does not gang
        // arbitrary cross-node allocations together.
        let c_nodes = c.alloc.nodes();
        for g in groups.iter_mut() {
            if g.jobs[0].base_model != c.job.base_model {
                continue;
            }
            if g.jobs.len() >= cfg.max_group_size {
                continue;
            }
            if !g.alloc.nodes().iter().any(|n| c_nodes.contains(n)) {
                continue;
            }
            let mut jobs = g.jobs.clone();
            jobs.push(c.job.clone());
            let alloc = g.alloc.union(&c.alloc);
            // memory feasibility == plan exists
            if predictor.group_perf(&jobs, &alloc).is_some() {
                g.jobs = jobs;
                g.alloc = alloc;
                continue 'next;
            }
        }
        groups.push(GroupState {
            jobs: vec![c.job],
            alloc: c.alloc,
            urgency: c.urgency,
            residual: c.residual,
        });
    }

    let merges = groups
        .iter()
        .map(|g| g.jobs.len().saturating_sub(1))
        .sum::<usize>();
    let mut out = vec![];
    for g in groups {
        if let Some(perf) = predictor.group_perf(&g.jobs, &g.alloc) {
            out.push((g, perf));
        }
    }
    ScheduleOutcome {
        groups: out,
        merges_intra: merges,
        merges_inter: 0,
        predictor_probes: predictor.probes - probes0,
    }
}

/// Megatron baseline: every job runs isolated on its own allocation
/// (efficient model parallelism, zero co-location).
pub fn megatron_schedule(
    candidates: Vec<Candidate>,
    predictor: &mut Predictor,
) -> ScheduleOutcome {
    let probes0 = predictor.probes;
    let mut out = vec![];
    for c in candidates {
        let g = GroupState {
            jobs: vec![c.job],
            alloc: c.alloc,
            urgency: c.urgency,
            residual: c.residual,
        };
        if let Some(perf) = predictor.group_perf(&g.jobs, &g.alloc) {
            out.push((g, perf));
        }
    }
    ScheduleOutcome {
        groups: out,
        merges_intra: 0,
        merges_inter: 0,
        predictor_probes: predictor.probes - probes0,
    }
}

/// Dispatch a scheduling round for `policy`.
///
/// * tLoRA / tLoRA-w/o-Kernel-Fuser → the Adapter Scheduler (§3.4)
/// * tLoRA-w/o-Scheduler / mLoRA → mLoRA's FIFO memory packing
/// * Megatron → isolated
///
/// (The kernel choice — fused vs unfused — is carried by the
/// `Predictor`'s [`crate::planner::PlanOptions::fused_kernel`].)
pub fn dispatch(
    policy: Policy,
    candidates: Vec<Candidate>,
    predictor: &mut Predictor,
    cfg: &SchedulerConfig,
) -> ScheduleOutcome {
    if policy.uses_tlora_scheduler() {
        schedule(candidates, predictor, cfg)
    } else if policy.groups_jobs() {
        mlora_schedule(candidates, predictor, cfg)
    } else {
        megatron_schedule(candidates, predictor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Allocator, ClusterSpec};
    use crate::planner::PlanOptions;
    use crate::workload::JobSpec;

    fn job(id: u64, rank: usize, batch: usize, gpus: usize) -> JobSpec {
        JobSpec {
            id,
            base_model: "llama3-8b".into(),
            rank,
            batch_size: batch,
            seq_len: 512,
            gpus,
            total_steps: 100,
            submit_time: id as f64,
            max_slowdown: 2.0,
        }
    }

    fn mk(
        jobs: Vec<JobSpec>,
    ) -> (Vec<Candidate>, Predictor, SchedulerConfig) {
        let spec = ClusterSpec::default_128();
        let mut alloc = Allocator::new(spec.clone());
        let mut pred = Predictor::new(spec, PlanOptions::default());
        let cands = jobs
            .into_iter()
            .map(|j| {
                let a = alloc.allocate(j.gpus).unwrap();
                let residual = pred.residual(&j, &a).unwrap_or(0.5);
                Candidate {
                    job: j,
                    alloc: a,
                    urgency: 0.0,
                    residual,
                }
            })
            .collect();
        (cands, pred, SchedulerConfig::default())
    }

    #[test]
    fn megatron_never_groups() {
        let (cands, mut pred, _) =
            mk((0..5).map(|i| job(i, 8, 4, 1)).collect());
        let out = megatron_schedule(cands, &mut pred);
        assert_eq!(out.groups.len(), 5);
        assert!(out.groups.iter().all(|(g, _)| g.jobs.len() == 1));
    }

    #[test]
    fn mlora_groups_fifo_until_memory() {
        let (cands, mut pred, cfg) =
            mk((0..4).map(|i| job(i, 8, 4, 1)).collect());
        let out = mlora_schedule(cands, &mut pred, &cfg);
        // 8B model + small adapters easily fit: mLoRA packs everything
        assert_eq!(out.groups.len(), 1);
        assert_eq!(out.groups[0].0.jobs.len(), 4);
        // FIFO order preserved inside the group
        let ids: Vec<u64> =
            out.groups[0].0.jobs.iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn mlora_ignores_slowdown_constraints() {
        // a tiny job packed with a heavy one: the tiny job's step is
        // tied to the heavy job's cadence (huge slowdown). tLoRA's Δ^max
        // guard refuses this; mLoRA happily packs it — the §4.2 "mLoRA
        // often underperforms Megatron" mechanism
        let mut a = job(0, 2, 1, 1);
        a.seq_len = 256;
        a.max_slowdown = 1.2;
        let mut b = job(1, 16, 8, 1);
        b.seq_len = 1024;
        b.max_slowdown = 1.2;
        let (cands, mut pred, cfg) = mk(vec![a, b]);
        let out = mlora_schedule(cands, &mut pred, &cfg);
        assert_eq!(out.groups.len(), 1, "mLoRA packs regardless");
        let (g, perf) = &out.groups[0];
        assert!(
            !perf.within_slowdown(&g.jobs),
            "expected a slowdown violation mLoRA cannot see"
        );
    }

    #[test]
    fn mlora_respects_base_model_boundary() {
        let mut b = job(1, 8, 4, 1);
        b.base_model = "qwen3-8b".into();
        let (cands, mut pred, cfg) = mk(vec![job(0, 8, 4, 1), b]);
        let out = mlora_schedule(cands, &mut pred, &cfg);
        assert_eq!(out.groups.len(), 2);
    }

    #[test]
    fn dispatch_routes_policies() {
        let (cands, mut pred, cfg) =
            mk((0..3).map(|i| job(i, 8, 4, 1)).collect());
        let out =
            dispatch(Policy::Megatron, cands, &mut pred, &cfg);
        assert_eq!(out.groups.len(), 3);
    }
}
