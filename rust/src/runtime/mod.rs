//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them from Rust — Python never runs on the training path.
//!
//! Pipeline: `HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` → `execute_b` over device-resident buffers.
//! HLO *text* is the interchange format because the crate's pinned
//! xla_extension (0.5.1) rejects jax≥0.5's 64-bit-id serialized protos;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod manifest;
pub mod exec;
pub mod checkpoint;

pub use checkpoint::Checkpoint;
pub use exec::{Executable, Runtime, Trainer, StepStats};
pub use manifest::{Manifest, ProgramMeta, TensorSpec, VariantMeta,
                   KmicroMeta};
