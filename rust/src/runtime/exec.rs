//! PJRT execution: compile HLO text, manage device-resident state, and
//! drive fused SSM training steps.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{Manifest, ProgramMeta, VariantMeta};

/// Wraps the PJRT CPU client and the loaded manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
}

/// A compiled program with its I/O contract.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ProgramMeta,
}

impl Runtime {
    /// Create a CPU PJRT client and load `dir/manifest.json`.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)
            .map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest })
    }

    /// Compile one program from its HLO text file.
    pub fn compile(&self, meta: &ProgramMeta) -> Result<Executable> {
        let path = self.manifest.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(Executable {
            exe,
            meta: meta.clone(),
        })
    }

    /// Upload a host literal to the device.
    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("upload: {e:?}"))
    }

    /// Build an i32 literal of the given shape.
    pub fn literal_i32(values: &[i32], shape: &[usize]) -> Result<xla::Literal> {
        let expect: usize = shape.iter().product();
        if values.len() != expect {
            bail!("literal_i32: {} values for shape {shape:?}", values.len());
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(values)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// Build an f32 literal of the given shape.
    pub fn literal_f32(values: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(values)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }
}

impl Executable {
    /// Execute with host literals; returns the decomposed output tuple.
    pub fn run_literals(&self, args: &[xla::Literal])
        -> Result<Vec<xla::Literal>> {
        if args.len() != self.meta.inputs.len() {
            bail!(
                "expected {} args, got {}",
                self.meta.inputs.len(),
                args.len()
            );
        }
        let out = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        tuple
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))
    }

    /// Execute with device buffers; returns the raw output tuple literal
    /// (callers decompose as needed).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer])
        -> Result<Vec<xla::Literal>> {
        if args.len() != self.meta.inputs.len() {
            bail!(
                "expected {} args, got {}",
                self.meta.inputs.len(),
                args.len()
            );
        }
        let out = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .map_err(|e| anyhow!("execute_b: {e:?}"))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        tuple
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))
    }
}

/// Per-step training statistics.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub loss: f32,
    pub per_adapter_loss: Vec<f32>,
}

/// A device buffer paired with the host literal it was copied from.
///
/// SAFETY-CRITICAL: `buffer_from_host_literal` enqueues the host→device
/// copy on a PJRT worker thread and returns immediately; dropping the
/// source literal while the copy is in flight is a use-after-free (it
/// segfaults inside `AbstractTfrtCpuBuffer::CopyFromLiteral`). Holding
/// the literal for the buffer's lifetime makes the pair sound.
pub struct DeviceTensor {
    pub buf: xla::PjRtBuffer,
    _src: xla::Literal,
}

/// Drives one SSM variant: initializes device-resident state from the
/// AOT init program and advances fused training steps. The backbone
/// buffers are uploaded once and never touched again (they are frozen);
/// only the small adapter/optimizer tensors round-trip each step.
pub struct Trainer {
    step_exe: Executable,
    variant: VariantMeta,
    /// device state in manifest order: backbone ++ lora ++ m ++ v ++ t
    state: Vec<DeviceTensor>,
    client_handle: RuntimeHandle,
    pub steps_done: u64,
}

/// Cheap clone of the pieces of [`Runtime`] the trainer needs.
struct RuntimeHandle {
    client: xla::PjRtClient,
}

impl RuntimeHandle {
    /// Upload, keeping the source literal alive with the buffer (see
    /// [`DeviceTensor`]).
    fn upload(&self, lit: xla::Literal) -> Result<DeviceTensor> {
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow!("upload: {e:?}"))?;
        Ok(DeviceTensor {
            buf,
            _src: lit,
        })
    }
}

impl Trainer {
    /// Compile init+step for `variant`, run init with `seed`, upload the
    /// state.
    pub fn new(runtime: &Runtime, variant: &str, seed: i32)
        -> Result<Trainer> {
        Trainer::new_with_init_from(runtime, variant, variant, seed)
    }

    /// Like [`Trainer::new`] but borrow the init program from another
    /// variant that shares the same state layout (nano-batched step
    /// programs reuse their base variant's init).
    pub fn new_with_init_from(
        runtime: &Runtime,
        variant: &str,
        init_variant: &str,
        seed: i32,
    ) -> Result<Trainer> {
        let vmeta = runtime
            .manifest
            .variant(variant)
            .with_context(|| format!("unknown variant {variant}"))?
            .clone();
        let init_owner = runtime
            .manifest
            .variant(init_variant)
            .with_context(|| format!("unknown variant {init_variant}"))?;
        let init_meta = init_owner
            .init
            .as_ref()
            .with_context(|| format!("variant {init_variant} has no init"))?;
        let init_exe = runtime.compile(init_meta)?;
        let step_exe = runtime.compile(&vmeta.step)?;

        let seed_lit = xla::Literal::scalar(seed);
        let state_lits = init_exe.run_literals(&[seed_lit])?;
        if state_lits.len() != vmeta.n_state() {
            bail!(
                "init returned {} tensors, expected {}",
                state_lits.len(),
                vmeta.n_state()
            );
        }
        let handle = RuntimeHandle {
            client: runtime_client(runtime),
        };
        let state = state_lits
            .into_iter()
            .map(|l| handle.upload(l))
            .collect::<Result<Vec<_>>>()?;
        Ok(Trainer {
            step_exe,
            variant: vmeta,
            state,
            client_handle: handle,
            steps_done: 0,
        })
    }

    pub fn variant(&self) -> &VariantMeta {
        &self.variant
    }

    /// One fused training step over `tokens` (row-major [B, S]) with
    /// per-sequence `adapter_ids` (len B).
    pub fn step(&mut self, tokens: &[i32], adapter_ids: &[i32])
        -> Result<StepStats> {
        let cfg = &self.variant.config;
        let b = cfg.total_batch();
        let s = cfg.seq_len;
        if tokens.len() != b * s {
            bail!("tokens: got {}, want {}", tokens.len(), b * s);
        }
        if adapter_ids.len() != b {
            bail!("adapter_ids: got {}, want {b}", adapter_ids.len());
        }
        let tok_buf = self
            .client_handle
            .upload(Runtime::literal_i32(tokens, &[b, s])?)?;
        let aid_buf = self
            .client_handle
            .upload(Runtime::literal_i32(adapter_ids, &[b])?)?;

        let mut args: Vec<&xla::PjRtBuffer> =
            self.state.iter().map(|t| &t.buf).collect();
        args.push(&tok_buf.buf);
        args.push(&aid_buf.buf);
        let mut outs = self.step_exe.run_buffers(&args)?;
        // outputs: lora(n) ++ m(n) ++ v(n) ++ t ++ loss ++ per_adapter
        let n_l = self.variant.n_lora;
        let expect = 3 * n_l + 3;
        if outs.len() != expect {
            bail!("step returned {} tensors, expected {expect}", outs.len());
        }
        let per_adapter_lit = outs.pop().unwrap();
        let loss_lit = outs.pop().unwrap();
        // re-upload the updated adapter/optimizer state (backbone fixed)
        for (i, lit) in outs.into_iter().enumerate() {
            self.state[self.variant.n_backbone + i] =
                self.client_handle.upload(lit)?;
        }
        let loss = loss_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?[0];
        let per_adapter_loss = per_adapter_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("per-adapter loss: {e:?}"))?;
        self.steps_done += 1;
        Ok(StepStats {
            loss,
            per_adapter_loss,
        })
    }

    /// Download the current LoRA parameter tensors (inspection/tests).
    pub fn lora_state(&self) -> Result<Vec<Vec<f32>>> {
        let n0 = self.variant.n_backbone;
        (n0..n0 + self.variant.n_lora)
            .map(|i| self.download_f32(i))
            .collect()
    }

    /// Download the full trainable state — lora ++ m ++ v ++ t — in
    /// manifest order (checkpointing).
    pub fn trainable_state(&self) -> Result<Vec<Vec<f32>>> {
        (self.variant.n_backbone..self.variant.n_state())
            .map(|i| self.download_f32(i))
            .collect()
    }

    /// Overwrite the trainable state from flattened f32 tensors (the
    /// counterpart of [`Self::trainable_state`]; checkpoint restore).
    pub fn load_trainable_state(&mut self, tensors: &[Vec<f32>])
        -> Result<()> {
        let n0 = self.variant.n_backbone;
        let expect = self.variant.n_state() - n0;
        if tensors.len() != expect {
            bail!("expected {expect} trainable tensors, got {}",
                  tensors.len());
        }
        for (k, vals) in tensors.iter().enumerate() {
            let spec = &self.variant.step.inputs[n0 + k];
            if spec.elements() != vals.len() {
                bail!(
                    "tensor {k}: {} values for shape {:?}",
                    vals.len(),
                    spec.shape
                );
            }
            let lit = Runtime::literal_f32(vals, &spec.shape)?;
            self.state[n0 + k] = self.client_handle.upload(lit)?;
        }
        Ok(())
    }

    fn download_f32(&self, i: usize) -> Result<Vec<f32>> {
        self.state[i]
            .buf
            .to_literal_sync()
            .map_err(|e| anyhow!("download: {e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}

/// The xla client is an Rc-style handle internally; cloning shares it.
fn runtime_client(rt: &Runtime) -> xla::PjRtClient {
    rt.client.clone()
}
