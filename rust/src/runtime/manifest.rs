//! `artifacts/manifest.json` parsing: the contract between `aot.py` and
//! the Rust runtime. The manifest pins every program's positional buffer
//! layout (shapes + dtypes in argument order), so binding is fully
//! static — no Python, no reflection, no shape inference at run time.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// Shape + dtype of one program argument/result.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_size(&self) -> usize {
        let per = match self.dtype.as_str() {
            "f32" | "i32" => 4,
            "bf16" | "f16" => 2,
            _ => 4,
        };
        self.elements() * per
    }

    fn from_json(j: &Json) -> Result<TensorSpec, String> {
        Ok(TensorSpec {
            shape: j
                .get("shape")
                .and_then(Json::as_usize_vec)
                .ok_or("tensor spec missing shape")?,
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .ok_or("tensor spec missing dtype")?
                .to_string(),
        })
    }
}

/// One AOT program (init or step) with its I/O layout.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramMeta {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ProgramMeta {
    fn from_json(j: &Json) -> Result<ProgramMeta, String> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>, String> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("program missing {key}"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(ProgramMeta {
            file: j
                .get("file")
                .and_then(Json::as_str)
                .ok_or("program missing file")?
                .to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }
}

/// Key SSM configuration echoed into the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct VariantConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub num_adapters: usize,
    pub r_max: usize,
    pub ranks: Vec<usize>,
    pub batch_sizes: Vec<usize>,
    pub fused: bool,
}

impl VariantConfig {
    pub fn total_batch(&self) -> usize {
        self.batch_sizes.iter().sum()
    }

    fn from_json(j: &Json) -> Result<VariantConfig, String> {
        let u = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("config missing {k}"))
        };
        Ok(VariantConfig {
            vocab: u("vocab")?,
            d_model: u("d_model")?,
            n_layers: u("n_layers")?,
            seq_len: u("seq_len")?,
            num_adapters: u("num_adapters")?,
            r_max: u("r_max")?,
            ranks: j
                .get("ranks")
                .and_then(Json::as_usize_vec)
                .ok_or("config missing ranks")?,
            batch_sizes: j
                .get("batch_sizes")
                .and_then(Json::as_usize_vec)
                .ok_or("config missing batch_sizes")?,
            fused: j
                .get("fused")
                .and_then(Json::as_bool)
                .unwrap_or(true),
        })
    }
}

/// One SSM variant: an optional init program + the train-step program.
#[derive(Debug, Clone, PartialEq)]
pub struct VariantMeta {
    pub name: String,
    pub n_nano: usize,
    pub config: VariantConfig,
    pub init: Option<ProgramMeta>,
    pub step: ProgramMeta,
    pub n_backbone: usize,
    pub n_lora: usize,
    pub param_count: u64,
    pub lora_param_count: u64,
    pub flops_per_step: f64,
}

impl VariantMeta {
    /// Number of state tensors (backbone + lora + m + v + t).
    pub fn n_state(&self) -> usize {
        self.n_backbone + 3 * self.n_lora + 1
    }

    fn from_json(j: &Json) -> Result<VariantMeta, String> {
        let layout = j.get("state_layout").ok_or("missing state_layout")?;
        Ok(VariantMeta {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or("variant missing name")?
                .to_string(),
            n_nano: j.get("n_nano").and_then(Json::as_usize).unwrap_or(1),
            config: VariantConfig::from_json(
                j.get("config").ok_or("variant missing config")?,
            )?,
            init: match j.get("init") {
                Some(p) => Some(ProgramMeta::from_json(p)?),
                None => None,
            },
            step: ProgramMeta::from_json(
                j.get("step").ok_or("variant missing step")?,
            )?,
            n_backbone: layout
                .get("n_backbone")
                .and_then(Json::as_usize)
                .ok_or("layout missing n_backbone")?,
            n_lora: layout
                .get("n_lora")
                .and_then(Json::as_usize)
                .ok_or("layout missing n_lora")?,
            param_count: j
                .get("param_count")
                .and_then(Json::as_i64)
                .unwrap_or(0) as u64,
            lora_param_count: j
                .get("lora_param_count")
                .and_then(Json::as_i64)
                .unwrap_or(0) as u64,
            flops_per_step: j
                .get("flops_per_step")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
        })
    }
}

/// Kernel micro-bench program (fused vs unfused, Fig. 7 / kernel_micro).
#[derive(Debug, Clone, PartialEq)]
pub struct KmicroMeta {
    pub name: String,
    pub file: String,
    pub fused: bool,
    pub k: usize,
    pub t: usize,
    pub d: usize,
    pub r: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl KmicroMeta {
    fn from_json(j: &Json) -> Result<KmicroMeta, String> {
        let u = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("kmicro missing {k}"))
        };
        Ok(KmicroMeta {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or("kmicro missing name")?
                .to_string(),
            file: j
                .get("file")
                .and_then(Json::as_str)
                .ok_or("kmicro missing file")?
                .to_string(),
            fused: j
                .get("fused")
                .and_then(Json::as_bool)
                .unwrap_or(true),
            k: u("k")?,
            t: u("t")?,
            d: u("d")?,
            r: u("r")?,
            inputs: j
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or("kmicro missing inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_, _>>()?,
            outputs: j
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or("kmicro missing outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_, _>>()?,
        })
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: Vec<VariantMeta>,
    pub nano: Vec<VariantMeta>,
    pub kmicro: Vec<KmicroMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let j = json::parse_file(&dir.join("manifest.json"))?;
        Manifest::from_json(dir, &j)
    }

    pub fn from_json(dir: &Path, j: &Json) -> Result<Manifest, String> {
        let arr = |key: &str| -> Vec<Json> {
            j.get(key)
                .and_then(Json::as_arr)
                .map(|a| a.to_vec())
                .unwrap_or_default()
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            variants: arr("variants")
                .iter()
                .map(VariantMeta::from_json)
                .collect::<Result<_, _>>()?,
            nano: arr("nano")
                .iter()
                .map(VariantMeta::from_json)
                .collect::<Result<_, _>>()?,
            kmicro: arr("kmicro")
                .iter()
                .map(KmicroMeta::from_json)
                .collect::<Result<_, _>>()?,
        })
    }

    pub fn variant(&self, name: &str) -> Option<&VariantMeta> {
        self.variants
            .iter()
            .chain(self.nano.iter())
            .find(|v| v.name == name)
    }

    pub fn kmicro_by_name(&self, name: &str) -> Option<&KmicroMeta> {
        self.kmicro.iter().find(|k| k.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        json::parse(
            r#"{
            "format": 1,
            "variants": [{
                "name": "tiny", "n_nano": 1,
                "config": {"vocab": 256, "d_model": 64, "n_layers": 2,
                           "seq_len": 32, "num_adapters": 4, "r_max": 8,
                           "ranks": [2,4,8,8], "batch_sizes": [2,2,2,2],
                           "fused": true},
                "param_count": 100000, "lora_param_count": 8192,
                "flops_per_step": 1e9,
                "state_layout": {"n_backbone": 10, "n_lora": 4},
                "init": {"file": "tiny.init.hlo.txt",
                         "inputs": [{"shape": [], "dtype": "i32"}],
                         "outputs": [{"shape": [256,64], "dtype": "f32"}]},
                "step": {"file": "tiny.step.hlo.txt",
                         "inputs": [{"shape": [8,32], "dtype": "i32"}],
                         "outputs": [{"shape": [], "dtype": "f32"}]}
            }],
            "nano": [],
            "kmicro": [{
                "name": "kmicro_fused_k4", "file": "k.hlo.txt",
                "fused": true, "k": 4, "t": 512, "d": 256, "r": 16,
                "inputs": [{"shape": [512,256], "dtype": "f32"}],
                "outputs": [{"shape": [512,256], "dtype": "f32"}]
            }]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(Path::new("/tmp"), &sample()).unwrap();
        assert_eq!(m.variants.len(), 1);
        let v = m.variant("tiny").unwrap();
        assert_eq!(v.config.total_batch(), 8);
        assert_eq!(v.n_state(), 10 + 12 + 1);
        assert!(v.init.is_some());
        assert_eq!(m.kmicro.len(), 1);
        assert!(m.kmicro_by_name("kmicro_fused_k4").is_some());
        assert!(m.variant("nope").is_none());
    }

    #[test]
    fn tensor_spec_sizes() {
        let t = TensorSpec {
            shape: vec![4, 8],
            dtype: "f32".into(),
        };
        assert_eq!(t.elements(), 32);
        assert_eq!(t.byte_size(), 128);
        let b = TensorSpec {
            shape: vec![2],
            dtype: "bf16".into(),
        };
        assert_eq!(b.byte_size(), 4);
        let s = TensorSpec {
            shape: vec![],
            dtype: "f32".into(),
        };
        assert_eq!(s.elements(), 1);
    }

    #[test]
    fn rejects_malformed() {
        let j = json::parse(r#"{"variants": [{"name": "x"}]}"#).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // integration-level check against the actual artifacts dir when
        // `make artifacts` has run (skipped otherwise)
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            let tiny = m.variant("tiny").expect("tiny variant");
            assert_eq!(tiny.n_backbone, 10);
            assert_eq!(tiny.n_lora, 4);
            assert_eq!(
                tiny.step.inputs.len(),
                tiny.n_state() + 2,
                "state + tokens + adapter_ids"
            );
        }
    }
}
