//! Adapter checkpointing: save/restore the trainable state (LoRA
//! parameters + Adam moments) of a fused SSM.
//!
//! Format: a JSON header line (variant, init seed, step count, tensor
//! byte lengths) followed by raw little-endian f32 payloads. The frozen
//! backbone is *not* stored — it is reproducible from the AOT init
//! program and the recorded seed, so an e2e100m checkpoint is ~29 MB
//! instead of ~420 MB.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::exec::{Runtime, Trainer};
use crate::util::json::{self, Json};

/// Magic first bytes (also versions the format).
const MAGIC: &str = "TLORA-CKPT-1";

/// Serialized trainable state of one fused SSM.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub variant: String,
    pub seed: i32,
    pub steps_done: u64,
    /// lora ++ m ++ v ++ t tensors, flattened f32, manifest order
    pub tensors: Vec<Vec<f32>>,
}

impl Checkpoint {
    /// Capture the trainable state of `trainer`.
    pub fn capture(trainer: &Trainer, seed: i32) -> Result<Checkpoint> {
        Ok(Checkpoint {
            variant: trainer.variant().name.clone(),
            seed,
            steps_done: trainer.steps_done,
            tensors: trainer.trainable_state()?,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let header = Json::obj()
            .set("magic", MAGIC)
            .set("variant", self.variant.clone())
            .set("seed", self.seed as i64)
            .set("steps_done", self.steps_done)
            .set(
                "lens",
                Json::Arr(
                    self.tensors
                        .iter()
                        .map(|t| Json::Int(t.len() as i64))
                        .collect(),
                ),
            );
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        writeln!(f, "{}", header.to_string())?;
        for t in &self.tensors {
            let bytes: Vec<u8> =
                t.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut all = Vec::new();
        f.read_to_end(&mut all)?;
        let nl = all
            .iter()
            .position(|&b| b == b'\n')
            .context("missing header line")?;
        let header = json::parse(
            std::str::from_utf8(&all[..nl]).context("non-utf8 header")?,
        )
        .map_err(|e| anyhow!("header: {e}"))?;
        if header.get("magic").and_then(Json::as_str) != Some(MAGIC) {
            bail!("not a tLoRA checkpoint (bad magic)");
        }
        let lens = header
            .get("lens")
            .and_then(Json::as_usize_vec)
            .context("header missing lens")?;
        let mut tensors = Vec::with_capacity(lens.len());
        let mut off = nl + 1;
        for len in lens {
            let bytes = len * 4;
            if off + bytes > all.len() {
                bail!("checkpoint truncated");
            }
            let mut t = Vec::with_capacity(len);
            for chunk in all[off..off + bytes].chunks_exact(4) {
                t.push(f32::from_le_bytes(chunk.try_into().unwrap()));
            }
            tensors.push(t);
            off += bytes;
        }
        if off != all.len() {
            bail!("trailing bytes in checkpoint");
        }
        Ok(Checkpoint {
            variant: header
                .get("variant")
                .and_then(Json::as_str)
                .context("header missing variant")?
                .to_string(),
            seed: header
                .get("seed")
                .and_then(Json::as_i64)
                .context("header missing seed")? as i32,
            steps_done: header
                .get("steps_done")
                .and_then(Json::as_i64)
                .unwrap_or(0) as u64,
            tensors,
        })
    }

    /// Rebuild a trainer: backbone from the recorded init seed, then
    /// overwrite the trainable tensors from the checkpoint.
    pub fn restore(&self, runtime: &Runtime) -> Result<Trainer> {
        let mut trainer =
            Trainer::new(runtime, &self.variant, self.seed)?;
        trainer.load_trainable_state(&self.tensors)?;
        trainer.steps_done = self.steps_done;
        Ok(trainer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_in_memory_format() {
        let ck = Checkpoint {
            variant: "tiny".into(),
            seed: 7,
            steps_done: 42,
            tensors: vec![vec![1.0, -2.5, 3.25], vec![], vec![0.0; 5]],
        };
        let dir = std::env::temp_dir().join("tlora_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.variant, "tiny");
        assert_eq!(back.seed, 7);
        assert_eq!(back.steps_done, 42);
        assert_eq!(back.tensors, ck.tensors);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("tlora_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"{\"magic\":\"nope\"}\n").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::write(&path, b"not json\n").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    /// A small valid checkpoint with non-trivial payloads, for the
    /// corruption tests to mutate.
    fn sample() -> Checkpoint {
        Checkpoint {
            variant: "small".into(),
            seed: -3,
            steps_done: 7,
            tensors: vec![
                vec![0.5, -1.25, 3.0e-8, f32::MAX],
                vec![42.0],
            ],
        }
    }

    fn write_sample(name: &str) -> (std::path::PathBuf, Vec<u8>) {
        let dir = std::env::temp_dir().join("tlora_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        sample().save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        (path, bytes)
    }

    #[test]
    fn save_load_save_is_byte_identical() {
        // save -> load -> save must reproduce the file bit-for-bit:
        // the on-disk format is itself a determinism artifact
        let (path, first) = write_sample("roundtrip_bytes.ckpt");
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.seed, -3);
        assert_eq!(loaded.steps_done, 7);
        assert_eq!(loaded.tensors, sample().tensors);
        let path2 = path.with_file_name("roundtrip_bytes2.ckpt");
        loaded.save(&path2).unwrap();
        let second = std::fs::read(&path2).unwrap();
        assert_eq!(first, second, "resave changed the bytes");
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_file(&path2).unwrap();
    }

    #[test]
    fn bad_magic_is_rejected_with_cause() {
        let (path, bytes) = write_sample("corrupt_magic.ckpt");
        // valid JSON header, wrong magic, payload intact
        let nl = bytes.iter().position(|&b| b == b'\n').unwrap();
        let mut forged =
            b"{\"magic\":\"TLORA-CKPT-0\",\"lens\":[4,1],\
              \"seed\":-3,\"steps_done\":7,\"variant\":\"small\"}"
                .to_vec();
        forged.extend_from_slice(&bytes[nl..]);
        std::fs::write(&path, &forged).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncated_payload_is_rejected_with_cause() {
        let (path, bytes) = write_sample("corrupt_trunc.ckpt");
        // drop the final byte of the last tensor
        std::fs::write(&path, &bytes[..bytes.len() - 1]).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn trailing_bytes_are_rejected_with_cause() {
        let (path, mut bytes) = write_sample("corrupt_trail.ckpt");
        // an extra word after the declared payload: a stale partial
        // write or a lens/payload mismatch — never silently accepted
        bytes.extend_from_slice(&[0u8; 4]);
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("trailing bytes"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }
}
