//! The event-driven simulation loop.
//!
//! Instead of ticking a fixed 60 s horizon, the engine advances
//! straight to the next event ([`super::events`]): job arrivals, exact
//! completions derived from current step rates, and reschedule points
//! that bound how long a schedule may go unexamined. Every event
//! triggers one *scheduling round* — release, dissolve, admit,
//! dispatch (via [`PolicyHooks`]), elastic absorption, group install,
//! completion-event refresh — which is the paper's online reactive
//! scheduler (§3.4: regroup on arrivals/completions, reclaim resources
//! elastically).
//!
//! Reschedule points are scheduled only under *pressure*: queued jobs
//! waiting for capacity, or AIMD controllers still adapting. A quiet
//! cluster (empty queue, settled controllers) provably produces the
//! same dispatch outcome every round, so the engine jumps straight to
//! the next arrival/completion — this is where sparse low-arrival-rate
//! sweeps win both iterations and predictor probes over the old
//! per-horizon loop ([`EngineOptions::legacy_tick`] upper-bounds the
//! old cadence for comparison).

use std::collections::HashMap;

use super::events::{Event, EventKind, EventQueue};
use super::observer::{
    CompletionObserver, EvictCause, FaultObserver, GroupingObserver,
    RoundStats, SimObserver, SlowdownObserver, TimelineObserver,
};
use super::state::{Eviction, JobState, SimState};
use super::SimResult;
use crate::baselines::hooks_for;
use crate::config::ExperimentConfig;
use crate::model::arch::{arch_by_name, LoraSpec};
use crate::model::cost::restore_time_s;
use crate::planner::PlanOptions;
use crate::scheduler::predictor::Predictor;
use crate::scheduler::PolicyHooks;
use crate::util::stats::Summary;
use crate::workload::faults::{
    FaultKind, NodeFaultModel, PreemptionModel, ScriptedFault,
};
use crate::workload::{classify, JobSpec};

/// Engine knobs that are not experiment configuration.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Approximate the legacy fixed-horizon loop's cadence *from
    /// above*: force a scheduling round at every multiple of
    /// `scheduler.horizon_s` regardless of pressure, on top of the
    /// reactive arrival/completion rounds (which the old loop did not
    /// run — so this mode's round/probe counts upper-bound the old
    /// loop's grid count but are not a bit-exact replay of it; AIMD
    /// observation order also differs). Kept for cadence benchmarking
    /// and the engine-vs-loop regression tests; real runs leave this
    /// off.
    pub legacy_tick: bool,
    /// AIMD observation count after which a group's controller is
    /// considered settled and stops forcing periodic reschedule points
    /// (the controller keeps adapting at arrival/completion rounds).
    pub aimd_settle_obs: u64,
    /// Deterministic injected faults on top of (or instead of) the
    /// seeded `config::FaultConfig` streams — pinned scenarios like
    /// "kill node 0 at t=100" (`workload::faults::ScriptedFault`).
    pub fault_script: Vec<ScriptedFault>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            legacy_tick: false,
            aimd_settle_obs: 256,
            fault_script: vec![],
        }
    }
}

/// Built-in metric observers; `SimResult` is assembled from these (and
/// any extra observers the caller registered see the same stream).
struct ObserverSet {
    timeline: TimelineObserver,
    completion: CompletionObserver,
    grouping: GroupingObserver,
    slowdown: SlowdownObserver,
    faults: FaultObserver,
}

/// Fan one observer callback out to every built-in plus the caller's
/// extras. Adding a built-in observer means touching this macro once,
/// not every forwarding method.
macro_rules! fan_out {
    ($set:ident, $extra:ident, $hook:ident($($arg:expr),*)) => {{
        $set.timeline.$hook($($arg),*);
        $set.completion.$hook($($arg),*);
        $set.grouping.$hook($($arg),*);
        $set.slowdown.$hook($($arg),*);
        $set.faults.$hook($($arg),*);
        for o in $extra.iter_mut() {
            o.$hook($($arg),*);
        }
    }};
}

impl ObserverSet {
    fn admit(
        &mut self,
        t: f64,
        job: &JobState,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_admit(t, job));
    }

    fn round(
        &mut self,
        stats: &RoundStats,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_round(stats));
    }

    fn complete(
        &mut self,
        t: f64,
        job: &JobState,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_complete(t, job));
    }

    fn node_failure(
        &mut self,
        t: f64,
        node: usize,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_node_failure(t, node));
    }

    fn node_recovery(
        &mut self,
        t: f64,
        node: usize,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_node_recovery(t, node));
    }

    fn evict(
        &mut self,
        t: f64,
        job: &JobState,
        cause: EvictCause,
        ev: &Eviction,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(
            self,
            extra,
            on_evict(t, job, cause, ev.lost_s, ev.penalty_s)
        );
    }

    fn finish(
        &mut self,
        t_end: f64,
        jobs: &[&JobState],
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_finish(t_end, jobs));
    }
}

/// Per-job checkpoint-restore penalty (seconds), from the adapter-only
/// checkpoint size model: fixed overhead + `train_state_bytes` read at
/// the configured bandwidth. An unknown backbone restores at the bare
/// overhead.
fn restore_penalties(
    cfg: &ExperimentConfig,
    jobs: &[JobSpec],
) -> HashMap<u64, f64> {
    jobs.iter()
        .map(|j| {
            let p = match arch_by_name(&j.base_model) {
                Some(arch) => restore_time_s(
                    &arch,
                    &LoraSpec::new(j.rank),
                    cfg.faults.restore_overhead_s,
                    cfg.faults.ckpt_read_bw,
                ),
                None => cfg.faults.restore_overhead_s,
            };
            (j.id, p)
        })
        .collect()
}

/// Origin tag for exogenous fault events, carried in the (otherwise
/// unused) `epoch` field: model-originated events chain the next draw
/// from their seeded stream when handled; scripted events (epoch 0)
/// never chain, so mixing a script into a faulted config cannot
/// multiply the stream rate or shift the per-node draw sequences.
const FAULT_MODEL_ORIGIN: u64 = 1;

/// The seeded fault sources driving the engine's exogenous events.
struct FaultDriver {
    /// per-node MTBF/MTTR streams (None: node failures disabled)
    nodes: Option<NodeFaultModel>,
    /// Poisson preemption stream (None: preemptions disabled)
    preempt: Option<PreemptionModel>,
    /// per-job restore penalty in seconds
    penalties: HashMap<u64, f64>,
}

impl FaultDriver {
    fn new(cfg: &ExperimentConfig, jobs: &[JobSpec]) -> FaultDriver {
        let f = &cfg.faults;
        let nodes = if f.mtbf_s > 0.0 {
            Some(NodeFaultModel::new(
                f.mtbf_s,
                f.mttr_s,
                cfg.cluster.n_nodes,
                cfg.seed,
            ))
        } else {
            None
        };
        let preempt = if f.preempt_rate > 0.0 && !jobs.is_empty() {
            Some(PreemptionModel::new(
                f.preempt_rate,
                jobs.iter().map(|j| j.id).collect(),
                cfg.seed,
            ))
        } else {
            None
        };
        FaultDriver {
            nodes,
            preempt,
            penalties: restore_penalties(cfg, jobs),
        }
    }
}

/// The event-driven simulator.
pub struct Engine<'a> {
    cfg: &'a ExperimentConfig,
    opts: EngineOptions,
    hooks: Box<dyn PolicyHooks>,
    predictor: Predictor,
    state: SimState,
    events: EventQueue,
    obs: ObserverSet,
    faults: FaultDriver,
    epoch: u64,
    sched_rounds: u64,
    events_processed: u64,
    arrivals_pending: usize,
    n_jobs: usize,
    total_gpus: f64,
    t_max: f64,
}

impl<'a> Engine<'a> {
    pub fn new(
        cfg: &'a ExperimentConfig,
        jobs: Vec<JobSpec>,
        opts: EngineOptions,
    ) -> Engine<'a> {
        let plan_opts = PlanOptions {
            fused_kernel: cfg.policy.uses_kernel_fuser(),
            // AIMD drives n online; None would use the oracle.
            n_nano: Some(cfg.aimd.n0),
            n_nano_max: cfg.aimd.n_max,
        };
        let size_classes: HashMap<_, _> =
            classify(&jobs).into_iter().collect();
        // safety valve: generous upper bound on simulated time
        let t_max = (jobs
            .iter()
            .map(|j| j.submit_time)
            .fold(0.0f64, f64::max)
            + 1.0)
            * 50.0
            + 1e7;
        let mut events = EventQueue::new();
        for j in &jobs {
            events.push(Event {
                time: j.submit_time,
                kind: EventKind::Arrival,
                job_id: j.id,
                epoch: 0,
            });
        }
        let mut faults = FaultDriver::new(cfg, &jobs);
        // seed the exogenous fault streams: one pending failure per
        // node, one pending preemption; each handled event chains the
        // next draw from its own stream
        if let Some(m) = &mut faults.nodes {
            for node in 0..m.n_nodes() {
                events.push(Event {
                    time: m.uptime(node),
                    kind: EventKind::NodeFailure,
                    job_id: node as u64,
                    epoch: FAULT_MODEL_ORIGIN,
                });
            }
        }
        if let Some(p) = &mut faults.preempt {
            let (dt, target) = p.next();
            events.push(Event {
                time: dt,
                kind: EventKind::Preemption,
                job_id: target,
                epoch: FAULT_MODEL_ORIGIN,
            });
        }
        // deterministic injected faults (pinned scenarios)
        for f in &opts.fault_script {
            let kind = match f.kind {
                FaultKind::NodeFailure => EventKind::NodeFailure,
                FaultKind::NodeRecovery => EventKind::NodeRecovery,
                FaultKind::Preemption => EventKind::Preemption,
            };
            if kind != EventKind::Preemption {
                // fail loudly on a bad script instead of an opaque
                // slice-index panic inside the allocator at fire time
                // (preemption targets may name unknown jobs: no-op)
                assert!(
                    (f.target as usize) < cfg.cluster.n_nodes,
                    "fault_script entry at t={} targets node {} but \
                     the cluster has {} nodes",
                    f.time,
                    f.target,
                    cfg.cluster.n_nodes
                );
            }
            events.push(Event {
                time: f.time,
                kind,
                job_id: f.target,
                epoch: 0,
            });
        }
        let n_jobs = jobs.len();
        Engine {
            predictor: Predictor::new(cfg.cluster.clone(), plan_opts),
            state: SimState::new(cfg, &jobs),
            events,
            obs: ObserverSet {
                timeline: TimelineObserver::default(),
                completion: CompletionObserver::default(),
                grouping: GroupingObserver::new(size_classes),
                slowdown: SlowdownObserver::default(),
                faults: FaultObserver::new(cfg.faults.slo_factor),
            },
            faults,
            epoch: 0,
            sched_rounds: 0,
            events_processed: 0,
            arrivals_pending: n_jobs,
            n_jobs,
            total_gpus: cfg.cluster.total_gpus() as f64,
            t_max,
            cfg,
            opts,
            hooks: hooks_for(cfg.policy),
        }
    }

    /// Is the event still meaningful? Exogenous events (arrivals,
    /// faults) always are; completion and reschedule events go stale
    /// when a later round re-derived step rates (and re-issued events)
    /// under a newer epoch ([`Event::is_stale`]).
    fn is_valid(&self, ev: &Event) -> bool {
        !ev.is_stale(self.epoch)
    }

    fn pop_next_valid(&mut self) -> Option<Event> {
        while let Some(ev) = self.events.pop() {
            if self.is_valid(&ev) {
                return Some(ev);
            }
        }
        None
    }

    /// Pop the next valid event iff it shares timestamp `t` — events at
    /// one instant are batched into a single scheduling round.
    fn pop_valid_at(&mut self, t: f64) -> Option<Event> {
        loop {
            let ev = *self.events.peek()?;
            if !self.is_valid(&ev) {
                self.events.pop();
                continue;
            }
            if ev.time == t {
                self.events.pop();
                return Some(ev);
            }
            return None;
        }
    }

    /// Any running AIMD controller still warming up? While one is, the
    /// schedule keeps changing between events and periodic reschedule
    /// points stay on.
    fn aimd_pressure(&self) -> bool {
        self.state.running.iter().any(|g| {
            g.aimd
                .as_ref()
                .map_or(false, |c| {
                    c.adjustments() < self.opts.aimd_settle_obs
                })
        })
    }

    /// A node died at `t`: evict touched groups (restore penalties
    /// charged per job), notify observers, and — for model-originated
    /// failures — chain the repair from the node's own MTTR stream.
    /// (A scripted failure with no matching scripted recovery and no
    /// active MTBF model leaves the node down for good.)
    fn apply_node_failure(
        &mut self,
        node: usize,
        from_model: bool,
        t: f64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        let evs =
            self.state.fail_node(node, t, &self.faults.penalties);
        self.obs.node_failure(t, node, extra);
        for e in &evs {
            self.obs.evict(
                t,
                &self.state.states[&e.job_id],
                EvictCause::NodeFailure,
                e,
                extra,
            );
        }
        if from_model {
            if let Some(m) = &mut self.faults.nodes {
                self.events.push(Event {
                    time: t + m.downtime(node),
                    kind: EventKind::NodeRecovery,
                    job_id: node as u64,
                    epoch: FAULT_MODEL_ORIGIN,
                });
            }
        }
    }

    /// A node came back at `t`; model-originated recoveries chain the
    /// node's next failure from its MTBF stream.
    fn apply_node_recovery(
        &mut self,
        node: usize,
        from_model: bool,
        t: f64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        self.state.recover_node(node);
        self.obs.node_recovery(t, node, extra);
        if from_model {
            if let Some(m) = &mut self.faults.nodes {
                self.events.push(Event {
                    time: t + m.uptime(node),
                    kind: EventKind::NodeFailure,
                    job_id: node as u64,
                    epoch: FAULT_MODEL_ORIGIN,
                });
            }
        }
    }

    /// Job `id` is exogenously preempted at `t` (no-op unless placed);
    /// model-originated preemptions chain the next Poisson draw.
    fn apply_preemption(
        &mut self,
        id: u64,
        from_model: bool,
        t: f64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        if let Some(e) =
            self.state.preempt(id, t, &self.faults.penalties)
        {
            self.obs.evict(
                t,
                &self.state.states[&id],
                EvictCause::Preemption,
                &e,
                extra,
            );
        }
        if from_model {
            if let Some(p) = &mut self.faults.preempt {
                let (dt, target) = p.next();
                self.events.push(Event {
                    time: t + dt,
                    kind: EventKind::Preemption,
                    job_id: target,
                    epoch: FAULT_MODEL_ORIGIN,
                });
            }
        }
    }

    /// One scheduling round at time `t`. Mirrors the legacy loop's
    /// steps but runs reactively: release → dissolve → admit →
    /// dispatch (policy) → elastic absorption (policy) → install →
    /// re-derive completion events → bound the next round.
    fn round(&mut self, t: f64, extra: &mut [&mut dyn SimObserver]) {
        self.epoch += 1;
        self.sched_rounds += 1;

        self.state.release_completed();
        self.state.requeue_shared();
        let newly = self.state.admit_queued(
            self.cfg.max_concurrent_jobs,
            &mut self.predictor,
            t,
        );
        for id in newly {
            self.obs.admit(t, &self.state.states[&id], extra);
        }

        let candidates =
            self.state.build_candidates(&mut self.predictor, t);
        let outcome = self.hooks.dispatch(
            candidates,
            &mut self.predictor,
            &self.cfg.scheduler,
        );
        let mut groups = outcome.groups;

        let absorbed = self.state.absorb_queued(
            &mut groups,
            self.hooks.as_ref(),
            &mut self.predictor,
            &self.cfg.scheduler,
            self.cfg.max_concurrent_jobs,
            t,
        );
        for id in absorbed {
            self.obs.admit(t, &self.state.states[&id], extra);
        }

        self.state.install_groups(
            groups,
            self.hooks.aimd_enabled(),
            self.cfg,
        );

        // exact completion events from the current step rates
        for g in &self.state.running {
            for id in &g.job_ids {
                let st = &self.state.states[id];
                let remaining = (st.spec.total_steps as f64
                    - st.steps_done)
                    .max(0.0);
                self.events.push(Event {
                    time: t + remaining * g.step_time,
                    kind: EventKind::Completion,
                    job_id: *id,
                    epoch: self.epoch,
                });
            }
        }

        // bound the interval until the next round
        let h = self.cfg.scheduler.horizon_s;
        if self.opts.legacy_tick {
            self.events.push(Event {
                time: (t / h).floor() * h + h,
                kind: EventKind::ReschedulePoint,
                job_id: 0,
                epoch: self.epoch,
            });
        } else {
            // queued work can only be retried by a future round; a job
            // that cannot even be placed on a fully idle cluster with
            // no arrivals left is unsatisfiable — no point ticking
            // until t_max for it (it is reported in incomplete_jobs).
            // Jobs inside their checkpoint-restore window are excluded:
            // they get an exact wake-up below instead of periodic ticks
            let unblocked_queued = self
                .state
                .queue
                .iter()
                .any(|id| self.state.states[id].restart_at <= t);
            let queue_pressure = unblocked_queued
                && !(self.state.running.is_empty()
                    && self.arrivals_pending == 0);
            if queue_pressure || self.aimd_pressure() {
                self.events.push(Event {
                    time: t + h,
                    kind: EventKind::ReschedulePoint,
                    job_id: 0,
                    epoch: self.epoch,
                });
            }
        }

        // evicted jobs waiting out their restore window: wake exactly
        // when the earliest one becomes runnable (re-derived each
        // round, so staleness handles superseded wake-ups)
        let mut wake: Option<f64> = None;
        for id in &self.state.queue {
            let ra = self.state.states[id].restart_at;
            if ra > t {
                wake = Some(wake.map_or(ra, |w: f64| w.min(ra)));
            }
        }
        if let Some(w) = wake {
            self.events.push(Event {
                time: w,
                kind: EventKind::ReschedulePoint,
                job_id: 0,
                epoch: self.epoch,
            });
        }

        let stats = self.round_stats(t);
        self.obs.round(&stats, extra);
    }

    fn round_stats(&self, t: f64) -> RoundStats {
        let mut inst = 0.0;
        let mut busy = 0.0;
        let mut n_running = 0usize;
        for g in &self.state.running {
            let batch: f64 = g
                .job_ids
                .iter()
                .map(|id| {
                    self.state.states[id].spec.batch_size as f64
                })
                .sum();
            inst += batch / g.step_time;
            busy += g.compute_util * g.alloc.n_gpus() as f64;
            n_running += g.job_ids.len();
        }
        RoundStats {
            t,
            inst_throughput: inst,
            busy_gpus: busy,
            total_gpus: self.total_gpus,
            n_groups: self.state.running.len(),
            n_running,
            n_queued: self.state.queue.len(),
        }
    }

    /// Run to completion (or starvation / `t_max`) and assemble the
    /// result from the observers.
    pub fn run(
        mut self,
        extra: &mut [&mut dyn SimObserver],
    ) -> SimResult {
        // round 0 at t=0 mirrors the legacy loop's first horizon:
        // admit anything submitted at the trace origin (scripted
        // faults at t=0 apply before the first dispatch; preemptions
        // at t=0 are no-ops — nothing is placed yet)
        while let Some(ev) = self.pop_valid_at(0.0) {
            self.events_processed += 1;
            let from_model = ev.epoch == FAULT_MODEL_ORIGIN;
            match ev.kind {
                EventKind::Arrival => {
                    self.arrivals_pending -= 1;
                    self.state.queue.push(ev.job_id);
                }
                EventKind::NodeFailure => {
                    self.apply_node_failure(
                        ev.job_id as usize,
                        from_model,
                        0.0,
                        extra,
                    );
                }
                EventKind::NodeRecovery => {
                    self.apply_node_recovery(
                        ev.job_id as usize,
                        from_model,
                        0.0,
                        extra,
                    );
                }
                EventKind::Preemption => {
                    self.apply_preemption(
                        ev.job_id,
                        from_model,
                        0.0,
                        extra,
                    );
                }
                EventKind::Completion
                | EventKind::ReschedulePoint => {}
            }
        }
        self.round(0.0, extra);

        while self.state.completed < self.n_jobs {
            let Some(first) = self.pop_next_valid() else {
                // no events left but jobs incomplete: unsatisfiable
                // jobs (e.g. wanting more GPUs than the cluster has) —
                // surfaced via SimResult::incomplete_jobs
                break;
            };
            let t = first.time;
            if t > self.t_max {
                break;
            }
            self.state.advance_to(t);
            let mut arrivals = vec![];
            let mut completions = vec![];
            let mut failures = vec![];
            let mut recoveries = vec![];
            let mut preemptions = vec![];
            let mut batch = vec![first];
            while let Some(ev) = self.pop_valid_at(t) {
                batch.push(ev);
            }
            for ev in batch {
                self.events_processed += 1;
                match ev.kind {
                    EventKind::Arrival => {
                        self.arrivals_pending -= 1;
                        arrivals.push(ev.job_id);
                    }
                    EventKind::Completion => {
                        completions.push(ev.job_id);
                    }
                    EventKind::NodeFailure => {
                        failures.push((
                            ev.job_id as usize,
                            ev.epoch == FAULT_MODEL_ORIGIN,
                        ));
                    }
                    EventKind::NodeRecovery => {
                        recoveries.push((
                            ev.job_id as usize,
                            ev.epoch == FAULT_MODEL_ORIGIN,
                        ));
                    }
                    EventKind::Preemption => {
                        preemptions.push((
                            ev.job_id,
                            ev.epoch == FAULT_MODEL_ORIGIN,
                        ));
                    }
                    EventKind::ReschedulePoint => {}
                }
            }
            for id in arrivals {
                self.state.queue.push(id);
            }
            // completions first (rank order): a final step landing at
            // the failure instant still counts as finished
            for id in completions {
                if self.state.complete(id, t) {
                    self.obs.complete(
                        t,
                        &self.state.states[&id],
                        extra,
                    );
                }
            }
            for (node, from_model) in failures {
                self.apply_node_failure(node, from_model, t, extra);
            }
            for (node, from_model) in recoveries {
                self.apply_node_recovery(node, from_model, t, extra);
            }
            for (id, from_model) in preemptions {
                self.apply_preemption(id, from_model, t, extra);
            }
            self.round(t, extra);
        }

        let makespan = self.state.now;
        {
            let jobs = self.state.sorted_states();
            self.obs.finish(makespan, &jobs, extra);
        }

        let jct = std::mem::take(&mut self.obs.completion.jct);
        let jvals: Vec<f64> =
            jct.iter().map(|&(_, v)| v).collect();
        let summary = Summary::of(&jvals);
        let (avg_throughput, avg_gpu_util) = self
            .obs
            .timeline
            .windowed_averages(self.cfg.scheduler.horizon_s);
        let (avg_throughput_full, avg_gpu_util_full) =
            self.obs.timeline.full_averages();

        SimResult {
            policy: self.cfg.policy,
            mean_jct: summary.mean,
            p99_jct: summary.p99,
            jct,
            avg_throughput,
            avg_throughput_full,
            throughput_timeline: std::mem::take(
                &mut self.obs.timeline.throughput_timeline,
            ),
            avg_gpu_util,
            avg_gpu_util_full,
            util_timeline: std::mem::take(
                &mut self.obs.timeline.util_timeline,
            ),
            makespan,
            grouping_ratio: std::mem::take(
                &mut self.obs.grouping.grouping_ratio,
            ),
            scheduler_probes: self.predictor.probes,
            sched_rounds: self.sched_rounds,
            events: self.events_processed,
            incomplete_jobs: std::mem::take(
                &mut self.obs.completion.incomplete,
            ),
            mean_slowdown: self.obs.slowdown.mean_slowdown,
            node_failures: self.obs.faults.node_failures,
            preemptions: self.obs.faults.preemptions,
            restarts: self.obs.faults.restarts,
            lost_step_time_s: self.obs.faults.lost_step_time_s,
            restore_delay_s: self.obs.faults.restore_delay_s,
            goodput: self.obs.faults.goodput,
            slo_attainment: self.obs.faults.slo_attainment,
        }
    }
}
