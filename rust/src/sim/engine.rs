//! The event-driven simulation loop.
//!
//! Instead of ticking a fixed 60 s horizon, the engine advances
//! straight to the next event ([`super::events`]): job arrivals, exact
//! completions derived from current step rates, and reschedule points
//! that bound how long a schedule may go unexamined. Every event
//! triggers one *scheduling round* — release, dissolve, admit,
//! dispatch (via [`PolicyHooks`]), elastic absorption, group install,
//! completion-event refresh — which is the paper's online reactive
//! scheduler (§3.4: regroup on arrivals/completions, reclaim resources
//! elastically).
//!
//! Reschedule points are scheduled only under *pressure*: queued jobs
//! waiting for capacity, or AIMD controllers still adapting. A quiet
//! cluster (empty queue, settled controllers) provably produces the
//! same dispatch outcome every round, so the engine jumps straight to
//! the next arrival/completion — this is where sparse low-arrival-rate
//! sweeps win both iterations and predictor probes over the old
//! per-horizon loop ([`EngineOptions::legacy_tick`] upper-bounds the
//! old cadence for comparison).

use std::collections::HashMap;

use super::events::{Event, EventKind, EventQueue};
use super::observer::{
    CompletionObserver, EvictCause, FaultObserver, GroupingObserver,
    RoundStats, ShrinkObserver, SimObserver, SlowdownObserver,
    StragglerObserver, TimelineObserver,
};
use super::state::{Eviction, JobState, SimState};
use super::SimResult;
use crate::baselines::hooks_for;
use crate::config::ExperimentConfig;
use crate::model::arch::{arch_by_name, LoraSpec};
use crate::model::cost::restore_time_s;
use crate::planner::PlanOptions;
use crate::scheduler::predictor::Predictor;
use crate::scheduler::{NodeSpeedEstimator, NodeView, PolicyHooks};
use crate::util::stats::{Summary, TimeWeighted};
use crate::workload::faults::{
    FaultKind, GpuFaultKind, GpuFaultModel, NodeFaultModel,
    PreemptionModel, ScriptedFault, ScriptedGpuFault,
    ScriptedStraggler, StragglerModel,
};
use crate::workload::{classify, JobSpec};

/// Engine knobs that are not experiment configuration.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Approximate the legacy fixed-horizon loop's cadence *from
    /// above*: force a scheduling round at every multiple of
    /// `scheduler.horizon_s` regardless of pressure, on top of the
    /// reactive arrival/completion rounds (which the old loop did not
    /// run — so this mode's round/probe counts upper-bound the old
    /// loop's grid count but are not a bit-exact replay of it; AIMD
    /// observation order also differs). Kept for cadence benchmarking
    /// and the engine-vs-loop regression tests; real runs leave this
    /// off.
    pub legacy_tick: bool,
    /// AIMD observation count after which a group's controller is
    /// considered settled and stops forcing periodic reschedule points
    /// (the controller keeps adapting at arrival/completion rounds).
    pub aimd_settle_obs: u64,
    /// Deterministic injected faults on top of (or instead of) the
    /// seeded `config::FaultConfig` streams — pinned scenarios like
    /// "kill node 0 at t=100" (`workload::faults::ScriptedFault`).
    pub fault_script: Vec<ScriptedFault>,
    /// Deterministic injected straggler transitions on top of (or
    /// instead of) the seeded `config::StragglerConfig` model —
    /// pinned scenarios like "node 0 runs at 0.25× from t=100"
    /// (`workload::faults::ScriptedStraggler`; `speed >= 1` restores).
    pub straggler_script: Vec<ScriptedStraggler>,
    /// Deterministic injected *single-GPU* faults on top of (or
    /// instead of) the seeded `gpu_mtbf_s` streams — pinned scenarios
    /// like "GPU 3 of node 0 dies at t=100"
    /// (`workload::faults::ScriptedGpuFault`).
    pub gpu_fault_script: Vec<ScriptedGpuFault>,
    /// Enable the predictor's shape-level plan cache (default). `false`
    /// is *cold mode*: every plan-level consult runs the planner — the
    /// cached-vs-cold differential in `tests/integration_perf.rs`
    /// pins that the cache never changes a single output bit.
    pub plan_shape_cache: bool,
    /// Re-issue every running job's completion event every round (the
    /// pre-dirty-set behavior, re-pushing the *anchored* instants so
    /// the valid-event stream is comparable bit-for-bit). Default off:
    /// only *dirty* jobs — rate bits changed, progress continuity
    /// broken, or newly running — get their event re-derived. The
    /// dirty-vs-global differential proves per-job epochs discard
    /// exactly the events a global bump would have.
    pub global_reissue: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            legacy_tick: false,
            aimd_settle_obs: 256,
            fault_script: vec![],
            straggler_script: vec![],
            gpu_fault_script: vec![],
            plan_shape_cache: true,
            global_reissue: false,
        }
    }
}

/// Built-in metric observers; `SimResult` is assembled from these (and
/// any extra observers the caller registered see the same stream).
struct ObserverSet {
    timeline: TimelineObserver,
    completion: CompletionObserver,
    grouping: GroupingObserver,
    slowdown: SlowdownObserver,
    faults: FaultObserver,
    stragglers: StragglerObserver,
    shrink: ShrinkObserver,
}

/// Fan one observer callback out to every built-in plus the caller's
/// extras. Adding a built-in observer means touching this macro once,
/// not every forwarding method.
macro_rules! fan_out {
    ($set:ident, $extra:ident, $hook:ident($($arg:expr),*)) => {{
        $set.timeline.$hook($($arg),*);
        $set.completion.$hook($($arg),*);
        $set.grouping.$hook($($arg),*);
        $set.slowdown.$hook($($arg),*);
        $set.faults.$hook($($arg),*);
        $set.stragglers.$hook($($arg),*);
        $set.shrink.$hook($($arg),*);
        for o in $extra.iter_mut() {
            o.$hook($($arg),*);
        }
    }};
}

impl ObserverSet {
    fn admit(
        &mut self,
        t: f64,
        job: &JobState,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_admit(t, job));
    }

    fn round(
        &mut self,
        stats: &RoundStats,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_round(stats));
    }

    fn complete(
        &mut self,
        t: f64,
        job: &JobState,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_complete(t, job));
    }

    fn node_failure(
        &mut self,
        t: f64,
        node: usize,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_node_failure(t, node));
    }

    fn node_recovery(
        &mut self,
        t: f64,
        node: usize,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_node_recovery(t, node));
    }

    fn gpu_failure(
        &mut self,
        t: f64,
        node: usize,
        gpu: usize,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_gpu_failure(t, node, gpu));
    }

    fn gpu_recovery(
        &mut self,
        t: f64,
        node: usize,
        gpu: usize,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_gpu_recovery(t, node, gpu));
    }

    fn node_degraded(
        &mut self,
        t: f64,
        node: usize,
        speed: f64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_node_degraded(t, node, speed));
    }

    fn node_restored(
        &mut self,
        t: f64,
        node: usize,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_node_restored(t, node));
    }

    fn evict(
        &mut self,
        t: f64,
        job: &JobState,
        cause: EvictCause,
        ev: &Eviction,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(
            self,
            extra,
            on_evict(t, job, cause, ev.lost_s, ev.penalty_s)
        );
    }

    fn shrink(
        &mut self,
        t: f64,
        jobs: &[u64],
        groups: u64,
        rollback_lost_s: f64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(
            self,
            extra,
            on_shrink(t, jobs, groups, rollback_lost_s)
        );
    }

    fn regrow(
        &mut self,
        t: f64,
        job: u64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_regrow(t, job));
    }

    fn finish(
        &mut self,
        t_end: f64,
        jobs: &[&JobState],
        extra: &mut [&mut dyn SimObserver],
    ) {
        fan_out!(self, extra, on_finish(t_end, jobs));
    }
}

/// Per-job checkpoint-restore penalty (seconds), from the adapter-only
/// checkpoint size model: fixed overhead + `train_state_bytes` read at
/// the configured bandwidth. An unknown backbone restores at the bare
/// overhead.
fn restore_penalties(
    cfg: &ExperimentConfig,
    jobs: &[JobSpec],
) -> HashMap<u64, f64> {
    jobs.iter()
        .map(|j| {
            let p = match arch_by_name(&j.base_model) {
                Some(arch) => restore_time_s(
                    &arch,
                    &LoraSpec::new(j.rank),
                    cfg.faults.restore_overhead_s,
                    cfg.faults.ckpt_read_bw,
                ),
                None => cfg.faults.restore_overhead_s,
            };
            (j.id, p)
        })
        .collect()
}

/// Per-hardware-tier utilization accounting for mixed fleets. `None`
/// on uniform-reference clusters: constructing it would add float work
/// to the homogeneous path, which must stay byte-identical to pre-tier
/// builds (`SimResult::tier_util` is simply empty there).
struct TierUtilTracker {
    /// GPU count per tier (the busy-fraction denominator); a tier with
    /// no mapped nodes keeps count 0 and reports utilization 0
    gpus: Vec<f64>,
    /// time-weighted busy fraction per tier
    acc: Vec<TimeWeighted>,
}

/// Topology-radius accounting for non-flat clusters: how many racks
/// each running gang spans, sampled once per gang per scheduling
/// round. `None` on flat topologies — constructing it would add work
/// to the flat path, which must stay byte-identical to pre-topology
/// builds (`SimResult::rack_span_*` simply report 0 there).
struct RackSpanTracker {
    span_sum: u64,
    span_obs: u64,
    span_max: u64,
}

/// Origin tag for exogenous fault events, carried in the (otherwise
/// unused) `epoch` field: model-originated events chain the next draw
/// from their seeded stream when handled; scripted events (epoch 0)
/// never chain, so mixing a script into a faulted config cannot
/// multiply the stream rate or shift the per-node draw sequences.
const FAULT_MODEL_ORIGIN: u64 = 1;

/// The seeded fault sources driving the engine's exogenous events.
struct FaultDriver {
    /// per-node MTBF/MTTR streams (None: node failures disabled)
    nodes: Option<NodeFaultModel>,
    /// per-GPU MTBF/MTTR streams (None: single-GPU faults disabled)
    gpus: Option<GpuFaultModel>,
    /// Poisson preemption stream (None: preemptions disabled)
    preempt: Option<PreemptionModel>,
    /// per-job restore penalty in seconds
    penalties: HashMap<u64, f64>,
}

impl FaultDriver {
    fn new(cfg: &ExperimentConfig, jobs: &[JobSpec]) -> FaultDriver {
        let f = &cfg.faults;
        let nodes = if f.mtbf_s > 0.0 {
            Some(NodeFaultModel::new(
                f.mtbf_s,
                f.mttr_s,
                cfg.cluster.n_nodes,
                cfg.seed,
            ))
        } else {
            None
        };
        // wear-coupled streams: alpha 0.0 is an *exact* no-op (the
        // per-device draws are bit-identical to the memoryless model),
        // so routing through with_wear unconditionally keeps
        // wear-free configs byte-identical
        let gpus = if f.gpu_mtbf_s > 0.0 {
            Some(GpuFaultModel::with_wear(
                f.gpu_mtbf_s,
                f.gpu_mttr_s,
                cfg.cluster.n_nodes,
                cfg.cluster.gpus_per_node,
                cfg.seed,
                f.gpu_wear_alpha,
            ))
        } else {
            None
        };
        let preempt = if f.preempt_rate > 0.0 && !jobs.is_empty() {
            Some(PreemptionModel::new(
                f.preempt_rate,
                jobs.iter().map(|j| j.id).collect(),
                cfg.seed,
            ))
        } else {
            None
        };
        FaultDriver {
            nodes,
            gpus,
            preempt,
            penalties: restore_penalties(cfg, jobs),
        }
    }
}

/// The seeded straggler source plus the severity side-table for
/// scripted transitions (events carry only the node index; the speed
/// is looked up by `(time, node)` when the event fires).
struct StragglerDriver {
    /// per-node degrade/restore renewal streams (None: seeded
    /// stragglers disabled)
    model: Option<StragglerModel>,
    /// scripted severities keyed by `(time.to_bits(), node)`
    scripted_speed: HashMap<(u64, u64), f64>,
}

impl StragglerDriver {
    fn new(
        cfg: &ExperimentConfig,
        script: &[ScriptedStraggler],
    ) -> StragglerDriver {
        let s = &cfg.stragglers;
        let model = if s.mtbs_s > 0.0 {
            Some(StragglerModel::new(
                s.mtbs_s,
                s.mtts_s,
                s.severity_min,
                s.severity_max,
                cfg.cluster.n_nodes,
                cfg.seed,
            ))
        } else {
            None
        };
        let mut scripted_speed = HashMap::new();
        for e in script {
            assert!(
                (e.node as usize) < cfg.cluster.n_nodes,
                "straggler_script entry at t={} targets node {} but \
                 the cluster has {} nodes",
                e.time,
                e.node,
                cfg.cluster.n_nodes
            );
            assert!(
                e.speed > 0.0,
                "straggler_script entry at t={} has speed {} (a node \
                 at speed 0 is a failure, not a straggler)",
                e.time,
                e.speed
            );
            let prev = scripted_speed
                .insert((e.time.to_bits(), e.node), e.speed);
            assert!(
                prev.is_none(),
                "straggler_script has two entries for node {} at t={}",
                e.node,
                e.time
            );
        }
        StragglerDriver {
            model,
            scripted_speed,
        }
    }
}

/// The event-driven simulator.
pub struct Engine<'a> {
    cfg: &'a ExperimentConfig,
    opts: EngineOptions,
    hooks: Box<dyn PolicyHooks>,
    predictor: Predictor,
    state: SimState,
    events: EventQueue,
    obs: ObserverSet,
    faults: FaultDriver,
    stragglers: StragglerDriver,
    /// per-node slowdown estimator (Some only when straggler sources
    /// exist, detection is on, and the policy consumes the signal —
    /// absent, every code path is the oblivious pre-straggler one)
    estimator: Option<NodeSpeedEstimator>,
    /// last time `observe_speeds` ran (estimator bookkeeping)
    last_obs_t: f64,
    /// graceful degradation active: `faults.shrink` configured *and*
    /// the policy is elastic enough to shrink gangs in place
    /// ([`PolicyHooks::shrinks_in_place`]). False routes every
    /// single-GPU failure through the historic evict path and never
    /// calls the regrow pass — the off state is byte-identical to the
    /// pre-shrink engine.
    shrink_enabled: bool,
    /// per-tier utilization accumulators (mixed fleets only)
    tier_util: Option<TierUtilTracker>,
    /// gang rack-span accounting (non-flat topologies only)
    rack_span: Option<RackSpanTracker>,
    /// scheduling-round counter; stamps (and stales) *reschedule
    /// points* only — completions use the per-job epochs below
    epoch: u64,
    /// per-job completion-event epoch: a Completion event is valid iff
    /// its stamp equals its job's current entry, so re-deriving one
    /// job's completion never discards any other job's live event
    completion_epoch: HashMap<u64, u64>,
    /// live anchored completion per running job: (event time, the
    /// effective step-time bits it was derived under). While the rate
    /// bits are unchanged and progress advanced only by continuous
    /// execution, the anchored instant is exact — no re-derivation
    /// needed (a clean group's completion is invariant across rounds:
    /// t₁ + (rem₀ − (t₁−t₀)/st)·st = t₀ + rem₀·st).
    completion_anchor: HashMap<u64, (f64, u64)>,
    /// jobs whose steps_done jumped discontinuously this round
    /// (eviction rollback) — membership-only set, never iterated, so
    /// HashSet nondeterminism cannot leak into the event stream
    dirty_jobs: std::collections::HashSet<u64>,
    /// stale events discarded on pop (heap-churn diagnostic)
    stale_discards: u64,
    sched_rounds: u64,
    events_processed: u64,
    arrivals_pending: usize,
    n_jobs: usize,
    total_gpus: f64,
    t_max: f64,
}

impl<'a> Engine<'a> {
    pub fn new(
        cfg: &'a ExperimentConfig,
        jobs: Vec<JobSpec>,
        opts: EngineOptions,
    ) -> Engine<'a> {
        let plan_opts = PlanOptions {
            fused_kernel: cfg.policy.uses_kernel_fuser(),
            // AIMD drives n online; None would use the oracle.
            n_nano: Some(cfg.aimd.n0),
            n_nano_max: cfg.aimd.n_max,
        };
        let size_classes: HashMap<_, _> =
            classify(&jobs).into_iter().collect();
        // safety valve: generous upper bound on simulated time
        let t_max = (jobs
            .iter()
            .map(|j| j.submit_time)
            .fold(0.0f64, f64::max)
            + 1.0)
            * 50.0
            + 1e7;
        let mut events = EventQueue::new();
        for j in &jobs {
            events.push(Event {
                time: j.submit_time,
                kind: EventKind::Arrival,
                job_id: j.id,
                epoch: 0,
            });
        }
        let mut faults = FaultDriver::new(cfg, &jobs);
        // seed the exogenous fault streams: one pending failure per
        // node, one pending preemption; each handled event chains the
        // next draw from its own stream
        if let Some(m) = &mut faults.nodes {
            for node in 0..m.n_nodes() {
                events.push(Event {
                    time: m.uptime(node),
                    kind: EventKind::NodeFailure,
                    job_id: node as u64,
                    epoch: FAULT_MODEL_ORIGIN,
                });
            }
        }
        // single-GPU streams: one pending failure per device, in flat
        // index order (node-major) — the order synthesize_gpu_faults
        // pins. Each handled event chains the device's next draw.
        let gpn = cfg.cluster.gpus_per_node;
        if let Some(m) = &mut faults.gpus {
            for node in 0..cfg.cluster.n_nodes {
                for gpu in 0..gpn {
                    events.push(Event {
                        time: m.uptime(node, gpu),
                        kind: EventKind::GpuFailure,
                        job_id: (node * gpn + gpu) as u64,
                        epoch: FAULT_MODEL_ORIGIN,
                    });
                }
            }
        }
        for f in &opts.gpu_fault_script {
            assert!(
                (f.node as usize) < cfg.cluster.n_nodes
                    && (f.gpu as usize) < gpn,
                "gpu_fault_script entry at t={} targets device \
                 ({}, {}) but the cluster is {} nodes x {} GPUs",
                f.time,
                f.node,
                f.gpu,
                cfg.cluster.n_nodes,
                gpn
            );
            events.push(Event {
                time: f.time,
                kind: match f.kind {
                    GpuFaultKind::Failure => EventKind::GpuFailure,
                    GpuFaultKind::Recovery => EventKind::GpuRecovery,
                },
                job_id: f.node * gpn as u64 + f.gpu,
                epoch: 0,
            });
        }
        // correlated domain episodes: synthesized once over the
        // topology's failure domains as epoch-0 scripts reusing the
        // existing NodeFailure/NodeDegraded machinery — no new event
        // kinds. A flat topology has no domains and a zero knob
        // synthesizes nothing, so the flat path stays byte-identical.
        let domains = cfg.cluster.failure_domains();
        let domain_faults = if cfg.faults.domain_mtbf_s > 0.0
            && !domains.is_empty()
        {
            crate::workload::synthesize_domain_faults(
                cfg.faults.domain_mtbf_s,
                cfg.faults.domain_mttr_s,
                &domains,
                cfg.seed,
                t_max,
            )
        } else {
            vec![]
        };
        let domain_stragglers = if cfg.stragglers.domain_mtbs_s > 0.0
            && !domains.is_empty()
        {
            crate::workload::synthesize_domain_stragglers(
                cfg.stragglers.domain_mtbs_s,
                cfg.stragglers.domain_mtts_s,
                cfg.stragglers.severity_min,
                cfg.stragglers.severity_max,
                &domains,
                cfg.seed,
                t_max,
            )
        } else {
            vec![]
        };
        // straggler sources: one pending degrade per node from the
        // seeded renewal model (severity + restore are drawn when the
        // degrade fires), plus the scripted transitions (user script
        // and synthesized domain episodes alike)
        let mut straggler_script = opts.straggler_script.clone();
        straggler_script.extend(domain_stragglers);
        let mut stragglers =
            StragglerDriver::new(cfg, &straggler_script);
        if let Some(m) = &mut stragglers.model {
            for node in 0..m.n_nodes() {
                events.push(Event {
                    time: m.healthy_span(node),
                    kind: EventKind::NodeDegraded,
                    job_id: node as u64,
                    epoch: FAULT_MODEL_ORIGIN,
                });
            }
        }
        for e in &straggler_script {
            events.push(Event {
                time: e.time,
                kind: if e.speed < 1.0 {
                    EventKind::NodeDegraded
                } else {
                    EventKind::NodeRestored
                },
                job_id: e.node,
                epoch: 0,
            });
        }
        if let Some(p) = &mut faults.preempt {
            let (dt, target) = p.next();
            events.push(Event {
                time: dt,
                kind: EventKind::Preemption,
                job_id: target,
                epoch: FAULT_MODEL_ORIGIN,
            });
        }
        // deterministic injected faults (pinned scenarios), plus the
        // synthesized correlated domain failures
        for f in opts.fault_script.iter().chain(domain_faults.iter()) {
            let kind = match f.kind {
                FaultKind::NodeFailure => EventKind::NodeFailure,
                FaultKind::NodeRecovery => EventKind::NodeRecovery,
                FaultKind::Preemption => EventKind::Preemption,
            };
            if kind != EventKind::Preemption {
                // fail loudly on a bad script instead of an opaque
                // slice-index panic inside the allocator at fire time
                // (preemption targets may name unknown jobs: no-op)
                assert!(
                    (f.target as usize) < cfg.cluster.n_nodes,
                    "fault_script entry at t={} targets node {} but \
                     the cluster has {} nodes",
                    f.time,
                    f.target,
                    cfg.cluster.n_nodes
                );
            }
            events.push(Event {
                time: f.time,
                kind,
                job_id: f.target,
                epoch: 0,
            });
        }
        let n_jobs = jobs.len();
        let hooks = hooks_for(cfg.policy);
        let shrink_enabled =
            cfg.faults.shrink && hooks.shrinks_in_place();
        // the estimator exists only when there is something to detect
        // (seeded model or script), detection is configured on, and
        // the policy actually consumes the signal — otherwise every
        // admission/migration path is the oblivious pre-straggler one
        let straggler_sources = stragglers.model.is_some()
            || !stragglers.scripted_speed.is_empty();
        let estimator = if straggler_sources
            && cfg.stragglers.detect
            && hooks.straggler_aware()
        {
            Some(NodeSpeedEstimator::new(
                cfg.cluster.n_nodes,
                cfg.stragglers.detect_alpha,
            ))
        } else {
            None
        };
        let mut predictor =
            Predictor::new(cfg.cluster.clone(), plan_opts);
        predictor.set_shape_cache(opts.plan_shape_cache);
        let tier_util = if cfg.cluster.is_uniform_reference() {
            None
        } else {
            let mut gpus = vec![0.0; cfg.cluster.tiers.len()];
            for node in 0..cfg.cluster.n_nodes {
                gpus[cfg.cluster.tier_index(node)] +=
                    cfg.cluster.gpus_per_node as f64;
            }
            Some(TierUtilTracker {
                acc: vec![TimeWeighted::default(); gpus.len()],
                gpus,
            })
        };
        let rack_span = if cfg.cluster.topology.is_flat() {
            None
        } else {
            Some(RackSpanTracker {
                span_sum: 0,
                span_obs: 0,
                span_max: 0,
            })
        };
        Engine {
            predictor,
            state: SimState::new(cfg, &jobs),
            events,
            obs: ObserverSet {
                timeline: TimelineObserver::default(),
                completion: CompletionObserver::default(),
                grouping: GroupingObserver::new(size_classes),
                slowdown: SlowdownObserver::default(),
                faults: FaultObserver::new(cfg.faults.slo_factor),
                stragglers: StragglerObserver::new(
                    cfg.cluster.n_nodes,
                ),
                shrink: ShrinkObserver::default(),
            },
            faults,
            stragglers,
            estimator,
            last_obs_t: 0.0,
            shrink_enabled,
            tier_util,
            rack_span,
            epoch: 0,
            completion_epoch: HashMap::new(),
            completion_anchor: HashMap::new(),
            dirty_jobs: std::collections::HashSet::new(),
            stale_discards: 0,
            sched_rounds: 0,
            events_processed: 0,
            arrivals_pending: n_jobs,
            n_jobs,
            total_gpus: cfg.cluster.total_gpus() as f64,
            t_max,
            cfg,
            opts,
            hooks,
        }
    }

    /// Is the event still meaningful? Exogenous events (arrivals,
    /// faults) always are. Completions are valid iff their stamp
    /// matches their job's *per-job* epoch — re-deriving one dirty
    /// job's event leaves every untouched job's live event valid, the
    /// heap-churn win over the old global bump. Reschedule points
    /// keep the global round-epoch semantics ([`Event::is_stale`]).
    fn is_valid(&self, ev: &Event) -> bool {
        match ev.kind {
            EventKind::Completion => self
                .completion_epoch
                .get(&ev.job_id)
                .is_some_and(|&e| e == ev.epoch),
            EventKind::ReschedulePoint => !ev.is_stale(self.epoch),
            _ => true,
        }
    }

    fn pop_next_valid(&mut self) -> Option<Event> {
        while let Some(ev) = self.events.pop() {
            if self.is_valid(&ev) {
                return Some(ev);
            }
            self.stale_discards += 1;
        }
        None
    }

    /// Pop the next valid event iff it shares timestamp `t` — events at
    /// one instant are batched into a single scheduling round.
    fn pop_valid_at(&mut self, t: f64) -> Option<Event> {
        loop {
            let ev = *self.events.peek()?;
            if !self.is_valid(&ev) {
                self.events.pop();
                self.stale_discards += 1;
                continue;
            }
            if ev.time == t {
                self.events.pop();
                return Some(ev);
            }
            return None;
        }
    }

    /// Any running AIMD controller still warming up? While one is, the
    /// schedule keeps changing between events and periodic reschedule
    /// points stay on.
    fn aimd_pressure(&self) -> bool {
        self.state.running.iter().any(|g| {
            g.aimd
                .as_ref()
                .map_or(false, |c| {
                    c.adjustments() < self.opts.aimd_settle_obs
                })
        })
    }

    /// A node died at `t`: evict touched groups (restore penalties
    /// charged per job), notify observers, and — for model-originated
    /// failures — chain the repair from the node's own MTTR stream.
    /// (A scripted failure with no matching scripted recovery and no
    /// active MTBF model leaves the node down for good.)
    fn apply_node_failure(
        &mut self,
        node: usize,
        from_model: bool,
        t: f64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        let evs =
            self.state.fail_node(node, t, &self.faults.penalties);
        self.obs.node_failure(t, node, extra);
        for e in &evs {
            // rollback broke progress continuity: the job's anchored
            // completion (if any) must not survive a same-round
            // re-admission with coincidentally equal rate bits
            self.dirty_jobs.insert(e.job_id);
            self.obs.evict(
                t,
                &self.state.states[&e.job_id],
                EvictCause::NodeFailure,
                e,
                extra,
            );
        }
        if from_model {
            if let Some(m) = &mut self.faults.nodes {
                self.events.push(Event {
                    time: t + m.downtime(node),
                    kind: EventKind::NodeRecovery,
                    job_id: node as u64,
                    epoch: FAULT_MODEL_ORIGIN,
                });
            }
        }
    }

    /// A node came back at `t`; model-originated recoveries chain the
    /// node's next failure from its MTBF stream.
    fn apply_node_recovery(
        &mut self,
        node: usize,
        from_model: bool,
        t: f64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        self.state.recover_node(node);
        self.obs.node_recovery(t, node, extra);
        if from_model {
            if let Some(m) = &mut self.faults.nodes {
                self.events.push(Event {
                    time: t + m.uptime(node),
                    kind: EventKind::NodeFailure,
                    job_id: node as u64,
                    epoch: FAULT_MODEL_ORIGIN,
                });
            }
        }
    }

    /// A single GPU died at `t`: evict only the gangs actually
    /// touching the device (restore penalties charged per job), mask
    /// the hole out of the allocator's free lists, tell the predictor
    /// the node's surviving-GPU count so plan candidates re-price (and
    /// re-key) around the hole, and — for model-originated failures —
    /// chain the repair from the device's own MTTR stream.
    fn apply_gpu_failure(
        &mut self,
        node: usize,
        gpu: usize,
        from_model: bool,
        t: f64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        if self.shrink_enabled {
            // graceful degradation: register the hole with the
            // allocator *and the predictor first* (set_gpu_down is
            // idempotent — shrink_gpu re-asserts it), so the
            // shrunken-width re-plan inside shrink_gpu prices and
            // keys around the hole (the hole-aware
            // `PlanShapeKey::of_with_holes` path), not the healthy
            // node shape
            self.state.allocator.set_gpu_down(node, gpu, true);
            self.predictor.set_node_holes(
                node,
                self.state.allocator.holed_gpus(node) as u32,
            );
            let out = self.state.shrink_gpu(
                node,
                gpu,
                t,
                &self.faults.penalties,
                &mut self.predictor,
            );
            self.obs.gpu_failure(t, node, gpu, extra);
            for e in &out.evictions {
                self.dirty_jobs.insert(e.job_id);
                self.obs.evict(
                    t,
                    &self.state.states[&e.job_id],
                    EvictCause::GpuFailure,
                    e,
                    extra,
                );
            }
            // survivors' progress rolled back discontinuously: their
            // anchored completions must not outlive coincidentally
            // equal rate bits
            for id in &out.shrunk_jobs {
                self.dirty_jobs.insert(*id);
            }
            if !out.shrunk_jobs.is_empty() {
                self.obs.shrink(
                    t,
                    &out.shrunk_jobs,
                    out.groups_shrunk,
                    out.rollback_lost_s,
                    extra,
                );
            }
            if from_model {
                if let Some(m) = &mut self.faults.gpus {
                    self.events.push(Event {
                        time: t + m.downtime(node, gpu),
                        kind: EventKind::GpuRecovery,
                        job_id: (node
                            * self.cfg.cluster.gpus_per_node
                            + gpu)
                            as u64,
                        epoch: FAULT_MODEL_ORIGIN,
                    });
                }
            }
            return;
        }
        let evs =
            self.state.fail_gpu(node, gpu, t, &self.faults.penalties);
        self.obs.gpu_failure(t, node, gpu, extra);
        for e in &evs {
            self.dirty_jobs.insert(e.job_id);
            self.obs.evict(
                t,
                &self.state.states[&e.job_id],
                EvictCause::GpuFailure,
                e,
                extra,
            );
        }
        self.predictor.set_node_holes(
            node,
            self.state.allocator.holed_gpus(node) as u32,
        );
        if from_model {
            if let Some(m) = &mut self.faults.gpus {
                self.events.push(Event {
                    time: t + m.downtime(node, gpu),
                    kind: EventKind::GpuRecovery,
                    job_id: (node * self.cfg.cluster.gpus_per_node
                        + gpu) as u64,
                    epoch: FAULT_MODEL_ORIGIN,
                });
            }
        }
    }

    /// A holed GPU came back at `t`; model-originated recoveries chain
    /// the device's next failure from its MTBF stream.
    fn apply_gpu_recovery(
        &mut self,
        node: usize,
        gpu: usize,
        from_model: bool,
        t: f64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        self.state.recover_gpu(node, gpu);
        self.obs.gpu_recovery(t, node, gpu, extra);
        self.predictor.set_node_holes(
            node,
            self.state.allocator.holed_gpus(node) as u32,
        );
        if from_model {
            if let Some(m) = &mut self.faults.gpus {
                self.events.push(Event {
                    time: t + m.uptime(node, gpu),
                    kind: EventKind::GpuFailure,
                    job_id: (node * self.cfg.cluster.gpus_per_node
                        + gpu) as u64,
                    epoch: FAULT_MODEL_ORIGIN,
                });
            }
        }
    }

    /// A node starts straggling at `t`: model-originated degrades draw
    /// the episode's severity + duration from the node's own stream
    /// (and schedule the matching restore); scripted degrades look the
    /// severity up in the script side-table. Running groups touching
    /// the node are re-priced at this exact instant
    /// ([`SimState::set_node_speed`]); the round that follows
    /// re-derives their completion events under the new epoch.
    fn apply_node_degraded(
        &mut self,
        node: usize,
        from_model: bool,
        t: f64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        let speed = if from_model {
            let m = self
                .stragglers
                .model
                .as_mut()
                .expect("model-origin degrade without a model");
            let (speed, dur) = m.episode(node);
            self.events.push(Event {
                time: t + dur,
                kind: EventKind::NodeRestored,
                job_id: node as u64,
                epoch: FAULT_MODEL_ORIGIN,
            });
            speed
        } else {
            *self
                .stragglers
                .scripted_speed
                .get(&(t.to_bits(), node as u64))
                .expect("scripted degrade without a script entry")
        };
        self.state.set_node_speed(node, speed);
        self.obs.node_degraded(t, node, speed, extra);
    }

    /// A straggling node returns to full speed at `t` (or, for a
    /// scripted entry with `speed >= 1`, to that scripted multiplier);
    /// model-originated restores chain the node's next degrade from
    /// its stream.
    fn apply_node_restored(
        &mut self,
        node: usize,
        from_model: bool,
        t: f64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        let speed = if from_model {
            1.0
        } else {
            *self
                .stragglers
                .scripted_speed
                .get(&(t.to_bits(), node as u64))
                .expect("scripted restore without a script entry")
        };
        self.state.set_node_speed(node, speed);
        self.obs.node_restored(t, node, extra);
        if from_model {
            if let Some(m) = &mut self.stragglers.model {
                self.events.push(Event {
                    time: t + m.healthy_span(node),
                    kind: EventKind::NodeDegraded,
                    job_id: node as u64,
                    epoch: FAULT_MODEL_ORIGIN,
                });
            }
        }
    }

    /// Feed the straggler detector with what this interval *observed*:
    /// each group that ran over `[last_obs_t, t)` reports the ratio of
    /// its effective step time to its planned speed-1 step time,
    /// attributed to every node its gang touches (the detector cannot
    /// tell which member is slow — only disjoint placements separate
    /// them). Must run after `advance_to(t)` and **before** the
    /// event batch re-prices groups, so the observation reflects the
    /// rates that were actually in effect over the elapsed interval.
    fn observe_speeds(&mut self, t: f64) {
        let dt = t - self.last_obs_t;
        self.last_obs_t = t;
        let Some(est) = &mut self.estimator else {
            return;
        };
        if dt <= 0.0 {
            return;
        }
        let mut observed = vec![false; self.cfg.cluster.n_nodes];
        for g in &self.state.running {
            if g.base_step_time <= 0.0 || g.step_time <= 0.0 {
                continue;
            }
            let ratio = g.step_time / g.base_step_time;
            let steps = dt / g.step_time;
            let nodes = g.alloc.nodes();
            for &n in &nodes {
                if let Some(o) = observed.get_mut(n) {
                    *o = true;
                }
            }
            est.observe_group(&nodes, ratio, steps);
        }
        // nodes with no observations this interval drift back toward
        // healthy — suspicion would otherwise be unfalsifiable, since
        // avoided nodes produce no observations to clear themselves
        est.forgive_idle(
            &observed,
            dt,
            self.cfg.stragglers.rehab_tau_s,
        );
    }

    /// Job `id` is exogenously preempted at `t` (no-op unless placed);
    /// model-originated preemptions chain the next Poisson draw.
    fn apply_preemption(
        &mut self,
        id: u64,
        from_model: bool,
        t: f64,
        extra: &mut [&mut dyn SimObserver],
    ) {
        if let Some(e) =
            self.state.preempt(id, t, &self.faults.penalties)
        {
            self.dirty_jobs.insert(e.job_id);
            self.obs.evict(
                t,
                &self.state.states[&id],
                EvictCause::Preemption,
                &e,
                extra,
            );
        }
        if from_model {
            if let Some(p) = &mut self.faults.preempt {
                let (dt, target) = p.next();
                self.events.push(Event {
                    time: t + dt,
                    kind: EventKind::Preemption,
                    job_id: target,
                    epoch: FAULT_MODEL_ORIGIN,
                });
            }
        }
    }

    /// One scheduling round at time `t`. Mirrors the legacy loop's
    /// steps but runs reactively: release → dissolve → admit →
    /// dispatch (policy) → elastic absorption (policy) → install →
    /// re-derive completion events → bound the next round.
    fn round(&mut self, t: f64, extra: &mut [&mut dyn SimObserver]) {
        self.epoch += 1;
        self.sched_rounds += 1;

        self.state.release_completed();
        self.state.requeue_shared();

        // straggler detection (None = every path below is the
        // oblivious pre-straggler one): suspected nodes are avoided
        // by fresh placements, and jobs allocated on nodes whose
        // estimated slowdown crossed the migrate threshold are moved
        // off — evicted with the usual restore cost and re-placed on
        // healthier nodes by the very admission pass that follows
        let avoid: Option<Vec<bool>> =
            self.estimator.as_ref().map(|est| {
                (0..self.cfg.cluster.n_nodes)
                    .map(|n| {
                        est.slowdown(n)
                            > self.cfg.stragglers.detect_threshold
                    })
                    .collect()
            });
        if let (Some(est), Some(av)) = (&self.estimator, &avoid) {
            let flagged: Vec<bool> = (0..self.cfg.cluster.n_nodes)
                .map(|n| {
                    est.slowdown(n)
                        > self.cfg.stragglers.migrate_threshold
                })
                .collect();
            if flagged.iter().any(|&f| f) {
                let evs = self.state.migrate_stragglers(
                    &flagged,
                    av,
                    t,
                    &self.faults.penalties,
                );
                for e in &evs {
                    self.dirty_jobs.insert(e.job_id);
                    self.obs.evict(
                        t,
                        &self.state.states[&e.job_id],
                        EvictCause::StragglerMigration,
                        e,
                        extra,
                    );
                }
            }
        }

        // regrow shrunken gangs before fresh admissions (degraded
        // running jobs are made whole first — they were admitted
        // before today's queue). Shrink scenarios only: with shrink
        // off no partial allocation can exist and the pass never
        // runs, keeping shrink-free runs byte-identical.
        if self.shrink_enabled {
            for id in self.state.regrow_shrunken() {
                self.obs.regrow(t, id, extra);
            }
        }

        let newly = self.state.admit_queued(
            self.cfg.max_concurrent_jobs,
            &mut self.predictor,
            t,
            avoid.as_deref(),
        );
        for id in newly {
            self.obs.admit(t, &self.state.states[&id], extra);
        }

        let candidates =
            self.state.build_candidates(&mut self.predictor, t);
        let outcome = self.hooks.dispatch(
            candidates,
            &mut self.predictor,
            &self.cfg.scheduler,
        );
        let mut groups = outcome.groups;

        let view = match &self.estimator {
            Some(est) => NodeView::new(
                est,
                self.cfg.stragglers.detect_threshold,
            ),
            None => NodeView::oblivious(),
        };
        let absorbed = self.state.absorb_queued(
            &mut groups,
            self.hooks.as_ref(),
            &view,
            &mut self.predictor,
            &self.cfg.scheduler,
            self.cfg.max_concurrent_jobs,
            t,
        );
        for id in absorbed {
            self.obs.admit(t, &self.state.states[&id], extra);
        }

        self.state.install_groups(
            groups,
            self.hooks.aimd_enabled(),
            self.cfg,
        );

        // exact completion events, dirty-group re-derivation: a
        // running job keeps its live anchored event unless (a) its
        // group's effective step-time bits changed (regroup, AIMD
        // refresh, straggler re-pricing — install_groups recomputes
        // the same bits for an untouched group), (b) its progress
        // jumped discontinuously (eviction rollback; `dirty_jobs`),
        // or (c) it has no live event. Heap churn drops from
        // O(running × rounds) to O(touched × rounds).
        //
        // First: jobs that held a live completion but are no longer
        // running (evicted, re-queued, completed) — bump their epoch
        // so the orphaned event is discarded on pop, exactly as the
        // old global bump would have. (Key iteration order never
        // reaches the event stream: only per-key map mutations.)
        let running_ids: std::collections::HashSet<u64> = self
            .state
            .running
            .iter()
            .flat_map(|g| g.job_ids.iter().copied())
            .collect();
        let gone: Vec<u64> = self
            .completion_anchor
            .keys()
            .filter(|&&id| !running_ids.contains(&id))
            .copied()
            .collect();
        for id in gone {
            *self.completion_epoch.entry(id).or_insert(0) += 1;
            self.completion_anchor.remove(&id);
        }
        for g in &self.state.running {
            let bits = g.step_time.to_bits();
            for id in &g.job_ids {
                let anchored =
                    self.completion_anchor.get(id).copied();
                let clean = !self.dirty_jobs.contains(id)
                    && anchored.is_some_and(|(_, b)| b == bits);
                if clean && !self.opts.global_reissue {
                    continue;
                }
                let time = if clean {
                    // global-reissue mode re-pushes the *anchored*
                    // instant (not a recomputation, whose low-order
                    // bits would drift with the round timestamp), so
                    // its valid-event stream is bit-identical to
                    // dirty mode — the differential test's contract
                    anchored.unwrap().0
                } else {
                    let st = &self.state.states[id];
                    let remaining = (st.spec.total_steps as f64
                        - st.steps_done)
                        .max(0.0);
                    t + remaining * g.step_time
                };
                let e =
                    self.completion_epoch.entry(*id).or_insert(0);
                *e += 1;
                self.completion_anchor.insert(*id, (time, bits));
                self.events.push(Event {
                    time,
                    kind: EventKind::Completion,
                    job_id: *id,
                    epoch: *e,
                });
            }
        }
        self.dirty_jobs.clear();

        // bound the interval until the next round
        let h = self.cfg.scheduler.horizon_s;
        if self.opts.legacy_tick {
            self.events.push(Event {
                time: (t / h).floor() * h + h,
                kind: EventKind::ReschedulePoint,
                job_id: 0,
                epoch: self.epoch,
            });
        } else {
            // queued work can only be retried by a future round; a job
            // that cannot even be placed on a fully idle cluster with
            // no arrivals left is unsatisfiable — no point ticking
            // until t_max for it (it is reported in incomplete_jobs).
            // Jobs inside their checkpoint-restore window are excluded:
            // they get an exact wake-up below instead of periodic ticks
            let unblocked_queued = self
                .state
                .queue
                .iter()
                .any(|id| self.state.states[id].restart_at <= t);
            let queue_pressure = unblocked_queued
                && !(self.state.running.is_empty()
                    && self.arrivals_pending == 0);
            if queue_pressure || self.aimd_pressure() {
                self.events.push(Event {
                    time: t + h,
                    kind: EventKind::ReschedulePoint,
                    job_id: 0,
                    epoch: self.epoch,
                });
            }
        }

        // evicted jobs waiting out their restore window: wake exactly
        // when the earliest one becomes runnable (re-derived each
        // round, so staleness handles superseded wake-ups)
        let mut wake: Option<f64> = None;
        for id in &self.state.queue {
            let ra = self.state.states[id].restart_at;
            if ra > t {
                wake = Some(wake.map_or(ra, |w: f64| w.min(ra)));
            }
        }
        if let Some(w) = wake {
            self.events.push(Event {
                time: w,
                kind: EventKind::ReschedulePoint,
                job_id: 0,
                epoch: self.epoch,
            });
        }

        self.observe_tier_util(t);
        self.observe_rack_span();
        let stats = self.round_stats(t);
        self.obs.round(&stats, extra);
    }

    /// Record the per-tier busy-GPU fraction taking effect at `t`
    /// (mixed fleets only): each running gang contributes its
    /// `compute_util` once per member GPU, attributed to that GPU's
    /// tier. The step function is closed at the makespan when the
    /// result is assembled.
    fn observe_tier_util(&mut self, t: f64) {
        let Some(tr) = &mut self.tier_util else {
            return;
        };
        let mut busy = vec![0.0; tr.gpus.len()];
        for g in &self.state.running {
            for gpu in &g.alloc.gpus {
                busy[self.cfg.cluster.tier_index(gpu.node)] +=
                    g.compute_util;
            }
        }
        for (i, tw) in tr.acc.iter_mut().enumerate() {
            if tr.gpus[i] > 0.0 {
                tw.add(t, busy[i] / tr.gpus[i]);
            }
        }
    }

    /// Sample how many racks every running gang spans (non-flat
    /// topologies only): one observation per gang per round, so the
    /// mean weights gangs by how long they occupy the cluster.
    fn observe_rack_span(&mut self) {
        let Some(rs) = &mut self.rack_span else {
            return;
        };
        for g in &self.state.running {
            let mut racks: Vec<usize> = g
                .alloc
                .gpus
                .iter()
                .map(|gpu| self.cfg.cluster.rack_of(gpu.node))
                .collect();
            racks.sort_unstable();
            racks.dedup();
            let span = racks.len() as u64;
            rs.span_sum += span;
            rs.span_obs += 1;
            rs.span_max = rs.span_max.max(span);
        }
    }

    fn round_stats(&self, t: f64) -> RoundStats {
        let mut inst = 0.0;
        let mut busy = 0.0;
        let mut n_running = 0usize;
        for g in &self.state.running {
            let batch: f64 = g
                .job_ids
                .iter()
                .map(|id| {
                    self.state.states[id].spec.batch_size as f64
                })
                .sum();
            inst += batch / g.step_time;
            busy += g.compute_util * g.alloc.n_gpus() as f64;
            n_running += g.job_ids.len();
        }
        RoundStats {
            t,
            inst_throughput: inst,
            busy_gpus: busy,
            total_gpus: self.total_gpus,
            n_groups: self.state.running.len(),
            n_running,
            n_queued: self.state.queue.len(),
            probes: self.predictor.probes,
            plan_cache_hits: self.predictor.cache_hits(),
        }
    }

    /// Run to completion (or starvation / `t_max`) and assemble the
    /// result from the observers.
    pub fn run(
        mut self,
        extra: &mut [&mut dyn SimObserver],
    ) -> SimResult {
        // round 0 at t=0 mirrors the legacy loop's first horizon:
        // admit anything submitted at the trace origin (scripted
        // faults at t=0 apply before the first dispatch; preemptions
        // at t=0 are no-ops — nothing is placed yet)
        while let Some(ev) = self.pop_valid_at(0.0) {
            self.events_processed += 1;
            let from_model = ev.epoch == FAULT_MODEL_ORIGIN;
            match ev.kind {
                EventKind::Arrival => {
                    self.arrivals_pending -= 1;
                    self.state.queue.push(ev.job_id);
                }
                EventKind::NodeFailure => {
                    self.apply_node_failure(
                        ev.job_id as usize,
                        from_model,
                        0.0,
                        extra,
                    );
                }
                EventKind::NodeRecovery => {
                    self.apply_node_recovery(
                        ev.job_id as usize,
                        from_model,
                        0.0,
                        extra,
                    );
                }
                EventKind::GpuFailure => {
                    let gpn = self.cfg.cluster.gpus_per_node;
                    self.apply_gpu_failure(
                        ev.job_id as usize / gpn,
                        ev.job_id as usize % gpn,
                        from_model,
                        0.0,
                        extra,
                    );
                }
                EventKind::GpuRecovery => {
                    let gpn = self.cfg.cluster.gpus_per_node;
                    self.apply_gpu_recovery(
                        ev.job_id as usize / gpn,
                        ev.job_id as usize % gpn,
                        from_model,
                        0.0,
                        extra,
                    );
                }
                EventKind::NodeDegraded => {
                    self.apply_node_degraded(
                        ev.job_id as usize,
                        from_model,
                        0.0,
                        extra,
                    );
                }
                EventKind::NodeRestored => {
                    self.apply_node_restored(
                        ev.job_id as usize,
                        from_model,
                        0.0,
                        extra,
                    );
                }
                EventKind::Preemption => {
                    self.apply_preemption(
                        ev.job_id,
                        from_model,
                        0.0,
                        extra,
                    );
                }
                EventKind::Completion
                | EventKind::ReschedulePoint => {}
            }
        }
        self.round(0.0, extra);

        while self.state.completed < self.n_jobs {
            let Some(first) = self.pop_next_valid() else {
                // no events left but jobs incomplete: unsatisfiable
                // jobs (e.g. wanting more GPUs than the cluster has) —
                // surfaced via SimResult::incomplete_jobs
                break;
            };
            let t = first.time;
            if t > self.t_max {
                break;
            }
            self.state.advance_to(t);
            // detector observations cover [last_obs_t, t) at the rates
            // that were actually in effect — before this batch's
            // degrade/restore events re-price anything
            self.observe_speeds(t);
            let mut arrivals = vec![];
            let mut completions = vec![];
            let mut failures = vec![];
            let mut recoveries = vec![];
            let mut gpu_failures = vec![];
            let mut gpu_recoveries = vec![];
            let mut degrades = vec![];
            let mut restores = vec![];
            let mut preemptions = vec![];
            let mut batch = vec![first];
            while let Some(ev) = self.pop_valid_at(t) {
                batch.push(ev);
            }
            for ev in batch {
                self.events_processed += 1;
                match ev.kind {
                    EventKind::Arrival => {
                        self.arrivals_pending -= 1;
                        arrivals.push(ev.job_id);
                    }
                    EventKind::Completion => {
                        completions.push(ev.job_id);
                    }
                    EventKind::NodeFailure => {
                        failures.push((
                            ev.job_id as usize,
                            ev.epoch == FAULT_MODEL_ORIGIN,
                        ));
                    }
                    EventKind::NodeRecovery => {
                        recoveries.push((
                            ev.job_id as usize,
                            ev.epoch == FAULT_MODEL_ORIGIN,
                        ));
                    }
                    EventKind::GpuFailure => {
                        gpu_failures.push((
                            ev.job_id as usize,
                            ev.epoch == FAULT_MODEL_ORIGIN,
                        ));
                    }
                    EventKind::GpuRecovery => {
                        gpu_recoveries.push((
                            ev.job_id as usize,
                            ev.epoch == FAULT_MODEL_ORIGIN,
                        ));
                    }
                    EventKind::NodeDegraded => {
                        degrades.push((
                            ev.job_id as usize,
                            ev.epoch == FAULT_MODEL_ORIGIN,
                        ));
                    }
                    EventKind::NodeRestored => {
                        restores.push((
                            ev.job_id as usize,
                            ev.epoch == FAULT_MODEL_ORIGIN,
                        ));
                    }
                    EventKind::Preemption => {
                        preemptions.push((
                            ev.job_id,
                            ev.epoch == FAULT_MODEL_ORIGIN,
                        ));
                    }
                    EventKind::ReschedulePoint => {}
                }
            }
            for id in arrivals {
                self.state.queue.push(id);
            }
            // completions first (rank order): a final step landing at
            // the failure instant still counts as finished
            for id in completions {
                if self.state.complete(id, t) {
                    self.obs.complete(
                        t,
                        &self.state.states[&id],
                        extra,
                    );
                }
            }
            for (node, from_model) in failures {
                self.apply_node_failure(node, from_model, t, extra);
            }
            for (node, from_model) in recoveries {
                self.apply_node_recovery(node, from_model, t, extra);
            }
            // single-GPU faults after whole-node transitions (rank
            // order): a node-level outage at the same instant subsumes
            // the device fault — holing a GPU on an already-evicted
            // node is an idempotent mask update, never a double-evict
            let gpn = self.cfg.cluster.gpus_per_node;
            for (flat, from_model) in gpu_failures {
                self.apply_gpu_failure(
                    flat / gpn,
                    flat % gpn,
                    from_model,
                    t,
                    extra,
                );
            }
            for (flat, from_model) in gpu_recoveries {
                self.apply_gpu_recovery(
                    flat / gpn,
                    flat % gpn,
                    from_model,
                    t,
                    extra,
                );
            }
            // degrade/restore after failure/recovery (rank order), so
            // an eviction priced at this instant sees the new rate
            for (node, from_model) in degrades {
                self.apply_node_degraded(node, from_model, t, extra);
            }
            for (node, from_model) in restores {
                self.apply_node_restored(node, from_model, t, extra);
            }
            for (id, from_model) in preemptions {
                self.apply_preemption(id, from_model, t, extra);
            }
            self.round(t, extra);
        }

        let makespan = self.state.now;
        {
            let jobs = self.state.sorted_states();
            self.obs.finish(makespan, &jobs, extra);
        }

        let jct = std::mem::take(&mut self.obs.completion.jct);
        let jvals: Vec<f64> =
            jct.iter().map(|&(_, v)| v).collect();
        let summary = Summary::of(&jvals);
        let (avg_throughput, avg_gpu_util) = self
            .obs
            .timeline
            .windowed_averages(self.cfg.scheduler.horizon_s);
        let (avg_throughput_full, avg_gpu_util_full) =
            self.obs.timeline.full_averages();
        let tier_util = match &mut self.tier_util {
            Some(tr) => self
                .cfg
                .cluster
                .tiers
                .iter()
                .enumerate()
                .map(|(i, tier)| {
                    let u = if tr.gpus[i] > 0.0 {
                        tr.acc[i].finish(makespan)
                    } else {
                        0.0
                    };
                    (tier.name.clone(), u)
                })
                .collect(),
            None => vec![],
        };
        let (rack_span_mean, rack_span_max) = match &self.rack_span {
            Some(rs) if rs.span_obs > 0 => (
                rs.span_sum as f64 / rs.span_obs as f64,
                rs.span_max,
            ),
            _ => (0.0, 0),
        };

        SimResult {
            policy: self.cfg.policy,
            mean_jct: summary.mean,
            p99_jct: summary.p99,
            jct,
            avg_throughput,
            avg_throughput_full,
            throughput_timeline: std::mem::take(
                &mut self.obs.timeline.throughput_timeline,
            ),
            avg_gpu_util,
            avg_gpu_util_full,
            util_timeline: std::mem::take(
                &mut self.obs.timeline.util_timeline,
            ),
            makespan,
            grouping_ratio: std::mem::take(
                &mut self.obs.grouping.grouping_ratio,
            ),
            scheduler_probes: self.predictor.probes,
            plan_cache_hits: self.predictor.cache_hits(),
            sched_rounds: self.sched_rounds,
            events: self.events_processed,
            events_stale: self.stale_discards,
            incomplete_jobs: std::mem::take(
                &mut self.obs.completion.incomplete,
            ),
            mean_slowdown: self.obs.slowdown.mean_slowdown,
            node_failures: self.obs.faults.node_failures,
            gpu_failures: self.obs.faults.gpu_failures,
            holed_gpu_time_s: self.obs.faults.holed_gpu_time_s,
            preemptions: self.obs.faults.preemptions,
            restarts: self.obs.faults.restarts,
            lost_step_time_s: self.obs.faults.lost_step_time_s,
            restore_delay_s: self.obs.faults.restore_delay_s,
            goodput: self.obs.faults.goodput,
            slo_attainment: self.obs.faults.slo_attainment,
            node_degrades: self.obs.stragglers.node_degrades,
            degraded_node_time_s: self
                .obs
                .stragglers
                .degraded_node_time_s,
            straggler_slowdown: self
                .obs
                .stragglers
                .straggler_slowdown,
            migrations: self.obs.stragglers.migrations,
            shrinks: self.obs.shrink.shrinks,
            regrows: self.obs.shrink.regrows,
            degraded_rate_time_s: self
                .obs
                .shrink
                .degraded_rate_time_s,
            tier_util,
            rack_span_mean,
            rack_span_max,
        }
    }
}
