//! Metric observers: [`SimResult`](crate::sim::SimResult) is assembled
//! from pluggable [`SimObserver`]s instead of accumulators threaded
//! through the engine loop.
//!
//! **Observer contract.** The engine calls, in order per scheduling
//! round: zero or more `on_admit`/`on_complete` (as jobs start and
//! finish), then exactly one `on_round` with the post-round snapshot.
//! `on_finish` fires once after the last round with the final job
//! states sorted by id (the canonical order — observers summing floats
//! over it stay bit-deterministic). Observers must be deterministic
//! functions of their inputs; they must not read clocks or global
//! state, or the sweep engine's cross-thread bit-identity breaks.
//!
//! Custom observers (tests, future failure-injection / SLO scenarios)
//! implement the trait and are passed to
//! [`crate::sim::simulate_jobs_with`]; the four built-ins below feed
//! every field of `SimResult`.

use std::collections::HashMap;

use super::state::JobState;
use crate::util::stats::{percentile_sorted, TimeWeighted};
use crate::workload::SizeClass;

/// Snapshot the engine publishes after every scheduling round.
#[derive(Debug, Clone)]
pub struct RoundStats {
    /// round timestamp (simulated seconds)
    pub t: f64,
    /// Σ groups batch / step_time — instantaneous cluster samples/s
    pub inst_throughput: f64,
    /// Σ groups compute_util × gpus
    pub busy_gpus: f64,
    pub total_gpus: f64,
    pub n_groups: usize,
    /// running jobs across all groups
    pub n_running: usize,
    /// jobs still queued after this round's admission
    pub n_queued: usize,
}

/// Observer callbacks. All methods default to no-ops so an observer
/// implements only what it needs.
pub trait SimObserver {
    /// A job started making progress for the first time (own
    /// allocation or elastic shared admission).
    fn on_admit(&mut self, _t: f64, _job: &JobState) {}

    /// A scheduling round finished; `stats` is the new running state.
    fn on_round(&mut self, _stats: &RoundStats) {}

    /// A job completed at `t` (its final state, post-completion).
    fn on_complete(&mut self, _t: f64, _job: &JobState) {}

    /// The run ended at `t_end`; `jobs` holds every job's final state
    /// sorted by id (completed or not).
    fn on_finish(&mut self, _t_end: f64, _jobs: &[&JobState]) {}
}

/// Throughput + GPU-utilization timelines and their time-weighted
/// averages, both full-run and windowed to the 90th-percentile
/// completion (the steady window the paper's figures use, so a finite
/// trace's drain tail does not wash out the signal).
#[derive(Debug, Default)]
pub struct TimelineObserver {
    pub throughput_timeline: Vec<(f64, f64)>,
    pub util_timeline: Vec<(f64, f64)>,
    thr_full: TimeWeighted,
    util_full: TimeWeighted,
    completions: Vec<f64>,
    avg_throughput_full: f64,
    avg_gpu_util_full: f64,
}

impl SimObserver for TimelineObserver {
    fn on_round(&mut self, s: &RoundStats) {
        let util = s.busy_gpus / s.total_gpus;
        self.throughput_timeline.push((s.t, s.inst_throughput));
        self.util_timeline.push((s.t, util.min(1.0)));
        self.thr_full.add(s.t, s.inst_throughput);
        self.util_full.add(s.t, util);
    }

    fn on_complete(&mut self, t: f64, _job: &JobState) {
        self.completions.push(t);
    }

    fn on_finish(&mut self, t_end: f64, _jobs: &[&JobState]) {
        self.avg_throughput_full = self.thr_full.finish(t_end);
        self.avg_gpu_util_full = self.util_full.finish(t_end);
    }
}

impl TimelineObserver {
    /// Full-run time-weighted averages (throughput, utilization);
    /// valid after `on_finish`.
    pub fn full_averages(&self) -> (f64, f64) {
        (self.avg_throughput_full, self.avg_gpu_util_full)
    }

    /// Averages over the steady window `[0, t90]`, where `t90` is the
    /// 90th-percentile completion time, floored at `min_window`.
    pub fn windowed_averages(&self, min_window: f64) -> (f64, f64) {
        let mut done = self.completions.clone();
        done.sort_by(|a, b| crate::util::f64_cmp(*a, *b));
        let t90 = percentile_sorted(&done, 0.90).max(min_window);
        let window_avg = |tl: &[(f64, f64)]| -> f64 {
            let mut acc = TimeWeighted::default();
            for &(ts, v) in tl.iter().filter(|&&(ts, _)| ts <= t90) {
                acc.add(ts, v);
            }
            acc.finish(t90)
        };
        (
            window_avg(&self.throughput_timeline),
            window_avg(&self.util_timeline),
        )
    }
}

/// Per-job completion records: JCT pairs and the jobs that never
/// finished (silently truncated by the old loop's `t_max` valve — now
/// surfaced as [`crate::sim::SimResult::incomplete_jobs`]).
#[derive(Debug, Default)]
pub struct CompletionObserver {
    /// (job id, completion time - submit time), sorted by id at finish
    pub jct: Vec<(u64, f64)>,
    pub incomplete: Vec<u64>,
}

impl SimObserver for CompletionObserver {
    fn on_complete(&mut self, t: f64, job: &JobState) {
        self.jct.push((job.spec.id, t - job.spec.submit_time));
    }

    fn on_finish(&mut self, _t_end: f64, jobs: &[&JobState]) {
        self.jct.sort_by_key(|&(id, _)| id);
        self.incomplete = jobs
            .iter()
            .filter(|s| s.completed_at.is_none())
            .map(|s| s.spec.id)
            .collect();
    }
}

/// Per size-class grouping ratio (Fig. 6b): fraction of running time
/// each class spent co-located.
#[derive(Debug, Default)]
pub struct GroupingObserver {
    size_classes: HashMap<u64, SizeClass>,
    pub grouping_ratio: HashMap<&'static str, f64>,
}

impl GroupingObserver {
    pub fn new(size_classes: HashMap<u64, SizeClass>) -> Self {
        GroupingObserver {
            size_classes,
            grouping_ratio: HashMap::new(),
        }
    }
}

impl SimObserver for GroupingObserver {
    fn on_finish(&mut self, _t_end: f64, jobs: &[&JobState]) {
        let mut class_grouped: HashMap<&'static str, (f64, f64)> =
            HashMap::new();
        for s in jobs {
            let class = match self.size_classes.get(&s.spec.id) {
                Some(SizeClass::Small) => "small",
                Some(SizeClass::Medium) => "medium",
                Some(SizeClass::Large) => "large",
                None => continue,
            };
            let e = class_grouped.entry(class).or_insert((0.0, 0.0));
            e.0 += s.grouped_time;
            e.1 += s.running_time;
        }
        self.grouping_ratio = class_grouped
            .into_iter()
            .map(|(k, (g, r))| (k, if r > 0.0 { g / r } else { 0.0 }))
            .collect();
    }
}

/// Mean slowdown across jobs that ran (expected isolated steps over
/// actual steps, the §4.2 fairness metric).
#[derive(Debug, Default)]
pub struct SlowdownObserver {
    pub mean_slowdown: f64,
}

impl SimObserver for SlowdownObserver {
    fn on_finish(&mut self, _t_end: f64, jobs: &[&JobState]) {
        let mut acc = 0.0;
        let mut n = 0usize;
        for s in jobs {
            if s.running_time > 0.0 && s.iso_step_time.is_finite() {
                let exp_steps = s.running_time / s.iso_step_time;
                if s.steps_done > 0.0 && exp_steps > 0.0 {
                    acc += exp_steps / s.steps_done;
                    n += 1;
                }
            }
        }
        self.mean_slowdown =
            if n > 0 { acc / n as f64 } else { 1.0 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::JobSpec;

    fn job_state(id: u64, submit: f64) -> JobState {
        JobState {
            spec: JobSpec {
                id,
                base_model: "llama3-8b".into(),
                rank: 8,
                batch_size: 4,
                seq_len: 512,
                gpus: 1,
                total_steps: 100,
                submit_time: submit,
                max_slowdown: 2.0,
            },
            steps_done: 0.0,
            iso_step_time: 1.0,
            admitted_at: None,
            completed_at: None,
            grouped_time: 0.0,
            running_time: 0.0,
        }
    }

    #[test]
    fn timeline_windowed_vs_full_averages() {
        let mut o = TimelineObserver::default();
        let stats = |t: f64, thr: f64| RoundStats {
            t,
            inst_throughput: thr,
            busy_gpus: 0.0,
            total_gpus: 16.0,
            n_groups: 0,
            n_running: 0,
            n_queued: 0,
        };
        o.on_round(&stats(0.0, 10.0));
        o.on_round(&stats(100.0, 0.0)); // drain tail: zero throughput
        let done = job_state(0, 0.0);
        o.on_complete(50.0, &done);
        o.on_finish(200.0, &[]);
        let (full, _) = o.full_averages();
        // 10 samples/s for half the run, 0 for the other half
        assert!((full - 5.0).abs() < 1e-9, "{full}");
        // windowed to t90=max(50, 60)=60: only the busy stretch counts
        let (windowed, _) = o.windowed_averages(60.0);
        assert!((windowed - 10.0).abs() < 1e-9, "{windowed}");
    }

    #[test]
    fn completion_observer_tracks_incomplete() {
        let mut o = CompletionObserver::default();
        let mut a = job_state(3, 5.0);
        a.completed_at = Some(25.0);
        o.on_complete(25.0, &a);
        let b = job_state(7, 0.0); // never completed
        o.on_finish(100.0, &[&a, &b]);
        assert_eq!(o.jct, vec![(3, 20.0)]);
        assert_eq!(o.incomplete, vec![7]);
    }

    #[test]
    fn grouping_ratio_per_class() {
        let mut classes = HashMap::new();
        classes.insert(0, SizeClass::Small);
        classes.insert(1, SizeClass::Large);
        let mut o = GroupingObserver::new(classes);
        let mut a = job_state(0, 0.0);
        a.grouped_time = 30.0;
        a.running_time = 60.0;
        let mut b = job_state(1, 0.0);
        b.grouped_time = 0.0;
        b.running_time = 40.0;
        o.on_finish(100.0, &[&a, &b]);
        assert!((o.grouping_ratio["small"] - 0.5).abs() < 1e-12);
        assert_eq!(o.grouping_ratio["large"], 0.0);
    }

    #[test]
    fn slowdown_defaults_to_one_without_runners() {
        let mut o = SlowdownObserver::default();
        o.on_finish(10.0, &[]);
        assert_eq!(o.mean_slowdown, 1.0);
    }
}
