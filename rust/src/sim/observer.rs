//! Metric observers: [`SimResult`](crate::sim::SimResult) is assembled
//! from pluggable [`SimObserver`]s instead of accumulators threaded
//! through the engine loop.
//!
//! **Observer contract.** The engine calls, in order per scheduling
//! round: zero or more `on_admit`/`on_complete` (as jobs start and
//! finish), then exactly one `on_round` with the post-round snapshot.
//! `on_finish` fires once after the last round with the final job
//! states sorted by id (the canonical order — observers summing floats
//! over it stay bit-deterministic). Observers must be deterministic
//! functions of their inputs; they must not read clocks or global
//! state, or the sweep engine's cross-thread bit-identity breaks.
//!
//! Custom observers (tests, future failure-injection / SLO scenarios)
//! implement the trait and are passed to
//! [`crate::sim::simulate_jobs_with`]; the four built-ins below feed
//! every field of `SimResult`.

use std::collections::HashMap;

use super::state::JobState;
use crate::util::stats::{percentile_sorted, TimeWeighted};
use crate::workload::SizeClass;

/// Snapshot the engine publishes after every scheduling round.
#[derive(Debug, Clone)]
pub struct RoundStats {
    /// round timestamp (simulated seconds)
    pub t: f64,
    /// Σ groups batch / step_time — instantaneous cluster samples/s
    pub inst_throughput: f64,
    /// Σ groups compute_util × gpus
    pub busy_gpus: f64,
    pub total_gpus: f64,
    pub n_groups: usize,
    /// running jobs across all groups
    pub n_running: usize,
    /// jobs still queued after this round's admission
    pub n_queued: usize,
    /// cumulative planner evaluations so far (the predictor's
    /// shape-level cache misses — `SimResult::scheduler_probes` is
    /// the final value)
    pub probes: u64,
    /// cumulative predictor queries the caches absorbed so far
    /// (exact + shape level)
    pub plan_cache_hits: u64,
}

/// Why a job was evicted mid-run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictCause {
    /// Its group's allocation touched a failed node.
    NodeFailure,
    /// Its group's allocation touched a single failed GPU (the rest
    /// of the node keeps serving; only gangs on the device itself are
    /// evicted).
    GpuFailure,
    /// Exogenous preemption (spot reclaim / priority tenant).
    Preemption,
    /// A detection-aware policy moved it off a suspected straggler
    /// node (same mechanics as an eviction: rollback + restore
    /// penalty, then re-placement on healthier nodes).
    StragglerMigration,
}

/// Observer callbacks. All methods default to no-ops so an observer
/// implements only what it needs.
pub trait SimObserver {
    /// A job started making progress for the first time (own
    /// allocation or elastic shared admission).
    fn on_admit(&mut self, _t: f64, _job: &JobState) {}

    /// A scheduling round finished; `stats` is the new running state.
    fn on_round(&mut self, _stats: &RoundStats) {}

    /// A job completed at `t` (its final state, post-completion).
    fn on_complete(&mut self, _t: f64, _job: &JobState) {}

    /// A node went down at `t`.
    fn on_node_failure(&mut self, _t: f64, _node: usize) {}

    /// A node returned to the pool at `t`.
    fn on_node_recovery(&mut self, _t: f64, _node: usize) {}

    /// A single GPU died at `t`; its node's surviving devices keep
    /// serving.
    fn on_gpu_failure(&mut self, _t: f64, _node: usize, _gpu: usize) {}

    /// A holed GPU returned to its node's pool at `t`.
    fn on_gpu_recovery(&mut self, _t: f64, _node: usize, _gpu: usize) {
    }

    /// A node started straggling at `t`: it runs at `speed` × nominal
    /// until restored (a repeat degrade re-samples the severity).
    fn on_node_degraded(
        &mut self,
        _t: f64,
        _node: usize,
        _speed: f64,
    ) {
    }

    /// A straggling node returned to full speed at `t`.
    fn on_node_restored(&mut self, _t: f64, _node: usize) {}

    /// A job was evicted at `t`: `lost_s` seconds of in-flight work
    /// rolled back, `penalty_s` of checkpoint-restore delay before it
    /// may run again (`job` is its post-eviction state).
    fn on_evict(
        &mut self,
        _t: f64,
        _job: &JobState,
        _cause: EvictCause,
        _lost_s: f64,
        _penalty_s: f64,
    ) {
    }

    /// Gangs shrank in place at `t` (graceful degradation under a
    /// single-GPU failure, `faults.shrink` scenarios): `jobs` kept
    /// training at the surviving width, `groups` running gangs were
    /// shrunk, and `rollback_lost_s` seconds of in-flight work rolled
    /// back to the survivors' last checkpoint boundaries. Members that
    /// spilled instead arrive through the usual `on_evict`.
    fn on_shrink(
        &mut self,
        _t: f64,
        _jobs: &[u64],
        _groups: u64,
        _rollback_lost_s: f64,
    ) {
    }

    /// A shrunken gang was regrown to its full provisioned width at
    /// `t` (device recovery or free-pool backfill).
    fn on_regrow(&mut self, _t: f64, _job: u64) {}

    /// The run ended at `t_end`; `jobs` holds every job's final state
    /// sorted by id (completed or not).
    fn on_finish(&mut self, _t_end: f64, _jobs: &[&JobState]) {}
}

/// Throughput + GPU-utilization timelines and their time-weighted
/// averages, both full-run and windowed to the 90th-percentile
/// completion (the steady window the paper's figures use, so a finite
/// trace's drain tail does not wash out the signal).
#[derive(Debug, Default)]
pub struct TimelineObserver {
    pub throughput_timeline: Vec<(f64, f64)>,
    pub util_timeline: Vec<(f64, f64)>,
    thr_full: TimeWeighted,
    util_full: TimeWeighted,
    completions: Vec<f64>,
    avg_throughput_full: f64,
    avg_gpu_util_full: f64,
}

impl SimObserver for TimelineObserver {
    fn on_round(&mut self, s: &RoundStats) {
        let util = s.busy_gpus / s.total_gpus;
        self.throughput_timeline.push((s.t, s.inst_throughput));
        self.util_timeline.push((s.t, util.min(1.0)));
        self.thr_full.add(s.t, s.inst_throughput);
        self.util_full.add(s.t, util);
    }

    fn on_complete(&mut self, t: f64, _job: &JobState) {
        self.completions.push(t);
    }

    fn on_finish(&mut self, t_end: f64, _jobs: &[&JobState]) {
        self.avg_throughput_full = self.thr_full.finish(t_end);
        self.avg_gpu_util_full = self.util_full.finish(t_end);
    }
}

impl TimelineObserver {
    /// Full-run time-weighted averages (throughput, utilization);
    /// valid after `on_finish`.
    pub fn full_averages(&self) -> (f64, f64) {
        (self.avg_throughput_full, self.avg_gpu_util_full)
    }

    /// Averages over the steady window `[0, t90]`, where `t90` is the
    /// 90th-percentile completion time, floored at `min_window`.
    pub fn windowed_averages(&self, min_window: f64) -> (f64, f64) {
        let mut done = self.completions.clone();
        done.sort_by(|a, b| crate::util::f64_cmp(*a, *b));
        let t90 = percentile_sorted(&done, 0.90).max(min_window);
        let window_avg = |tl: &[(f64, f64)]| -> f64 {
            let mut acc = TimeWeighted::default();
            for &(ts, v) in tl.iter().filter(|&&(ts, _)| ts <= t90) {
                acc.add(ts, v);
            }
            acc.finish(t90)
        };
        (
            window_avg(&self.throughput_timeline),
            window_avg(&self.util_timeline),
        )
    }
}

/// Per-job completion records: JCT pairs and the jobs that never
/// finished (silently truncated by the old loop's `t_max` valve — now
/// surfaced as [`crate::sim::SimResult::incomplete_jobs`]).
#[derive(Debug, Default)]
pub struct CompletionObserver {
    /// (job id, completion time - submit time), sorted by id at finish
    pub jct: Vec<(u64, f64)>,
    pub incomplete: Vec<u64>,
}

impl SimObserver for CompletionObserver {
    fn on_complete(&mut self, t: f64, job: &JobState) {
        self.jct.push((job.spec.id, t - job.spec.submit_time));
    }

    fn on_finish(&mut self, _t_end: f64, jobs: &[&JobState]) {
        self.jct.sort_by_key(|&(id, _)| id);
        self.incomplete = jobs
            .iter()
            .filter(|s| s.completed_at.is_none())
            .map(|s| s.spec.id)
            .collect();
    }
}

/// Per size-class grouping ratio (Fig. 6b): fraction of running time
/// each class spent co-located.
#[derive(Debug, Default)]
pub struct GroupingObserver {
    size_classes: HashMap<u64, SizeClass>,
    pub grouping_ratio: HashMap<&'static str, f64>,
}

impl GroupingObserver {
    pub fn new(size_classes: HashMap<u64, SizeClass>) -> Self {
        GroupingObserver {
            size_classes,
            grouping_ratio: HashMap::new(),
        }
    }
}

impl SimObserver for GroupingObserver {
    fn on_finish(&mut self, _t_end: f64, jobs: &[&JobState]) {
        let mut class_grouped: HashMap<&'static str, (f64, f64)> =
            HashMap::new();
        for s in jobs {
            let class = match self.size_classes.get(&s.spec.id) {
                Some(SizeClass::Small) => "small",
                Some(SizeClass::Medium) => "medium",
                Some(SizeClass::Large) => "large",
                None => continue,
            };
            let e = class_grouped.entry(class).or_insert((0.0, 0.0));
            e.0 += s.grouped_time;
            e.1 += s.running_time;
        }
        self.grouping_ratio = class_grouped
            .into_iter()
            .map(|(k, (g, r))| (k, if r > 0.0 { g / r } else { 0.0 }))
            .collect();
    }
}

/// Fault & SLO accounting: churn counts, lost work, restore delay,
/// goodput, and per-job deadline attainment.
///
/// *Goodput* is useful samples per second — every step that survived
/// to the end of the run (rolled-back work is subtracted from
/// `steps_done` at eviction, so it never counts), over the makespan.
/// *SLO attainment* is the fraction of jobs that finished by their
/// deadline `submit + slo_factor × Δ^max × total_steps ×
/// iso_step_time` (incomplete or never-admitted jobs are misses).
#[derive(Debug)]
pub struct FaultObserver {
    slo_factor: f64,
    pub node_failures: u64,
    pub node_recoveries: u64,
    /// single-GPU faults (the sub-node axis; node_failures excluded)
    pub gpu_failures: u64,
    pub preemptions: u64,
    /// total evictions (failure + preemption)
    pub restarts: u64,
    pub lost_step_time_s: f64,
    pub restore_delay_s: f64,
    /// Σ over devices of seconds spent individually holed (episodes
    /// still open at the end of the run are closed at `t_end`)
    pub holed_gpu_time_s: f64,
    /// open holed-device episodes: (node, gpu) → fail time. Never
    /// iterated except drained *sorted* at finish, so map order
    /// cannot leak into the float sum.
    holed_open: HashMap<(usize, usize), f64>,
    pub goodput: f64,
    pub slo_attainment: f64,
}

impl FaultObserver {
    pub fn new(slo_factor: f64) -> FaultObserver {
        FaultObserver {
            slo_factor,
            node_failures: 0,
            node_recoveries: 0,
            gpu_failures: 0,
            preemptions: 0,
            restarts: 0,
            lost_step_time_s: 0.0,
            restore_delay_s: 0.0,
            holed_gpu_time_s: 0.0,
            holed_open: HashMap::new(),
            goodput: 0.0,
            slo_attainment: 1.0,
        }
    }

    /// A job's SLO deadline under this observer's factor, if its
    /// isolated baseline is known.
    pub fn deadline_of(&self, job: &JobState) -> Option<f64> {
        if job.iso_step_time.is_finite() && job.iso_step_time > 0.0 {
            Some(
                job.spec.submit_time
                    + self.slo_factor
                        * job.spec.max_slowdown
                        * job.spec.total_steps as f64
                        * job.iso_step_time,
            )
        } else {
            None
        }
    }
}

impl SimObserver for FaultObserver {
    fn on_node_failure(&mut self, _t: f64, _node: usize) {
        self.node_failures += 1;
    }

    fn on_node_recovery(&mut self, _t: f64, _node: usize) {
        self.node_recoveries += 1;
    }

    fn on_gpu_failure(&mut self, t: f64, node: usize, gpu: usize) {
        self.gpu_failures += 1;
        // a repeat failure without a recovery (scripted) keeps the
        // original episode open — the device was already holed
        self.holed_open.entry((node, gpu)).or_insert(t);
    }

    fn on_gpu_recovery(&mut self, t: f64, node: usize, gpu: usize) {
        if let Some(start) = self.holed_open.remove(&(node, gpu)) {
            self.holed_gpu_time_s += (t - start).max(0.0);
        }
    }

    fn on_evict(
        &mut self,
        _t: f64,
        _job: &JobState,
        cause: EvictCause,
        lost_s: f64,
        penalty_s: f64,
    ) {
        // straggler migrations are *voluntary* evictions (a policy
        // choice, not a fault) — they are accounted by
        // [`StragglerObserver`] so the fault columns keep meaning
        // "damage the environment inflicted"
        if cause == EvictCause::StragglerMigration {
            return;
        }
        self.restarts += 1;
        if cause == EvictCause::Preemption {
            self.preemptions += 1;
        }
        self.lost_step_time_s += lost_s;
        self.restore_delay_s += penalty_s;
    }

    fn on_finish(&mut self, t_end: f64, jobs: &[&JobState]) {
        let mut open: Vec<((usize, usize), f64)> =
            self.holed_open.drain().collect();
        open.sort_unstable_by_key(|&(k, _)| k);
        for (_, start) in open {
            self.holed_gpu_time_s += (t_end - start).max(0.0);
        }
        let mut samples = 0.0;
        let mut met = 0usize;
        for s in jobs {
            samples += s.steps_done.min(s.spec.total_steps as f64)
                * s.spec.batch_size as f64;
            if let (Some(done), Some(deadline)) =
                (s.completed_at, self.deadline_of(s))
            {
                if done <= deadline {
                    met += 1;
                }
            }
        }
        self.goodput =
            if t_end > 0.0 { samples / t_end } else { 0.0 };
        self.slo_attainment = if jobs.is_empty() {
            1.0
        } else {
            met as f64 / jobs.len() as f64
        };
    }
}

/// Straggler accounting: degrade/restore episodes per node, total
/// degraded node-time, the time-weighted severity of that time, and
/// the voluntary migrations detection-aware policies performed.
///
/// *degraded_node_time_s* sums, over nodes, the simulated seconds each
/// spent degraded (episodes still open at the end of the run are
/// closed at `t_end`). *straggler_slowdown* is the time-weighted mean
/// of `1/speed` over that degraded time (1.0 when no node ever
/// degraded) — "how slow was a degraded node, while degraded".
/// *migrations* counts [`EvictCause::StragglerMigration`] evictions.
#[derive(Debug)]
pub struct StragglerObserver {
    /// per node: (episode start, episode speed) while degraded
    open: Vec<Option<(f64, f64)>>,
    pub node_degrades: u64,
    pub migrations: u64,
    pub degraded_node_time_s: f64,
    /// Σ episode_duration / episode_speed
    weighted_slow_s: f64,
    pub straggler_slowdown: f64,
}

impl StragglerObserver {
    pub fn new(n_nodes: usize) -> StragglerObserver {
        StragglerObserver {
            open: vec![None; n_nodes],
            node_degrades: 0,
            migrations: 0,
            degraded_node_time_s: 0.0,
            weighted_slow_s: 0.0,
            straggler_slowdown: 1.0,
        }
    }

    fn close_episode(&mut self, node: usize, t: f64) {
        if let Some(Some((start, speed))) =
            self.open.get_mut(node).map(Option::take)
        {
            let dur = (t - start).max(0.0);
            self.degraded_node_time_s += dur;
            self.weighted_slow_s += dur / speed;
        }
    }
}

impl SimObserver for StragglerObserver {
    fn on_node_degraded(&mut self, t: f64, node: usize, speed: f64) {
        // a repeat degrade closes the running episode (severity
        // changed) and opens a new one at the new speed
        self.close_episode(node, t);
        if node < self.open.len() {
            self.open[node] = Some((t, speed));
        }
        self.node_degrades += 1;
    }

    fn on_node_restored(&mut self, t: f64, node: usize) {
        self.close_episode(node, t);
    }

    fn on_evict(
        &mut self,
        _t: f64,
        _job: &JobState,
        cause: EvictCause,
        _lost_s: f64,
        _penalty_s: f64,
    ) {
        if cause == EvictCause::StragglerMigration {
            self.migrations += 1;
        }
    }

    fn on_finish(&mut self, t_end: f64, _jobs: &[&JobState]) {
        for node in 0..self.open.len() {
            self.close_episode(node, t_end);
        }
        self.straggler_slowdown = if self.degraded_node_time_s > 0.0 {
            self.weighted_slow_s / self.degraded_node_time_s
        } else {
            1.0
        };
    }
}

/// Graceful-degradation accounting (`faults.shrink` scenarios): gangs
/// shrunk in place, regrows back to full width, and the total
/// simulated seconds jobs spent training *degraded* (shrunken width,
/// reduced rate).
///
/// *degraded_rate_time_s* sums per-job episodes opened at shrink time
/// and closed by whichever comes first: regrow, eviction (the job left
/// the degraded gang through the normal spill/churn path), completion,
/// or the end of the run. A repeat shrink while an episode is open
/// (a second device dying under the same gang) keeps the original
/// episode — the job was already degraded. The open-episode map is
/// never iterated except drained *sorted* at finish, so map order
/// cannot leak into the float sum.
#[derive(Debug, Default)]
pub struct ShrinkObserver {
    /// gangs shrunk in place (kept running at surviving width)
    pub shrinks: u64,
    /// shrunken gangs topped back up to full provisioned width
    pub regrows: u64,
    /// Σ over jobs of seconds spent running at shrunken width
    pub degraded_rate_time_s: f64,
    /// Σ checkpoint-boundary rollback across surviving members
    pub rollback_lost_s: f64,
    /// open degraded episodes: job id → shrink time
    open: HashMap<u64, f64>,
}

impl ShrinkObserver {
    fn close_episode(&mut self, id: u64, t: f64) {
        if let Some(start) = self.open.remove(&id) {
            self.degraded_rate_time_s += (t - start).max(0.0);
        }
    }
}

impl SimObserver for ShrinkObserver {
    fn on_shrink(
        &mut self,
        t: f64,
        jobs: &[u64],
        groups: u64,
        rollback_lost_s: f64,
    ) {
        self.shrinks += groups;
        self.rollback_lost_s += rollback_lost_s;
        for id in jobs {
            self.open.entry(*id).or_insert(t);
        }
    }

    fn on_regrow(&mut self, t: f64, job: u64) {
        self.regrows += 1;
        self.close_episode(job, t);
    }

    fn on_evict(
        &mut self,
        t: f64,
        job: &JobState,
        _cause: EvictCause,
        _lost_s: f64,
        _penalty_s: f64,
    ) {
        self.close_episode(job.spec.id, t);
    }

    fn on_complete(&mut self, t: f64, job: &JobState) {
        self.close_episode(job.spec.id, t);
    }

    fn on_finish(&mut self, t_end: f64, _jobs: &[&JobState]) {
        let mut open: Vec<(u64, f64)> = self.open.drain().collect();
        open.sort_unstable_by_key(|&(id, _)| id);
        for (_, start) in open {
            self.degraded_rate_time_s += (t_end - start).max(0.0);
        }
    }
}

/// Mean slowdown across jobs that ran (expected isolated steps over
/// actual steps, the §4.2 fairness metric).
#[derive(Debug, Default)]
pub struct SlowdownObserver {
    pub mean_slowdown: f64,
}

impl SimObserver for SlowdownObserver {
    fn on_finish(&mut self, _t_end: f64, jobs: &[&JobState]) {
        let mut acc = 0.0;
        let mut n = 0usize;
        for s in jobs {
            if s.running_time > 0.0 && s.iso_step_time.is_finite() {
                let exp_steps = s.running_time / s.iso_step_time;
                if s.steps_done > 0.0 && exp_steps > 0.0 {
                    acc += exp_steps / s.steps_done;
                    n += 1;
                }
            }
        }
        self.mean_slowdown =
            if n > 0 { acc / n as f64 } else { 1.0 };
    }
}

/// One fixed-width time bin of cluster load (see [`LoadObserver`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LoadBin {
    /// scheduling rounds that fell in this bin
    pub rounds: u64,
    pub admits: u64,
    pub completions: u64,
    /// peak running jobs observed at any round in the bin
    pub max_running: usize,
    /// peak queued jobs observed at any round in the bin
    pub max_queued: usize,
}

/// Time-binned load profile: admission/completion churn and peak
/// running/queue depth per fixed-width bin. Built for diurnal traces —
/// a day/night arrival cycle should show up as load-bin modulation —
/// and for million-arrival sweeps, where memory is O(makespan /
/// bin_s), never O(jobs). Purely additive: it feeds no `SimResult`
/// field, so attaching it cannot perturb canonical outputs.
#[derive(Debug)]
pub struct LoadObserver {
    bin_s: f64,
    pub bins: Vec<LoadBin>,
}

impl LoadObserver {
    pub fn new(bin_s: f64) -> LoadObserver {
        assert!(bin_s > 0.0, "bin width must be positive");
        LoadObserver {
            bin_s,
            bins: Vec::new(),
        }
    }

    fn bin_at(&mut self, t: f64) -> &mut LoadBin {
        let i = (t.max(0.0) / self.bin_s) as usize;
        if i >= self.bins.len() {
            self.bins.resize(i + 1, LoadBin::default());
        }
        &mut self.bins[i]
    }

    /// Bin width in simulated seconds.
    pub fn bin_s(&self) -> f64 {
        self.bin_s
    }

    /// Peak concurrently-running jobs across the whole run.
    pub fn peak_running(&self) -> usize {
        self.bins.iter().map(|b| b.max_running).max().unwrap_or(0)
    }
}

impl SimObserver for LoadObserver {
    fn on_admit(&mut self, t: f64, _job: &JobState) {
        self.bin_at(t).admits += 1;
    }

    fn on_complete(&mut self, t: f64, _job: &JobState) {
        self.bin_at(t).completions += 1;
    }

    fn on_round(&mut self, s: &RoundStats) {
        let (running, queued) = (s.n_running, s.n_queued);
        let bin = self.bin_at(s.t);
        bin.rounds += 1;
        bin.max_running = bin.max_running.max(running);
        bin.max_queued = bin.max_queued.max(queued);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::JobSpec;

    fn job_state(id: u64, submit: f64) -> JobState {
        JobState {
            spec: JobSpec {
                id,
                base_model: "llama3-8b".into(),
                rank: 8,
                batch_size: 4,
                seq_len: 512,
                gpus: 1,
                total_steps: 100,
                submit_time: submit,
                max_slowdown: 2.0,
            },
            steps_done: 0.0,
            iso_step_time: 1.0,
            admitted_at: None,
            completed_at: None,
            grouped_time: 0.0,
            running_time: 0.0,
            restart_at: 0.0,
            restarts: 0,
        }
    }

    #[test]
    fn timeline_windowed_vs_full_averages() {
        let mut o = TimelineObserver::default();
        let stats = |t: f64, thr: f64| RoundStats {
            t,
            inst_throughput: thr,
            busy_gpus: 0.0,
            total_gpus: 16.0,
            n_groups: 0,
            n_running: 0,
            n_queued: 0,
            probes: 0,
            plan_cache_hits: 0,
        };
        o.on_round(&stats(0.0, 10.0));
        o.on_round(&stats(100.0, 0.0)); // drain tail: zero throughput
        let done = job_state(0, 0.0);
        o.on_complete(50.0, &done);
        o.on_finish(200.0, &[]);
        let (full, _) = o.full_averages();
        // 10 samples/s for half the run, 0 for the other half
        assert!((full - 5.0).abs() < 1e-9, "{full}");
        // windowed to t90=max(50, 60)=60: only the busy stretch counts
        let (windowed, _) = o.windowed_averages(60.0);
        assert!((windowed - 10.0).abs() < 1e-9, "{windowed}");
    }

    #[test]
    fn completion_observer_tracks_incomplete() {
        let mut o = CompletionObserver::default();
        let mut a = job_state(3, 5.0);
        a.completed_at = Some(25.0);
        o.on_complete(25.0, &a);
        let b = job_state(7, 0.0); // never completed
        o.on_finish(100.0, &[&a, &b]);
        assert_eq!(o.jct, vec![(3, 20.0)]);
        assert_eq!(o.incomplete, vec![7]);
    }

    #[test]
    fn grouping_ratio_per_class() {
        let mut classes = HashMap::new();
        classes.insert(0, SizeClass::Small);
        classes.insert(1, SizeClass::Large);
        let mut o = GroupingObserver::new(classes);
        let mut a = job_state(0, 0.0);
        a.grouped_time = 30.0;
        a.running_time = 60.0;
        let mut b = job_state(1, 0.0);
        b.grouped_time = 0.0;
        b.running_time = 40.0;
        o.on_finish(100.0, &[&a, &b]);
        assert!((o.grouping_ratio["small"] - 0.5).abs() < 1e-12);
        assert_eq!(o.grouping_ratio["large"], 0.0);
    }

    #[test]
    fn slowdown_defaults_to_one_without_runners() {
        let mut o = SlowdownObserver::default();
        o.on_finish(10.0, &[]);
        assert_eq!(o.mean_slowdown, 1.0);
    }

    #[test]
    fn fault_observer_accounts_churn_and_goodput() {
        let mut o = FaultObserver::new(3.0);
        o.on_node_failure(10.0, 2);
        o.on_node_recovery(40.0, 2);
        let j = job_state(0, 0.0);
        o.on_evict(10.0, &j, EvictCause::NodeFailure, 0.4, 12.0);
        o.on_evict(20.0, &j, EvictCause::Preemption, 0.1, 12.0);
        assert_eq!(o.node_failures, 1);
        assert_eq!(o.node_recoveries, 1);
        assert_eq!(o.restarts, 2);
        assert_eq!(o.preemptions, 1);
        assert!((o.lost_step_time_s - 0.5).abs() < 1e-12);
        assert!((o.restore_delay_s - 24.0).abs() < 1e-12);
        // goodput: surviving steps x batch over makespan
        let mut a = job_state(1, 0.0); // batch 4, 100 steps
        a.steps_done = 100.0;
        a.completed_at = Some(200.0);
        let mut b = job_state(2, 0.0);
        b.steps_done = 50.0; // incomplete: still useful work
        o.on_finish(200.0, &[&a, &b]);
        let want = (100.0 * 4.0 + 50.0 * 4.0) / 200.0;
        assert!((o.goodput - want).abs() < 1e-9, "{}", o.goodput);
    }

    #[test]
    fn fault_observer_accounts_gpu_holes() {
        let mut o = FaultObserver::new(3.0);
        // device (0,1): holed over [10, 40): 30 s
        o.on_gpu_failure(10.0, 0, 1);
        o.on_gpu_recovery(40.0, 0, 1);
        // device (2,3): holed at 50, never recovered — closed at
        // t_end = 100: 50 s. A repeat (scripted) failure keeps the
        // original episode open rather than restarting the clock.
        o.on_gpu_failure(50.0, 2, 3);
        o.on_gpu_failure(70.0, 2, 3);
        // recovery of a device that never failed is a no-op
        o.on_gpu_recovery(60.0, 1, 0);
        // GPU evictions count as environment damage like node faults
        let j = job_state(0, 0.0);
        o.on_evict(50.0, &j, EvictCause::GpuFailure, 0.4, 12.0);
        o.on_finish(100.0, &[]);
        assert_eq!(o.gpu_failures, 3);
        assert_eq!(o.node_failures, 0);
        assert_eq!(o.restarts, 1);
        assert!((o.lost_step_time_s - 0.4).abs() < 1e-12);
        assert!(
            (o.holed_gpu_time_s - 80.0).abs() < 1e-9,
            "{}",
            o.holed_gpu_time_s
        );
    }

    #[test]
    fn straggler_observer_episode_accounting() {
        let mut o = StragglerObserver::new(3);
        assert_eq!(o.straggler_slowdown, 1.0);
        // node 1: degraded to 0.5 over [10, 40): 30 node-seconds at 2x
        o.on_node_degraded(10.0, 1, 0.5);
        o.on_node_restored(40.0, 1);
        // node 2: degraded to 0.25 at 50, never restored — closed at
        // t_end=100: 50 node-seconds at 4x
        o.on_node_degraded(50.0, 2, 0.25);
        // restore of a healthy node is a no-op
        o.on_node_restored(60.0, 0);
        o.on_finish(100.0, &[]);
        assert_eq!(o.node_degrades, 2);
        assert!((o.degraded_node_time_s - 80.0).abs() < 1e-9);
        // time-weighted 1/speed: (30*2 + 50*4) / 80 = 3.25
        assert!(
            (o.straggler_slowdown - 3.25).abs() < 1e-9,
            "{}",
            o.straggler_slowdown
        );
    }

    #[test]
    fn straggler_observer_repeat_degrade_resamples_severity() {
        let mut o = StragglerObserver::new(1);
        o.on_node_degraded(0.0, 0, 0.5); // [0,10) at 2x
        o.on_node_degraded(10.0, 0, 0.25); // [10,20) at 4x
        o.on_node_restored(20.0, 0);
        o.on_finish(30.0, &[]);
        assert_eq!(o.node_degrades, 2);
        assert!((o.degraded_node_time_s - 20.0).abs() < 1e-9);
        assert!(
            (o.straggler_slowdown - 3.0).abs() < 1e-9,
            "{}",
            o.straggler_slowdown
        );
    }

    #[test]
    fn straggler_observer_counts_migrations_fault_observer_does_not() {
        let mut s = StragglerObserver::new(2);
        let mut f = FaultObserver::new(3.0);
        let j = job_state(0, 0.0);
        s.on_evict(5.0, &j, EvictCause::StragglerMigration, 0.2, 3.0);
        s.on_evict(6.0, &j, EvictCause::Preemption, 0.2, 3.0);
        f.on_evict(5.0, &j, EvictCause::StragglerMigration, 0.2, 3.0);
        f.on_evict(6.0, &j, EvictCause::Preemption, 0.2, 3.0);
        assert_eq!(s.migrations, 1);
        // the fault accountant ignores voluntary migrations entirely
        assert_eq!(f.restarts, 1);
        assert_eq!(f.preemptions, 1);
        assert!((f.lost_step_time_s - 0.2).abs() < 1e-12);
        assert!((f.restore_delay_s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn shrink_observer_episode_accounting() {
        let mut o = ShrinkObserver::default();
        // job 1: degraded over [10, 40), closed by regrow: 30 s
        o.on_shrink(10.0, &[1], 1, 2.5);
        o.on_regrow(40.0, 1);
        // job 2: degraded at 50; a second shrink at 70 (another
        // device died under the same gang) keeps the original
        // episode; never regrown — closed at t_end = 100: 50 s
        o.on_shrink(50.0, &[2], 1, 0.0);
        o.on_shrink(70.0, &[2], 1, 1.5);
        // regrow of a never-shrunk job counts but opens nothing
        o.on_regrow(60.0, 9);
        o.on_finish(100.0, &[]);
        assert_eq!(o.shrinks, 3);
        assert_eq!(o.regrows, 2);
        assert!((o.rollback_lost_s - 4.0).abs() < 1e-12);
        assert!(
            (o.degraded_rate_time_s - 80.0).abs() < 1e-9,
            "{}",
            o.degraded_rate_time_s
        );
    }

    #[test]
    fn shrink_observer_eviction_and_completion_close_episodes() {
        let mut o = ShrinkObserver::default();
        let j1 = job_state(1, 0.0);
        let mut j2 = job_state(2, 0.0);
        o.on_shrink(10.0, &[1, 2], 1, 0.0);
        // job 1 spills out of the degraded gang at 30: 20 s degraded
        o.on_evict(30.0, &j1, EvictCause::GpuFailure, 0.1, 5.0);
        // job 2 completes at 60: 50 s degraded
        j2.completed_at = Some(60.0);
        o.on_complete(60.0, &j2);
        o.on_finish(100.0, &[]);
        assert!(
            (o.degraded_rate_time_s - 70.0).abs() < 1e-9,
            "{}",
            o.degraded_rate_time_s
        );
    }

    #[test]
    fn load_observer_bins_admits_and_peaks() {
        let mut o = LoadObserver::new(10.0);
        let round = |t: f64, running: usize, queued: usize| RoundStats {
            t,
            inst_throughput: 0.0,
            busy_gpus: 0.0,
            total_gpus: 16.0,
            n_groups: 0,
            n_running: running,
            n_queued: queued,
            probes: 0,
            plan_cache_hits: 0,
        };
        let j = job_state(0, 0.0);
        o.on_admit(1.0, &j);
        o.on_admit(2.0, &j);
        o.on_round(&round(3.0, 2, 5));
        o.on_round(&round(9.0, 4, 1));
        o.on_complete(25.0, &j);
        o.on_round(&round(25.0, 1, 0));
        assert_eq!(o.bins.len(), 3);
        assert_eq!(o.bins[0].admits, 2);
        assert_eq!(o.bins[0].rounds, 2);
        assert_eq!(o.bins[0].max_running, 4);
        assert_eq!(o.bins[0].max_queued, 5);
        assert_eq!(o.bins[1], LoadBin::default()); // gap bin
        assert_eq!(o.bins[2].completions, 1);
        assert_eq!(o.peak_running(), 4);
        assert!((o.bin_s() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn load_observer_is_passive_in_simulation() {
        // attach to a real run: simulate_jobs_with must produce the
        // same SimResult with and without the observer attached
        use crate::config::ExperimentConfig;
        use crate::sim::{simulate_jobs_with, EngineOptions};
        use crate::workload::{TraceGenerator, TraceProfile};

        let cfg = ExperimentConfig::default();
        let jobs = TraceGenerator::new(TraceProfile::month1(), 3)
            .generate(20);
        let mut load = LoadObserver::new(600.0);
        let with = simulate_jobs_with(
            &cfg,
            jobs.clone(),
            &EngineOptions::default(),
            &mut [&mut load],
        );
        let without = simulate_jobs_with(
            &cfg,
            jobs,
            &EngineOptions::default(),
            &mut [],
        );
        assert_eq!(with.jct, without.jct);
        assert_eq!(with.makespan, without.makespan);
        assert!(load.bins.iter().map(|b| b.rounds).sum::<u64>() > 0);
        assert!(load.peak_running() > 0);
    }

    #[test]
    fn fault_observer_slo_attainment() {
        let o = FaultObserver::new(2.0);
        // iso 1.0 s/step, 100 steps, Δ^max 2.0, factor 2.0:
        // deadline = submit + 2.0 * 2.0 * 100 * 1.0 = submit + 400
        let mut on_time = job_state(0, 0.0);
        on_time.spec.max_slowdown = 2.0;
        on_time.completed_at = Some(300.0);
        let mut late = job_state(1, 0.0);
        late.spec.max_slowdown = 2.0;
        late.completed_at = Some(500.0);
        let never = job_state(2, 0.0); // incomplete: a miss
        let mut o2 = o;
        assert_eq!(o2.deadline_of(&on_time), Some(400.0));
        o2.on_finish(600.0, &[&on_time, &late, &never]);
        assert!((o2.slo_attainment - 1.0 / 3.0).abs() < 1e-12);
        // no jobs: vacuously attained
        let mut o3 = FaultObserver::new(2.0);
        o3.on_finish(0.0, &[]);
        assert_eq!(o3.slo_attainment, 1.0);
    }
}
