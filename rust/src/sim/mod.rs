//! Trace-driven discrete-event cluster simulator.
//!
//! Plays the paper's role of the Sailor-based emulation (§4.1): jobs
//! arrive from a trace, the active policy groups them each scheduling
//! horizon, groups execute at the step time predicted by the
//! planner/kernelsim stack (calibrated against real PJRT measurements —
//! Fig. 10), and the simulator accounts throughput, per-job completion
//! times, and GPU utilization.
//!
//! Time advances horizon-by-horizon (default 60 s); within a horizon
//! every running group progresses analytically at its current step rate,
//! with completions interpolated exactly. The AIMD controller of each
//! group observes one step time per executed step (capped per horizon)
//! and adapts its nano-batch count online.

use std::collections::HashMap;

use crate::baselines::dispatch;
use crate::cluster::{Allocation, Allocator};
use crate::config::{ExperimentConfig, Policy};
use crate::kernelsim::AimdController;
use crate::planner::{PlanOptions};
use crate::scheduler::predictor::Predictor;
use crate::scheduler::{urgency, Candidate};
use crate::ssm::Ssm;
use crate::util::stats::{Summary, TimeWeighted};
use crate::workload::{classify, JobSpec, SizeClass};
use crate::workload::trace::TraceGenerator;

/// Per-job bookkeeping during the run.
#[derive(Debug, Clone)]
struct JobState {
    spec: JobSpec,
    steps_done: f64,
    /// isolated-execution step time on its provisioned GPUs (slowdown
    /// reference), computed lazily at admission
    iso_step_time: f64,
    admitted_at: Option<f64>,
    completed_at: Option<f64>,
    /// seconds spent in a group of size > 1
    grouped_time: f64,
    running_time: f64,
}

/// A group currently executing.
#[derive(Debug)]
struct RunningGroup {
    job_ids: Vec<u64>,
    alloc: Allocation,
    step_time: f64,
    compute_util: f64,
    aimd: Option<AimdController>,
    /// comp/comm decomposition for online AIMD re-evaluation
    comp_s: f64,
    comm_s: f64,
    oh: f64,
    lat: f64,
}

/// Simulation results — everything the paper's figures plot.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub policy: Policy,
    /// (job id, completion time - submit time)
    pub jct: Vec<(u64, f64)>,
    pub mean_jct: f64,
    pub p99_jct: f64,
    /// time-averaged cluster throughput (samples/s)
    pub avg_throughput: f64,
    /// (time, samples/s) series
    pub throughput_timeline: Vec<(f64, f64)>,
    /// time-averaged GPU utilization in [0,1]
    pub avg_gpu_util: f64,
    pub util_timeline: Vec<(f64, f64)>,
    /// wall-clock until the last job completes
    pub makespan: f64,
    /// per size-class grouping ratio (Fig. 6b): fraction of running
    /// time each class spent co-located
    pub grouping_ratio: HashMap<&'static str, f64>,
    /// total scheduler probes (cost diagnostics)
    pub scheduler_probes: u64,
    pub horizons: u64,
    /// mean slowdown across jobs that ran grouped
    pub mean_slowdown: f64,
}

impl SimResult {
    pub fn jct_values(&self) -> Vec<f64> {
        self.jct.iter().map(|&(_, v)| v).collect()
    }
}

/// Run one simulation for `cfg`.
pub fn simulate(cfg: &ExperimentConfig) -> SimResult {
    let jobs = TraceGenerator::new(cfg.trace.clone(), cfg.seed)
        .generate(cfg.n_jobs);
    simulate_jobs(cfg, jobs)
}

/// Run one simulation over an explicit job list (benches build custom
/// workloads; `simulate` feeds the generated trace).
pub fn simulate_jobs(cfg: &ExperimentConfig, jobs: Vec<JobSpec>)
    -> SimResult {
    let policy = cfg.policy;
    let opts = PlanOptions {
        fused_kernel: policy.uses_kernel_fuser(),
        // AIMD drives n online; None would use the oracle. Start at 1.
        n_nano: Some(cfg.aimd.n0),
        n_nano_max: cfg.aimd.n_max,
    };
    let mut predictor = Predictor::new(cfg.cluster.clone(), opts);
    let mut allocator = Allocator::new(cfg.cluster.clone());

    let size_classes: HashMap<u64, SizeClass> =
        classify(&jobs).into_iter().collect();

    let mut pending: Vec<JobSpec> = jobs.clone();
    pending.sort_by(|a, b| {
        crate::util::f64_cmp(b.submit_time, a.submit_time)
    }); // reversed: pop() takes earliest
    let mut states: HashMap<u64, JobState> = jobs
        .iter()
        .map(|j| {
            (
                j.id,
                JobState {
                    spec: j.clone(),
                    steps_done: 0.0,
                    iso_step_time: 0.0,
                    admitted_at: None,
                    completed_at: None,
                    grouped_time: 0.0,
                    running_time: 0.0,
                },
            )
        })
        .collect();

    let mut queue: Vec<u64> = vec![]; // arrived, waiting for GPUs
    let mut allocations: HashMap<u64, Allocation> = HashMap::new();
    let mut running: Vec<RunningGroup> = vec![];
    let mut completed = 0usize;

    let mut t = 0.0f64;
    let horizon = cfg.scheduler.horizon_s;
    let mut horizons = 0u64;

    let mut thr_tl: Vec<(f64, f64)> = vec![];
    let mut util_tl: Vec<(f64, f64)> = vec![];
    let mut thr_acc = TimeWeighted::default();
    let mut util_acc = TimeWeighted::default();
    let total_gpus = cfg.cluster.total_gpus() as f64;

    // safety valve: generous upper bound on simulated time
    let t_max = (jobs
        .iter()
        .map(|j| j.submit_time)
        .fold(0.0f64, f64::max)
        + 1.0)
        * 50.0
        + 1e7;

    while completed < jobs.len() && t < t_max {
        // ---- 1. admit arrivals up to t ----
        while pending
            .last()
            .map_or(false, |j| j.submit_time <= t)
        {
            let j = pending.pop().unwrap();
            queue.push(j.id);
        }

        // ---- 1b. dissolve shared placements: group members without
        // owned GPUs return to the queue and are re-admitted below
        // (step 2 may even give them their own allocation now — the
        // elastic "reclaim resources later" of §3.4). Progress and
        // admission timestamps persist in `states`.
        for g in &running {
            for id in &g.job_ids {
                if !allocations.contains_key(id)
                    && states[id].completed_at.is_none()
                {
                    queue.push(*id);
                }
            }
        }

        // ---- 2. allocate GPUs to queued jobs (FIFO; id breaks
        // submit-time ties so the order never depends on map order) ----
        queue.sort_by(|a, b| {
            crate::util::f64_cmp(
                states[a].spec.submit_time,
                states[b].spec.submit_time,
            )
            .then(a.cmp(b))
        });
        let mut still_queued = vec![];
        // owned, uncompleted jobs (shared members are re-queued above
        // and counted as they are re-admitted)
        let running_count: usize = allocations
            .iter()
            .filter(|(id, _)| states[id].completed_at.is_none())
            .count();
        let mut admitted_now = 0usize;
        for id in queue.drain(..) {
            let spec = states[&id].spec.clone();
            let cap_ok = running_count + admitted_now
                < cfg.max_concurrent_jobs;
            if cap_ok {
                if let Some(a) = allocator.allocate(spec.gpus) {
                    let iso = predictor
                        .isolated_step_time(&spec, &a)
                        .unwrap_or(f64::INFINITY);
                    let st = states.get_mut(&id).unwrap();
                    st.admitted_at = Some(t);
                    st.iso_step_time = iso;
                    allocations.insert(id, a);
                    admitted_now += 1;
                    continue;
                }
            }
            still_queued.push(id);
        }
        queue = still_queued;

        // ---- 3. (re)group all admitted, unfinished jobs ----
        // Walk allocations in job-id order: HashMap iteration order is
        // nondeterministic per instance, and the candidate order feeds
        // the scheduler's tie-breaking — bit-identical reruns (and the
        // sweep engine's cross-thread determinism) require a canonical
        // order here.
        let mut candidates = vec![];
        let mut alloc_ids: Vec<u64> = allocations.keys().copied().collect();
        alloc_ids.sort_unstable();
        for id in alloc_ids {
            let a = &allocations[&id];
            let st = &states[&id];
            if st.completed_at.is_some() {
                continue;
            }
            // current slowdown estimate from the group it last ran in
            let cur_slow = running
                .iter()
                .find(|g| g.job_ids.contains(&id))
                .map(|g| g.step_time / st.iso_step_time.max(1e-12))
                .unwrap_or(1.0);
            let wait_frac = if t > st.spec.submit_time {
                (t - st.admitted_at.unwrap_or(t))
                    .max(0.0)
                    .min(t - st.spec.submit_time)
                    / (t - st.spec.submit_time)
            } else {
                0.0
            };
            let residual = predictor
                .residual(&st.spec, a)
                .unwrap_or(0.5);
            candidates.push(Candidate {
                job: st.spec.clone(),
                alloc: a.clone(),
                urgency: urgency(
                    cur_slow,
                    st.spec.max_slowdown,
                    wait_frac,
                ),
                residual,
            });
        }
        let outcome =
            dispatch(policy, candidates, &mut predictor, &cfg.scheduler);
        let mut new_groups = outcome.groups;

        // ---- 3b. elastic admission (the Shared Super-Model's headline
        // mechanism): jobs still queued because no GPUs are free can be
        // absorbed into an existing group, sharing its GPUs.
        //   tLoRA: best group by predicted merged throughput, subject to
        //          every member's Δ^max (progress guard);
        //   mLoRA/w-o-Scheduler: first group whose memory fits (FIFO);
        //   Megatron: never shares.
        if policy.groups_jobs() {
            let mut still = vec![];
            let mut shared_now = 0usize;
            for id in queue.drain(..) {
                let n_running: usize =
                    new_groups.iter().map(|(g, _)| g.jobs.len()).sum();
                if n_running + shared_now >= cfg.max_concurrent_jobs {
                    still.push(id);
                    continue;
                }
                let spec = states[&id].spec.clone();
                let mut choice: Option<(usize, f64)> = None;
                for (gi, (g, perf)) in new_groups.iter().enumerate() {
                    if g.jobs.len() >= cfg.scheduler.max_group_size
                        || g.jobs[0].base_model != spec.base_model
                    {
                        continue;
                    }
                    let mut jobs2 = g.jobs.clone();
                    jobs2.push(spec.clone());
                    let Some(merged) =
                        predictor.group_perf(&jobs2, &g.alloc)
                    else {
                        continue;
                    };
                    if policy.uses_tlora_scheduler() {
                        // protect the *existing* members' Δ^max; the
                        // newcomer is queued — any progress beats zero,
                        // so its own slowdown bound cannot veto
                        // admission (starvation avoidance, §3.4)
                        if !merged.within_slowdown(&g.jobs) {
                            continue;
                        }
                        let gain = merged.throughput_samples_s
                            / perf.throughput_samples_s;
                        if gain <= 1.0 {
                            continue;
                        }
                        if choice.map_or(true, |(_, g0)| gain > g0) {
                            choice = Some((gi, gain));
                        }
                    } else {
                        // mLoRA: memory fits → take it, FIFO
                        choice = Some((gi, 1.0));
                        break;
                    }
                }
                match choice {
                    Some((gi, _)) => {
                        let (g, _) = &mut new_groups[gi];
                        g.jobs.push(spec.clone());
                        let alloc = g.alloc.clone();
                        let perf2 = predictor
                            .group_perf(&g.jobs, &alloc)
                            .expect("feasible merge vanished");
                        let iso = {
                            let sub = Allocation {
                                gpus: alloc
                                    .gpus
                                    .iter()
                                    .take(spec.gpus.max(1))
                                    .cloned()
                                    .collect(),
                            };
                            predictor
                                .isolated_step_time(&spec, &sub)
                                .unwrap_or(f64::INFINITY)
                        };
                        let st = states.get_mut(&id).unwrap();
                        if st.admitted_at.is_none() {
                            st.admitted_at = Some(t);
                            st.iso_step_time = iso;
                        }
                        new_groups[gi].1 = perf2;
                        shared_now += 1;
                    }
                    None => still.push(id),
                }
            }
            queue = still;
        }

        // carry over AIMD controllers keyed by group membership
        let mut prev_aimd: HashMap<Vec<u64>, AimdController> = running
            .drain(..)
            .filter_map(|g| {
                let mut ids = g.job_ids.clone();
                ids.sort_unstable();
                g.aimd.map(|c| (ids, c))
            })
            .collect();

        for (g, perf) in new_groups {
            let mut ids: Vec<u64> =
                g.jobs.iter().map(|j| j.id).collect();
            ids.sort_unstable();
            let aimd = if policy.uses_kernel_fuser() {
                Some(prev_aimd.remove(&ids).unwrap_or_else(|| {
                    AimdController::new(cfg.aimd.clone())
                }))
            } else {
                None
            };
            let gpu = &cfg.cluster.gpu;
            let lat = if g.alloc.spans_nodes() {
                cfg.cluster.ib_latency_s
            } else {
                1e-6
            };
            running.push(RunningGroup {
                job_ids: ids,
                alloc: g.alloc,
                step_time: perf.step_time_s,
                compute_util: perf.compute_util,
                comp_s: perf.plan.comp_s,
                comm_s: perf.plan.comm_s,
                oh: gpu.launch_overhead_s * 4.0,
                lat,
                aimd,
            });
        }

        // ---- 4. advance one horizon ----
        let dt = horizon;
        let mut inst_thr = 0.0;
        let mut busy_util = 0.0;
        for g in &mut running {
            // AIMD: evolve the nano count over the steps this horizon
            if let Some(c) = &mut g.aimd {
                let steps = (dt / g.step_time).max(1.0).min(16.0) as usize;
                for _ in 0..steps {
                    let t_step = crate::kernelsim::overlap::iter_time(
                        g.comp_s, g.comm_s, c.n(), g.oh, g.lat,
                    );
                    c.observe(t_step);
                }
                g.step_time = crate::kernelsim::overlap::iter_time(
                    g.comp_s, g.comm_s, c.n(), g.oh, g.lat,
                );
            }
            let batch: f64 = g
                .job_ids
                .iter()
                .map(|id| states[id].spec.batch_size as f64)
                .sum();
            inst_thr += batch / g.step_time;
            busy_util += g.compute_util * g.alloc.n_gpus() as f64;

            let grouped = g.job_ids.len() > 1;
            for id in &g.job_ids {
                let st = states.get_mut(id).unwrap();
                if st.completed_at.is_some() {
                    continue;
                }
                let before = st.steps_done;
                st.steps_done += dt / g.step_time;
                st.running_time += dt;
                if grouped {
                    st.grouped_time += dt;
                }
                if st.steps_done >= st.spec.total_steps as f64 {
                    // interpolate exact completion inside the horizon
                    let need = st.spec.total_steps as f64 - before;
                    let t_done = t + need * g.step_time;
                    st.completed_at = Some(t_done);
                    completed += 1;
                }
            }
        }
        thr_acc.add(t, inst_thr);
        util_acc.add(t, busy_util / total_gpus);
        thr_tl.push((t, inst_thr));
        util_tl.push((t, (busy_util / total_gpus).min(1.0)));

        // ---- 5. release completed jobs' GPUs; drop finished groups ----
        let mut freed = vec![];
        for g in &mut running {
            g.job_ids.retain(|id| {
                let done = states[id].completed_at.is_some();
                if done {
                    freed.push(*id);
                }
                !done
            });
        }
        running.retain(|g| !g.job_ids.is_empty());
        for id in freed {
            if let Some(a) = allocations.remove(&id) {
                allocator.release(&a);
            }
        }

        t += dt;
        horizons += 1;
    }

    // ---- collect results ----
    let mut jct: Vec<(u64, f64)> = states
        .values()
        .filter_map(|s| {
            s.completed_at.map(|c| (s.spec.id, c - s.spec.submit_time))
        })
        .collect();
    jct.sort_by_key(|&(id, _)| id);
    let jvals: Vec<f64> = jct.iter().map(|&(_, v)| v).collect();
    let summary = Summary::of(&jvals);

    // Utilization / throughput are averaged over the *steady* window —
    // up to the 90th-percentile completion — so a finite trace's drain
    // tail (a few stragglers on an otherwise empty cluster) does not
    // wash out the signal. The original trace replays a full month and
    // has no such boundary.
    let mut completions: Vec<f64> =
        states.values().filter_map(|s| s.completed_at).collect();
    completions.sort_by(|a, b| crate::util::f64_cmp(*a, *b));
    let t90 = crate::util::stats::percentile_sorted(&completions, 0.90)
        .max(horizon);
    let window_avg = |tl: &[(f64, f64)]| -> f64 {
        let mut acc = TimeWeighted::default();
        for &(ts, v) in tl.iter().filter(|&&(ts, _)| ts <= t90) {
            acc.add(ts, v);
        }
        acc.finish(t90)
    };

    // Final accumulations also walk jobs in id order: f64 addition is
    // not associative-in-bits, so summing in HashMap order would make
    // two identical runs differ in the last ulp (the sweep engine
    // guarantees bit-identical results across runs and thread counts).
    let mut state_ids: Vec<u64> = states.keys().copied().collect();
    state_ids.sort_unstable();

    let mut class_grouped: HashMap<&'static str, (f64, f64)> =
        HashMap::new();
    for id in &state_ids {
        let s = &states[id];
        let class = match size_classes.get(&s.spec.id) {
            Some(SizeClass::Small) => "small",
            Some(SizeClass::Medium) => "medium",
            Some(SizeClass::Large) => "large",
            None => continue,
        };
        let e = class_grouped.entry(class).or_insert((0.0, 0.0));
        e.0 += s.grouped_time;
        e.1 += s.running_time;
    }
    let grouping_ratio = class_grouped
        .into_iter()
        .map(|(k, (g, r))| (k, if r > 0.0 { g / r } else { 0.0 }))
        .collect();

    let mean_slowdown = {
        let mut acc = 0.0;
        let mut n = 0usize;
        for id in &state_ids {
            let s = &states[id];
            if s.running_time > 0.0 && s.iso_step_time.is_finite() {
                let exp_steps = s.running_time / s.iso_step_time;
                if s.steps_done > 0.0 && exp_steps > 0.0 {
                    acc += exp_steps / s.steps_done;
                    n += 1;
                }
            }
        }
        if n > 0 {
            acc / n as f64
        } else {
            1.0
        }
    };

    // full-run accumulators retained for diagnostics
    let _ = thr_acc.finish(t);
    let _ = util_acc.finish(t);

    SimResult {
        policy,
        mean_jct: summary.mean,
        p99_jct: summary.p99,
        jct,
        avg_throughput: window_avg(&thr_tl),
        throughput_timeline: thr_tl,
        avg_gpu_util: window_avg(&util_tl),
        util_timeline: util_tl,
        makespan: t,
        grouping_ratio,
        scheduler_probes: predictor.probes,
        horizons,
        mean_slowdown,
    }
}

/// Convenience: throughput of an explicit static group on an explicit
/// allocation — the Fig. 2 micro-experiment ("naive batching may hurt").
/// `spread_nodes` places one GPU per node (cross-node grouping, the
/// §2 regression mechanism); otherwise GPUs pack into one node.
/// When the policy has no Kernel Fuser the group runs serially (naive
/// batching: no nano-batch overlap, per-adapter kernels).
pub fn static_group_throughput(
    cfg: &ExperimentConfig,
    jobs: &[JobSpec],
    n_gpus: usize,
    spread_nodes: bool,
) -> Option<f64> {
    let opts = PlanOptions {
        fused_kernel: cfg.policy.uses_kernel_fuser(),
        n_nano: None,
        n_nano_max: cfg.aimd.n_max,
    };
    let a = if spread_nodes {
        if n_gpus > cfg.cluster.n_nodes {
            return None;
        }
        Allocation {
            gpus: (0..n_gpus)
                .map(|node| crate::cluster::GpuId { node, idx: 0 })
                .collect(),
        }
    } else {
        let mut alloc = Allocator::new(cfg.cluster.clone());
        alloc.allocate(n_gpus)?
    };
    let ssm = Ssm::fuse(jobs).ok()?;
    let p = crate::planner::plan(&ssm, &a, &cfg.cluster, &opts).ok()?;
    Some(
        jobs.iter().map(|j| j.batch_size as f64).sum::<f64>()
            / p.step_time_s,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::TraceProfile;

    fn small_cfg(policy: Policy) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = policy;
        cfg.cluster = crate::cluster::ClusterSpec::with_gpus(16);
        cfg.n_jobs = 20;
        cfg.trace = TraceProfile::month1().scaled(4.0);
        cfg.seed = 7;
        cfg
    }

    #[test]
    fn all_jobs_complete() {
        for policy in [Policy::TLora, Policy::MLora, Policy::Megatron] {
            let cfg = small_cfg(policy);
            let r = simulate(&cfg);
            assert_eq!(
                r.jct.len(),
                cfg.n_jobs,
                "{policy:?}: {} of {} completed",
                r.jct.len(),
                cfg.n_jobs
            );
            assert!(r.mean_jct > 0.0);
            assert!(r.makespan > 0.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg(Policy::TLora);
        let a = simulate(&cfg);
        let b = simulate(&cfg);
        assert_eq!(a.jct, b.jct);
        assert_eq!(a.horizons, b.horizons);
    }

    #[test]
    fn tlora_beats_megatron_on_throughput() {
        let r_t = simulate(&small_cfg(Policy::TLora));
        let r_m = simulate(&small_cfg(Policy::Megatron));
        assert!(
            r_t.avg_throughput > r_m.avg_throughput * 0.95,
            "tLoRA {} vs Megatron {}",
            r_t.avg_throughput,
            r_m.avg_throughput
        );
    }

    #[test]
    fn tlora_improves_mean_jct_vs_megatron() {
        let r_t = simulate(&small_cfg(Policy::TLora));
        let r_m = simulate(&small_cfg(Policy::Megatron));
        assert!(
            r_t.mean_jct <= r_m.mean_jct * 1.05,
            "tLoRA {} vs Megatron {}",
            r_t.mean_jct,
            r_m.mean_jct
        );
    }

    #[test]
    fn utilization_in_bounds() {
        let r = simulate(&small_cfg(Policy::TLora));
        assert!(r.avg_gpu_util >= 0.0 && r.avg_gpu_util <= 1.0);
        for &(_, u) in &r.util_timeline {
            assert!((0.0..=1.0).contains(&u));
        }
    }

    #[test]
    fn throughput_timeline_nonempty_and_nonnegative() {
        let r = simulate(&small_cfg(Policy::TLora));
        assert!(!r.throughput_timeline.is_empty());
        assert!(r.throughput_timeline.iter().all(|&(_, v)| v >= 0.0));
    }

    #[test]
    fn static_group_throughput_works() {
        let cfg = small_cfg(Policy::TLora);
        let jobs: Vec<JobSpec> = TraceGenerator::new(
            TraceProfile::month1(),
            3,
        )
        .generate(2);
        let thr = static_group_throughput(&cfg, &jobs, 2, false);
        assert!(thr.is_some());
        assert!(thr.unwrap() > 0.0);
        // cross-node placement pays IB communication: never faster
        let spread = static_group_throughput(&cfg, &jobs, 2, true);
        assert!(spread.unwrap() <= thr.unwrap() * 1.001);
    }
}
