//! Trace-driven discrete-event cluster simulator.
//!
//! Plays the paper's role of the Sailor-based emulation (§4.1): jobs
//! arrive from a trace, the active policy groups them (via
//! [`crate::scheduler::PolicyHooks`]), groups execute at the step time
//! predicted by the planner/kernelsim stack (calibrated against real
//! PJRT measurements — Fig. 10), and observers account throughput,
//! per-job completion times, and GPU utilization.
//!
//! The simulator is event-driven (§3.4's online reactive scheduler):
//! time advances straight to the next arrival / exact completion /
//! node or single-GPU failure / recovery / preemption / reschedule
//! point instead of
//! ticking a fixed horizon, with `scheduler.horizon_s` acting as the
//! *maximum* interval between scheduling rounds. The fault subsystem
//! (`config::FaultConfig` + `workload::faults`) injects seeded node
//! *and per-GPU* churn and preemptions; evicted jobs pay a
//! checkpoint-restore
//! penalty from the adapter-only size model and requeue, and each
//! policy reacts through its ordinary `PolicyHooks` dispatch (tLoRA
//! re-fuses elastically, mLoRA repacks FIFO, Megatron restarts in
//! isolation). The straggler subsystem (`config::StragglerConfig` +
//! `workload::faults::StragglerModel`) degrades nodes *partially*:
//! groups touching a degraded node run at its sampled speed
//! multiplier, the `scheduler::NodeSpeedEstimator` reconstructs the
//! slowdown from observed step times, and detection-aware policies
//! route placements around (and migrate off) suspected stragglers
//! while oblivious baselines keep crawling. See [`events`] for the
//! determinism tie-break rule,
//! [`engine`] for the loop, [`state`] for the bookkeeping, and
//! [`observer`] for the metric-collection contract.

pub mod engine;
pub mod events;
pub mod observer;
pub mod state;

pub use engine::{Engine, EngineOptions};
pub use observer::{
    EvictCause, FaultObserver, LoadBin, LoadObserver, RoundStats,
    ShrinkObserver, SimObserver, StragglerObserver,
};
pub use state::{Eviction, JobState, RunningGroup, SimState};

use std::collections::HashMap;

use crate::cluster::{Allocation, Allocator};
use crate::config::{ExperimentConfig, Policy};
use crate::planner::{ParallelPlan, PlanOptions};
use crate::ssm::Ssm;
use crate::workload::trace::TraceGenerator;
use crate::workload::JobSpec;

/// Simulation results — everything the paper's figures plot, assembled
/// from the engine's observers.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub policy: Policy,
    /// (job id, completion time - submit time)
    pub jct: Vec<(u64, f64)>,
    pub mean_jct: f64,
    pub p99_jct: f64,
    /// time-averaged cluster throughput (samples/s) over the steady
    /// window (up to the 90th-percentile completion)
    pub avg_throughput: f64,
    /// full-run time-averaged throughput, drain tail included
    pub avg_throughput_full: f64,
    /// (time, samples/s) series
    pub throughput_timeline: Vec<(f64, f64)>,
    /// time-averaged GPU utilization in [0,1] over the steady window
    pub avg_gpu_util: f64,
    /// full-run time-averaged GPU utilization, drain tail included
    pub avg_gpu_util_full: f64,
    pub util_timeline: Vec<(f64, f64)>,
    /// wall-clock until the last processed event
    pub makespan: f64,
    /// per size-class grouping ratio (Fig. 6b): fraction of running
    /// time each class spent co-located
    pub grouping_ratio: HashMap<&'static str, f64>,
    /// planner evaluations — the predictor's shape-level cache
    /// misses (the `sched_scaling` bench's gated quantity)
    pub scheduler_probes: u64,
    /// predictor queries absorbed by the exact + shape cache levels
    /// (`hits / (hits + probes)` is the cache hit-rate)
    pub plan_cache_hits: u64,
    /// scheduling rounds the engine ran (the event-driven analogue of
    /// the old per-horizon iteration count)
    pub sched_rounds: u64,
    /// events processed (arrivals, completions, node failures /
    /// recoveries, preemptions, reschedule points)
    pub events: u64,
    /// stale events discarded on pop (superseded completions /
    /// reschedule points — the dirty-set re-derivation's heap-churn
    /// diagnostic)
    pub events_stale: u64,
    /// jobs that never completed (unsatisfiable requests or the `t_max`
    /// safety valve) — previously these vanished from `jct` silently
    pub incomplete_jobs: Vec<u64>,
    /// mean slowdown across jobs that ran grouped
    pub mean_slowdown: f64,
    /// node-failure events applied (fault subsystem; 0 with faults off)
    pub node_failures: u64,
    /// single-GPU failure events applied (sub-node fault axis; 0 with
    /// GPU faults off — node failures are counted separately above)
    pub gpu_failures: u64,
    /// total device-seconds GPUs spent individually holed (episodes
    /// open at run end close at the makespan; 0 with GPU faults off)
    pub holed_gpu_time_s: f64,
    /// preemption evictions applied (no-op preemptions excluded)
    pub preemptions: u64,
    /// total evictions — node failures + preemptions; each charged a
    /// checkpoint-restore penalty
    pub restarts: u64,
    /// simulated seconds of in-flight work rolled back at evictions
    pub lost_step_time_s: f64,
    /// total checkpoint-restore delay charged across evictions
    pub restore_delay_s: f64,
    /// useful samples/s over the whole run (rolled-back work excluded)
    pub goodput: f64,
    /// fraction of jobs finishing within their SLO deadline
    /// (`faults.slo_factor` × Δ^max × ideal runtime past submission)
    pub slo_attainment: f64,
    /// straggler degrade events applied (0 with stragglers off)
    pub node_degrades: u64,
    /// total node-seconds spent degraded (episodes open at run end
    /// are closed at the makespan)
    pub degraded_node_time_s: f64,
    /// time-weighted mean of `1/speed` over the degraded node-time
    /// (1.0 when no node ever degraded)
    pub straggler_slowdown: f64,
    /// voluntary straggler-migration evictions performed by
    /// detection-aware policies (0 for oblivious runs)
    pub migrations: u64,
    /// gangs shrunk in place under single-GPU failures (graceful
    /// degradation; 0 unless `faults.shrink` is set *and* the policy
    /// can shrink — `PolicyHooks::shrinks_in_place`)
    pub shrinks: u64,
    /// shrunken gangs regrown to their full provisioned width
    /// (device recovery or free-pool backfill)
    pub regrows: u64,
    /// Σ over jobs of simulated seconds spent training at shrunken
    /// width (degraded rate); 0 with shrink off
    pub degraded_rate_time_s: f64,
    /// per-hardware-tier time-averaged GPU utilization in [0,1],
    /// ordered by tier index (`(tier name, utilization)`). Empty on
    /// uniform-reference clusters — the accumulators are never even
    /// constructed there, so homogeneous runs stay byte-identical to
    /// pre-tier builds.
    pub tier_util: Vec<(String, f64)>,
    /// mean number of racks spanned per running gang, sampled once per
    /// gang per scheduling round (0.0 on flat topologies — the tracker
    /// is never constructed there, keeping the flat path byte-stable)
    pub rack_span_mean: f64,
    /// maximum racks any gang ever spanned (0 on flat topologies)
    pub rack_span_max: u64,
}

impl SimResult {
    pub fn jct_values(&self) -> Vec<f64> {
        self.jct.iter().map(|&(_, v)| v).collect()
    }

    /// Fraction of predictor queries served from either cache level:
    /// `plan_cache_hits / (plan_cache_hits + scheduler_probes)`
    /// (0.0 when no queries ran). The cell-aggregated counterpart is
    /// `sweep::CellSummary::cache_hit_rate`.
    pub fn plan_cache_rate(&self) -> f64 {
        let total = self.plan_cache_hits + self.scheduler_probes;
        if total == 0 {
            0.0
        } else {
            self.plan_cache_hits as f64 / total as f64
        }
    }
}

/// Run one simulation for `cfg`.
pub fn simulate(cfg: &ExperimentConfig) -> SimResult {
    let jobs = TraceGenerator::new(cfg.trace.clone(), cfg.seed)
        .generate(cfg.n_jobs);
    simulate_jobs(cfg, jobs)
}

/// Run one simulation over an explicit job list (benches build custom
/// workloads; `simulate` feeds the generated trace).
pub fn simulate_jobs(
    cfg: &ExperimentConfig,
    jobs: Vec<JobSpec>,
) -> SimResult {
    simulate_jobs_with(cfg, jobs, &EngineOptions::default(), &mut [])
}

/// Full-control entry point: engine options plus extra observers that
/// see the same event stream as the built-in metric collectors.
pub fn simulate_jobs_with(
    cfg: &ExperimentConfig,
    jobs: Vec<JobSpec>,
    opts: &EngineOptions,
    extra: &mut [&mut dyn SimObserver],
) -> SimResult {
    Engine::new(cfg, jobs, opts.clone()).run(extra)
}

/// The parallel plan of an explicit static group on an explicit
/// allocation — the Fig. 2 micro-experiment ("naive batching may
/// hurt"). `spread_nodes` places one GPU per node (cross-node grouping,
/// the §2 regression mechanism); otherwise GPUs pack into one node.
/// Returning the full plan (not just throughput) lets callers assert
/// on the model's comp/comm decomposition directly.
pub fn static_group_plan(
    cfg: &ExperimentConfig,
    jobs: &[JobSpec],
    n_gpus: usize,
    spread_nodes: bool,
) -> Option<ParallelPlan> {
    let opts = PlanOptions {
        fused_kernel: cfg.policy.uses_kernel_fuser(),
        n_nano: None,
        n_nano_max: cfg.aimd.n_max,
    };
    let a = if spread_nodes {
        if n_gpus > cfg.cluster.n_nodes {
            return None;
        }
        Allocation {
            gpus: (0..n_gpus)
                .map(|node| crate::cluster::GpuId { node, idx: 0 })
                .collect(),
        }
    } else {
        let mut alloc = Allocator::new(cfg.cluster.clone());
        alloc.allocate(n_gpus)?
    };
    let ssm = Ssm::fuse(jobs).ok()?;
    crate::planner::plan(&ssm, &a, &cfg.cluster, &opts).ok()
}

/// Throughput of an explicit static group (samples/s). When the policy
/// has no Kernel Fuser the group runs serially (naive batching: no
/// nano-batch overlap, per-adapter kernels).
pub fn static_group_throughput(
    cfg: &ExperimentConfig,
    jobs: &[JobSpec],
    n_gpus: usize,
    spread_nodes: bool,
) -> Option<f64> {
    let p = static_group_plan(cfg, jobs, n_gpus, spread_nodes)?;
    Some(
        jobs.iter().map(|j| j.batch_size as f64).sum::<f64>()
            / p.step_time_s,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::TraceProfile;

    fn small_cfg(policy: Policy) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.policy = policy;
        cfg.cluster = crate::cluster::ClusterSpec::with_gpus(16);
        cfg.n_jobs = 20;
        cfg.trace = TraceProfile::month1().scaled(4.0);
        cfg.seed = 7;
        cfg
    }

    #[test]
    fn all_jobs_complete() {
        for policy in [Policy::TLora, Policy::MLora, Policy::Megatron] {
            let cfg = small_cfg(policy);
            let r = simulate(&cfg);
            assert_eq!(
                r.jct.len(),
                cfg.n_jobs,
                "{policy:?}: {} of {} completed",
                r.jct.len(),
                cfg.n_jobs
            );
            assert!(r.incomplete_jobs.is_empty(), "{policy:?}");
            assert!(r.mean_jct > 0.0);
            assert!(r.makespan > 0.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg(Policy::TLora);
        let a = simulate(&cfg);
        let b = simulate(&cfg);
        assert_eq!(a.jct, b.jct);
        assert_eq!(a.sched_rounds, b.sched_rounds);
        assert_eq!(a.events, b.events);
        assert_eq!(a.scheduler_probes, b.scheduler_probes);
    }

    #[test]
    fn tlora_beats_megatron_on_throughput() {
        let r_t = simulate(&small_cfg(Policy::TLora));
        let r_m = simulate(&small_cfg(Policy::Megatron));
        assert!(
            r_t.avg_throughput > r_m.avg_throughput * 0.95,
            "tLoRA {} vs Megatron {}",
            r_t.avg_throughput,
            r_m.avg_throughput
        );
    }

    #[test]
    fn tlora_improves_mean_jct_vs_megatron() {
        let r_t = simulate(&small_cfg(Policy::TLora));
        let r_m = simulate(&small_cfg(Policy::Megatron));
        assert!(
            r_t.mean_jct <= r_m.mean_jct * 1.05,
            "tLoRA {} vs Megatron {}",
            r_t.mean_jct,
            r_m.mean_jct
        );
    }

    #[test]
    fn utilization_in_bounds() {
        let r = simulate(&small_cfg(Policy::TLora));
        assert!(r.avg_gpu_util >= 0.0 && r.avg_gpu_util <= 1.0);
        assert!(
            r.avg_gpu_util_full >= 0.0 && r.avg_gpu_util_full <= 1.0
        );
        for &(_, u) in &r.util_timeline {
            assert!((0.0..=1.0).contains(&u));
        }
    }

    #[test]
    fn throughput_timeline_nonempty_and_nonnegative() {
        let r = simulate(&small_cfg(Policy::TLora));
        assert!(!r.throughput_timeline.is_empty());
        assert!(r.throughput_timeline.iter().all(|&(_, v)| v >= 0.0));
        assert!(r.avg_throughput_full >= 0.0);
    }

    #[test]
    fn full_run_average_includes_drain_tail() {
        // the steady-window average ignores the drain tail (stragglers
        // on an empty cluster); the full-run average covers it. The
        // two must agree to within a generous bracket — a swapped or
        // mis-spanned accumulator lands orders of magnitude off (the
        // exact accumulator math is pinned by the observer unit tests)
        let r = simulate(&small_cfg(Policy::TLora));
        assert!(r.avg_throughput > 0.0);
        assert!(r.avg_throughput_full > 0.0);
        assert!(
            r.avg_throughput_full <= r.avg_throughput * 3.0,
            "full {} vs windowed {}",
            r.avg_throughput_full,
            r.avg_throughput
        );
        assert!(
            r.avg_throughput <= r.avg_throughput_full * 30.0,
            "windowed {} vs full {}",
            r.avg_throughput,
            r.avg_throughput_full
        );
        assert!(r.avg_gpu_util_full <= r.avg_gpu_util * 3.0 + 1e-9);
    }

    #[test]
    fn fault_free_runs_report_zero_churn() {
        let r = simulate(&small_cfg(Policy::TLora));
        assert_eq!(r.node_failures, 0);
        assert_eq!(r.gpu_failures, 0);
        assert_eq!(r.holed_gpu_time_s, 0.0);
        assert_eq!(r.preemptions, 0);
        assert_eq!(r.restarts, 0);
        assert_eq!(r.lost_step_time_s, 0.0);
        assert_eq!(r.restore_delay_s, 0.0);
        assert!(r.goodput > 0.0);
        assert!((0.0..=1.0).contains(&r.slo_attainment));
        // straggler columns are quiescent too
        assert_eq!(r.node_degrades, 0);
        assert_eq!(r.degraded_node_time_s, 0.0);
        assert_eq!(r.straggler_slowdown, 1.0);
        assert_eq!(r.migrations, 0);
        // shrink columns are quiescent too
        assert_eq!(r.shrinks, 0);
        assert_eq!(r.regrows, 0);
        assert_eq!(r.degraded_rate_time_s, 0.0);
    }

    #[test]
    fn tier_util_empty_on_homogeneous_and_bounded_on_mixed() {
        // homogeneous fleets never construct the per-tier
        // accumulators (byte-identity gate)
        let r = simulate(&small_cfg(Policy::TLora));
        assert!(r.tier_util.is_empty());
        // a mixed fleet reports one bounded entry per tier, in tier
        // order, and the run is deterministic
        let mut cfg = small_cfg(Policy::TLora);
        cfg.cluster.apply_hardware_mix("a100:v100").unwrap();
        let r = simulate(&cfg);
        assert_eq!(r.tier_util.len(), 2);
        assert_eq!(r.tier_util[0].0, "a100");
        assert_eq!(r.tier_util[1].0, "v100");
        for (name, u) in &r.tier_util {
            assert!(
                (0.0..=1.0).contains(u),
                "{name} utilization {u} out of [0,1]"
            );
        }
        assert!(!r.jct.is_empty());
        let r2 = simulate(&cfg);
        assert_eq!(r.jct, r2.jct);
        assert_eq!(r.tier_util, r2.tier_util);
    }

    #[test]
    fn slow_generation_is_not_flagged_as_straggler() {
        // the tier multiplier is priced into every plan's baseline
        // step time, so on a healthy mixed fleet the detector sees
        // observed/planned ratios of ~1.0 even on the 0.4x v100
        // nodes. Detection is forced active via a no-op scripted
        // straggler source (speed 1.0 = already healthy); if tier
        // slowness leaked into the slowdown estimate, the v100 nodes
        // would cross migrate_threshold (1.6 < 1/0.4) and trigger
        // spurious migrations.
        let mut cfg = small_cfg(Policy::TLora);
        cfg.cluster.apply_hardware_mix("a100:v100").unwrap();
        assert!(cfg.stragglers.detect);
        let jobs = TraceGenerator::new(cfg.trace.clone(), cfg.seed)
            .generate(cfg.n_jobs);
        let opts = EngineOptions {
            straggler_script: vec![
                crate::workload::faults::ScriptedStraggler {
                    time: 0.0,
                    node: 0,
                    speed: 1.0,
                },
            ],
            ..EngineOptions::default()
        };
        let r = simulate_jobs_with(&cfg, jobs, &opts, &mut []);
        assert_eq!(
            r.migrations, 0,
            "tier slowness misread as straggling"
        );
        assert_eq!(r.node_degrades, 0);
        assert_eq!(r.restarts, 0);
    }

    #[test]
    fn correlated_rack_failure_strictly_degrades_goodput() {
        // same failure mass, two shapes: rack-correlated (4 nodes of
        // rack 0 down together for 5000 s) vs spread (the same 4
        // nodes down 5000 s each, staggered so the episodes never
        // overlap). The single 20-GPU job fits while any 3 nodes are
        // up (28 free) but not while a whole rack is out (16 free),
        // so the correlated shape stalls the job for the whole
        // episode where the spread shape only pays restart overheads.
        let mut cfg = ExperimentConfig::default();
        cfg.cluster = crate::cluster::ClusterSpec::with_gpus(32);
        cfg.cluster.apply_topology("racks=2:rack_bw=0.5").unwrap();
        cfg.n_jobs = 1;
        let job = JobSpec {
            id: 0,
            base_model: "llama3-8b".into(),
            rank: 8,
            batch_size: 4,
            seq_len: 512,
            gpus: 20,
            total_steps: 20_000,
            submit_time: 0.0,
            max_slowdown: 10.0,
        };
        let fail = |t: f64, node: u64| crate::workload::ScriptedFault {
            time: t,
            kind: crate::workload::FaultKind::NodeFailure,
            target: node,
        };
        let recover =
            |t: f64, node: u64| crate::workload::ScriptedFault {
                time: t,
                kind: crate::workload::FaultKind::NodeRecovery,
                target: node,
            };
        let correlated: Vec<_> = (0..4)
            .flat_map(|n| {
                [fail(1_000.0, n), recover(6_000.0, n)]
            })
            .collect();
        let spread: Vec<_> = (0..4)
            .flat_map(|n| {
                let t = 1_000.0 + n as f64 * 6_000.0;
                [fail(t, n as u64), recover(t + 5_000.0, n as u64)]
            })
            .collect();
        let run = |script: Vec<crate::workload::ScriptedFault>| {
            let opts = EngineOptions {
                fault_script: script,
                ..EngineOptions::default()
            };
            simulate_jobs_with(&cfg, vec![job.clone()], &opts, &mut [])
        };
        let corr = run(correlated);
        let ind = run(spread);
        assert_eq!(corr.node_failures, 4);
        assert_eq!(ind.node_failures, 4);
        assert!(corr.incomplete_jobs.is_empty());
        assert!(ind.incomplete_jobs.is_empty());
        assert!(
            corr.goodput < ind.goodput,
            "correlated goodput {} not strictly below spread {}",
            corr.goodput,
            ind.goodput
        );
        // a 20-GPU gang on 4-GPU nodes of 2 racks must span both —
        // the non-flat tracker sees it
        assert!(corr.rack_span_max >= 2, "{}", corr.rack_span_max);
        assert!(corr.rack_span_mean >= 1.0);
    }

    #[test]
    fn single_gpu_fault_evicts_less_and_beats_node_outage() {
        // the acceptance scenario: the same device-hours of outage,
        // two granularities. A single-GPU fault on a packed node
        // evicts only the gang on that device and the scheduler
        // re-shards onto the node's 3 survivors; the whole-node model
        // takes all 4 gangs down. Same seed, same workload — the
        // sub-node model must lose strictly less goodput.
        let mut cfg = ExperimentConfig::default();
        cfg.policy = Policy::Megatron; // isolation: 1 gang per job
        cfg.cluster = crate::cluster::ClusterSpec::with_gpus(16);
        cfg.seed = 7;
        let jobs: Vec<JobSpec> = (0..13)
            .map(|id| JobSpec {
                id,
                base_model: "llama3-8b".into(),
                rank: 8,
                batch_size: 4,
                seq_len: 512,
                gpus: 1,
                total_steps: 20_000,
                submit_time: 0.0,
                max_slowdown: 10.0,
            })
            .collect();
        let gpu_opts = EngineOptions {
            gpu_fault_script: vec![
                crate::workload::ScriptedGpuFault {
                    time: 1_000.0,
                    kind: crate::workload::GpuFaultKind::Failure,
                    node: 0,
                    gpu: 0,
                },
                crate::workload::ScriptedGpuFault {
                    time: 6_000.0,
                    kind: crate::workload::GpuFaultKind::Recovery,
                    node: 0,
                    gpu: 0,
                },
            ],
            ..EngineOptions::default()
        };
        let node_opts = EngineOptions {
            fault_script: vec![
                crate::workload::ScriptedFault {
                    time: 1_000.0,
                    kind: crate::workload::FaultKind::NodeFailure,
                    target: 0,
                },
                crate::workload::ScriptedFault {
                    time: 6_000.0,
                    kind: crate::workload::FaultKind::NodeRecovery,
                    target: 0,
                },
            ],
            ..EngineOptions::default()
        };
        let hole =
            simulate_jobs_with(&cfg, jobs.clone(), &gpu_opts, &mut []);
        let outage =
            simulate_jobs_with(&cfg, jobs, &node_opts, &mut []);
        // only the gang on the failed device is touched
        assert_eq!(hole.gpu_failures, 1);
        assert_eq!(hole.node_failures, 0);
        assert_eq!(hole.restarts, 1, "evicted more than touched gangs");
        assert!(
            (hole.holed_gpu_time_s - 5_000.0).abs() < 1e-9,
            "{}",
            hole.holed_gpu_time_s
        );
        // the whole-node model takes down all 4 resident gangs
        assert_eq!(outage.node_failures, 1);
        assert_eq!(outage.gpu_failures, 0);
        assert_eq!(outage.restarts, 4);
        assert_eq!(outage.holed_gpu_time_s, 0.0);
        // both runs finish every job; the sub-node model keeps
        // strictly more useful work per second
        assert!(hole.incomplete_jobs.is_empty());
        assert!(outage.incomplete_jobs.is_empty());
        assert!(
            hole.goodput > outage.goodput,
            "hole goodput {} not strictly above outage {}",
            hole.goodput,
            outage.goodput
        );
    }

    #[test]
    fn seeded_gpu_faults_conserve_jobs_and_are_deterministic() {
        let mut cfg = small_cfg(Policy::TLora);
        cfg.faults.gpu_mtbf_s = 20_000.0;
        cfg.faults.gpu_mttr_s = 600.0;
        cfg.validate().unwrap();
        let r = simulate(&cfg);
        assert_eq!(r.jct.len(), cfg.n_jobs);
        assert!(r.incomplete_jobs.is_empty());
        let r2 = simulate(&cfg);
        assert_eq!(r.jct, r2.jct);
        assert_eq!(r.gpu_failures, r2.gpu_failures);
        assert_eq!(
            r.holed_gpu_time_s.to_bits(),
            r2.holed_gpu_time_s.to_bits()
        );
    }

    #[test]
    fn gpu_fault_gate_off_is_byte_identical() {
        // the byte-freedom contract at the engine level: with
        // gpu_mtbf_s = 0 no stream is built, no event is pushed, and
        // every output bit matches a build that never heard of GPU
        // faults — even when the (gated-off) mttr knob differs
        let base = simulate(&small_cfg(Policy::TLora));
        let mut cfg = small_cfg(Policy::TLora);
        cfg.faults.gpu_mttr_s = 123.0;
        let r = simulate(&cfg);
        assert_eq!(base.jct, r.jct);
        assert_eq!(base.events, r.events);
        assert_eq!(base.sched_rounds, r.sched_rounds);
        assert_eq!(base.makespan.to_bits(), r.makespan.to_bits());
        assert_eq!(base.goodput.to_bits(), r.goodput.to_bits());
        assert_eq!(r.gpu_failures, 0);
        assert_eq!(r.holed_gpu_time_s, 0.0);
    }

    #[test]
    fn shrink_in_place_beats_evict_and_requeue_under_device_loss() {
        // the graceful-degradation acceptance scenario: one 8-GPU
        // tLoRA gang on an 8-GPU node, one device fails a quarter of
        // the way through and recovers at the halfway mark.
        //   * evict-and-requeue: the gang is torn down, pays the
        //     restore penalty, and stalls until recovery frees the
        //     8th device — zero progress for the whole outage.
        //   * shrink-in-place: the gang re-plans at width 7, rolls
        //     back only to the last checkpoint boundary, keeps
        //     training at degraded rate, and regrows to 8 on
        //     recovery.
        // The SLO deadline is pinned *between* the two analytic
        // completion times (both derived from the planner's own 8-
        // and 7-wide step times, so the test carries no magic rate
        // constants): shrink must meet it, evict must miss it.
        use crate::scheduler::predictor::Predictor;

        let mut cfg = ExperimentConfig::default();
        // tLoRA scheduler without AIMD: step times are plan-exact,
        // which is what lets the deadline be computed analytically
        cfg.policy = Policy::TLoraNoKernel;
        cfg.cluster = crate::cluster::ClusterSpec::with_gpus(8);
        cfg.seed = 7;
        let total_steps: u64 = 20_000;
        let job = JobSpec {
            id: 0,
            base_model: "llama3-8b".into(),
            rank: 8,
            batch_size: 4,
            seq_len: 512,
            gpus: 8,
            total_steps,
            submit_time: 0.0,
            max_slowdown: 3.0,
        };

        // plan-level rates at full and surviving width, probed
        // exactly the way the engine does (same PlanOptions; holes
        // registered before the 7-wide probe)
        let opts = PlanOptions {
            fused_kernel: cfg.policy.uses_kernel_fuser(),
            n_nano: Some(cfg.aimd.n0),
            n_nano_max: cfg.aimd.n_max,
        };
        let mut pred =
            Predictor::new(cfg.cluster.clone(), opts);
        let a8 = Allocator::new(cfg.cluster.clone())
            .allocate(8)
            .unwrap();
        let s8_iso = pred.isolated_step_time(&job, &a8).unwrap();
        let s8 = pred
            .group_perf(std::slice::from_ref(&job), &a8)
            .unwrap()
            .step_time_s;
        let dead = crate::cluster::GpuId { node: 0, idx: 3 };
        let a7 = Allocation {
            gpus: a8
                .gpus
                .iter()
                .copied()
                .filter(|g| *g != dead)
                .collect(),
        };
        pred.set_node_holes(0, 1);
        let s7 = pred
            .group_perf(std::slice::from_ref(&job), &a7)
            .unwrap()
            .step_time_s;
        // the shrunken gang is slower but inside the job's Δ^max —
        // otherwise the engine would (correctly) spill it and the
        // scenario would not exercise shrink at all
        assert!(s7 > s8, "7-wide {s7} not slower than 8-wide {s8}");
        assert!(
            s7 / s8_iso <= job.max_slowdown,
            "7-wide slowdown {} exceeds the test job's Δ^max",
            s7 / s8_iso
        );

        let total8 = total_steps as f64 * s8;
        let t1 = 0.25 * total8; // failure
        let t2 = 0.50 * total8; // recovery
        let steps_at_fail = (t1 / s8).floor();
        let done_evict =
            t2 + (total_steps as f64 - steps_at_fail) * s8;
        let done_shrink = t2
            + (total_steps as f64
                - steps_at_fail
                - (t2 - t1) / s7)
                * s8;
        assert!(done_shrink < done_evict);
        // midway: the margin on each side is 0.5·(t2-t1)·s8/s7 —
        // thousands of steps of slack, far beyond fp/rounding noise.
        // (done_evict is a *lower* bound: restore penalties and round
        // cadence only push the real evict completion later.)
        let deadline = 0.5 * (done_shrink + done_evict);
        cfg.faults.slo_factor = deadline
            / (job.max_slowdown
                * total_steps as f64
                * s8_iso);

        let opts_for = || EngineOptions {
            gpu_fault_script: vec![
                crate::workload::ScriptedGpuFault {
                    time: t1,
                    kind: crate::workload::GpuFaultKind::Failure,
                    node: 0,
                    gpu: 3,
                },
                crate::workload::ScriptedGpuFault {
                    time: t2,
                    kind: crate::workload::GpuFaultKind::Recovery,
                    node: 0,
                    gpu: 3,
                },
            ],
            ..EngineOptions::default()
        };
        let mut shrink_cfg = cfg.clone();
        shrink_cfg.faults.shrink = true;
        let shrink = simulate_jobs_with(
            &shrink_cfg,
            vec![job.clone()],
            &opts_for(),
            &mut [],
        );
        let evict = simulate_jobs_with(
            &cfg,
            vec![job.clone()],
            &opts_for(),
            &mut [],
        );

        // both runs finish the job and see the same fault mass
        assert!(shrink.incomplete_jobs.is_empty());
        assert!(evict.incomplete_jobs.is_empty());
        assert_eq!(shrink.gpu_failures, 1);
        assert_eq!(evict.gpu_failures, 1);
        // shrink kept the gang alive: no eviction, one shrink/regrow
        // cycle, degraded-rate time = the outage window
        assert_eq!(shrink.restarts, 0, "shrink path evicted the gang");
        assert_eq!(shrink.shrinks, 1);
        assert_eq!(shrink.regrows, 1);
        assert!(
            (shrink.degraded_rate_time_s - (t2 - t1)).abs()
                < 1e-6 * total8,
            "degraded {} vs outage window {}",
            shrink.degraded_rate_time_s,
            t2 - t1
        );
        // evict-and-requeue tore it down and stalled
        assert_eq!(evict.restarts, 1);
        assert_eq!(evict.shrinks, 0);
        assert_eq!(evict.regrows, 0);
        assert_eq!(evict.degraded_rate_time_s, 0.0);
        // the acceptance ordering: strictly better goodput AND SLO
        // attainment at the same seed
        assert!(
            shrink.makespan < evict.makespan,
            "shrink makespan {} not below evict {}",
            shrink.makespan,
            evict.makespan
        );
        assert!(
            shrink.goodput > evict.goodput,
            "shrink goodput {} not strictly above evict {}",
            shrink.goodput,
            evict.goodput
        );
        assert_eq!(shrink.slo_attainment, 1.0);
        assert_eq!(evict.slo_attainment, 0.0);
    }

    #[test]
    fn shrink_gate_off_is_byte_identical() {
        // byte-freedom contract for the shrink axis. Leg 1: with the
        // knob on but no GPU-fault source, no shrink path ever runs —
        // every output bit matches the fault-free baseline (the
        // regrow sweep scans only *partial* allocations, and none
        // exist)
        let base = simulate(&small_cfg(Policy::TLora));
        let mut cfg = small_cfg(Policy::TLora);
        cfg.faults.shrink = true;
        let r = simulate(&cfg);
        assert_eq!(base.jct, r.jct);
        assert_eq!(base.events, r.events);
        assert_eq!(base.sched_rounds, r.sched_rounds);
        assert_eq!(base.makespan.to_bits(), r.makespan.to_bits());
        assert_eq!(base.goodput.to_bits(), r.goodput.to_bits());
        assert_eq!(r.shrinks, 0);
        assert_eq!(r.regrows, 0);
        // Leg 2: a policy that cannot shrink (mLoRA keeps evict
        // semantics) ignores the knob even under real device churn —
        // the gate is `faults.shrink && shrinks_in_place()`, so the
        // evict path replays bit-identically
        let mut off = small_cfg(Policy::MLora);
        off.faults.gpu_mtbf_s = 20_000.0;
        off.faults.gpu_mttr_s = 600.0;
        off.validate().unwrap();
        let mut on = off.clone();
        on.faults.shrink = true;
        let r_off = simulate(&off);
        let r_on = simulate(&on);
        assert_eq!(r_off.jct, r_on.jct);
        assert_eq!(r_off.events, r_on.events);
        assert_eq!(r_off.sched_rounds, r_on.sched_rounds);
        assert_eq!(
            r_off.makespan.to_bits(),
            r_on.makespan.to_bits()
        );
        assert_eq!(r_off.goodput.to_bits(), r_on.goodput.to_bits());
        assert_eq!(
            r_off.holed_gpu_time_s.to_bits(),
            r_on.holed_gpu_time_s.to_bits()
        );
        assert_eq!(r_on.shrinks, 0);
        assert_eq!(r_on.regrows, 0);
        assert_eq!(r_on.degraded_rate_time_s, 0.0);
    }

    #[test]
    fn shrink_under_seeded_churn_conserves_jobs() {
        // shrink + regrow under a full seeded GPU-churn stream (with
        // per-device wear coupling): every job still completes
        // exactly once and the run is bit-deterministic
        let mut cfg = small_cfg(Policy::TLora);
        cfg.faults.gpu_mtbf_s = 20_000.0;
        cfg.faults.gpu_mttr_s = 600.0;
        cfg.faults.gpu_wear_alpha = 0.5;
        cfg.faults.shrink = true;
        cfg.validate().unwrap();
        let r = simulate(&cfg);
        assert_eq!(r.jct.len(), cfg.n_jobs);
        assert!(r.incomplete_jobs.is_empty());
        assert!(r.gpu_failures > 0, "churn stream never fired");
        let r2 = simulate(&cfg);
        assert_eq!(r.jct, r2.jct);
        assert_eq!(r.shrinks, r2.shrinks);
        assert_eq!(r.regrows, r2.regrows);
        assert_eq!(
            r.degraded_rate_time_s.to_bits(),
            r2.degraded_rate_time_s.to_bits()
        );
    }

    #[test]
    fn domain_episodes_conserve_jobs_and_are_deterministic() {
        // rack-scoped correlated failures + stragglers on: every job
        // still completes exactly once (episodes evict and requeue,
        // never lose jobs) and the run is bit-deterministic
        let mut cfg = small_cfg(Policy::TLora);
        cfg.cluster.apply_topology("racks=4:rack_bw=0.5").unwrap();
        cfg.faults.domain_mtbf_s = 4_000.0;
        cfg.faults.domain_mttr_s = 300.0;
        cfg.stragglers.domain_mtbs_s = 6_000.0;
        cfg.stragglers.domain_mtts_s = 400.0;
        cfg.validate().unwrap();
        let r = simulate(&cfg);
        assert_eq!(r.jct.len(), cfg.n_jobs);
        assert!(r.incomplete_jobs.is_empty());
        let r2 = simulate(&cfg);
        assert_eq!(r.jct, r2.jct);
        assert_eq!(r.node_failures, r2.node_failures);
        assert_eq!(r.node_degrades, r2.node_degrades);
        // with the knobs at 0 the same topology synthesizes nothing
        let mut quiet = small_cfg(Policy::TLora);
        quiet.cluster.apply_topology("racks=4:rack_bw=0.5").unwrap();
        let q = simulate(&quiet);
        assert_eq!(q.node_failures, 0);
        assert_eq!(q.node_degrades, 0);
    }

    #[test]
    fn single_rack_topology_is_byte_identical_to_flat() {
        // racks=1 parses to a non-empty spec string but a flat tree:
        // every metric must match the default cluster bit-for-bit,
        // and the rack-span tracker must never engage
        let flat = simulate(&small_cfg(Policy::TLora));
        let mut cfg = small_cfg(Policy::TLora);
        cfg.cluster.apply_topology("racks=1").unwrap();
        let r = simulate(&cfg);
        assert_eq!(flat.jct, r.jct);
        assert_eq!(flat.events, r.events);
        assert_eq!(flat.sched_rounds, r.sched_rounds);
        assert_eq!(flat.makespan.to_bits(), r.makespan.to_bits());
        assert_eq!(flat.goodput.to_bits(), r.goodput.to_bits());
        assert_eq!(r.rack_span_mean, 0.0);
        assert_eq!(r.rack_span_max, 0);
    }

    #[test]
    fn static_group_throughput_works() {
        let cfg = small_cfg(Policy::TLora);
        let jobs: Vec<JobSpec> =
            TraceGenerator::new(TraceProfile::month1(), 3).generate(2);
        let thr = static_group_throughput(&cfg, &jobs, 2, false);
        assert!(thr.is_some());
        assert!(thr.unwrap() > 0.0);
    }

    #[test]
    fn spread_placement_pays_on_comm_terms() {
        // cross-node placement routes the group's communication over
        // IB instead of NVLink. Asserted on the model's comm terms
        // directly, shape by shape (compute is placement-independent
        // for a fixed (pp, tp), so the comparison is exact — no
        // throughput fudge factor):
        let cfg = small_cfg(Policy::TLora);
        let jobs: Vec<JobSpec> =
            TraceGenerator::new(TraceProfile::month1(), 3).generate(2);
        let opts = PlanOptions {
            fused_kernel: cfg.policy.uses_kernel_fuser(),
            n_nano: None,
            n_nano_max: cfg.aimd.n_max,
        };
        let packed_alloc =
            Allocator::new(cfg.cluster.clone()).allocate(2).unwrap();
        assert!(!packed_alloc.spans_nodes());
        let spread_alloc = Allocation {
            gpus: (0..2)
                .map(|node| crate::cluster::GpuId { node, idx: 0 })
                .collect(),
        };
        let ssm = Ssm::fuse(&jobs).unwrap();
        for (pp, tp) in [(1usize, 2usize), (2, 1)] {
            let packed = crate::planner::plan_with_shape(
                &ssm, &packed_alloc, &cfg.cluster, &opts, pp, tp,
            )
            .unwrap();
            let spread = crate::planner::plan_with_shape(
                &ssm, &spread_alloc, &cfg.cluster, &opts, pp, tp,
            )
            .unwrap();
            // TP allreduces / stage p2p over IB are strictly slower
            // than over NVLink
            assert!(
                spread.comm_s > packed.comm_s,
                "({pp},{tp}): spread comm {} <= packed comm {}",
                spread.comm_s,
                packed.comm_s
            );
            // same compute, more communication: never faster
            assert!(
                spread.step_time_s >= packed.step_time_s,
                "({pp},{tp}): spread step {} < packed step {}",
                spread.step_time_s,
                packed.step_time_s
            );
        }
        // and the shape-searched best plans preserve the ordering the
        // old test asserted with a *1.001 tolerance
        let best_packed =
            static_group_plan(&cfg, &jobs, 2, false).unwrap();
        let best_spread =
            static_group_plan(&cfg, &jobs, 2, true).unwrap();
        assert!(best_spread.step_time_s >= best_packed.step_time_s);
    }
}
