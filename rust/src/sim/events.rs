//! Deterministic event queue for the simulation engine.
//!
//! The engine advances straight from event to event instead of ticking
//! a fixed horizon. Ten kinds exist:
//!
//! * [`EventKind::Arrival`] — a job's submit time was reached;
//! * [`EventKind::Completion`] — a running job's last step finishes,
//!   computed exactly from its group's current step rate;
//! * [`EventKind::NodeFailure`] / [`EventKind::NodeRecovery`] — a
//!   cluster node goes down / comes back (the fault subsystem;
//!   `job_id` carries the node index for these two);
//! * [`EventKind::GpuFailure`] / [`EventKind::GpuRecovery`] — a
//!   *single GPU* fails / heals while its node keeps serving from the
//!   survivors (the partial-node fault mode; `job_id` carries the flat
//!   device index `node * gpus_per_node + gpu`);
//! * [`EventKind::NodeDegraded`] / [`EventKind::NodeRestored`] — a
//!   node starts / stops *straggling*: it keeps its GPUs but runs
//!   every co-located group at a fraction of its nominal rate
//!   (`job_id` carries the node index; the severity travels in the
//!   engine's straggler driver, not in the event);
//! * [`EventKind::Preemption`] — an exogenous eviction of one job
//!   (spot reclaim / higher-priority tenant);
//! * [`EventKind::ReschedulePoint`] — the periodic regroup bound
//!   (`scheduler.horizon_s` now caps the *maximum* interval between
//!   scheduling rounds instead of forcing one every 60 s).
//!
//! **Determinism tie-break rule:** events order by
//! `(time, kind, job_id, epoch)` — time via the crate's total f64
//! order, then `Arrival < Completion < NodeFailure < NodeRecovery <
//! GpuFailure < GpuRecovery < NodeDegraded < NodeRestored <
//! Preemption < ReschedulePoint`, then job id. Two runs of the same
//! config therefore pop events in a bit-identical sequence, which is
//! what keeps the sweep engine's cross-thread determinism contract
//! intact (DESIGN.md §Determinism).
//! The fault ranks encode semantics: a job whose final step lands
//! exactly when its node dies *completed* (the step finished), and a
//! zero-downtime blip still orders failure before recovery. GPU
//! faults rank after the node kinds — a whole-node outage subsumes any
//! same-instant single-device fault on it, so the hole is applied to a
//! node whose gangs are already evicted (an idempotent mask update) —
//! and failure before recovery for the same zero-downtime-blip reason.
//! Graceful degradation (`faults.shrink`) adds **no new kinds**:
//! shrink-in-place rides the `GpuFailure` dispatch at rank 4 and
//! regrow is a stateless scan for partial allocations in the next
//! scheduling round (so it observes `GpuRecovery`/allocator backfill
//! at rank 5 and later), which keeps this tie-break table — and the
//! bit-identical replay contract built on it — untouched.
//! Straggler transitions rank after all capacity faults — a node that
//! dies at the instant it would have degraded is simply dead — and
//! degrade before restore, so a zero-length episode is a no-op rather
//! than a restore-then-degrade inversion; both rank before
//! `Preemption`, so an eviction priced at the degrade instant sees the
//! new rate.
//!
//! Completion and reschedule events are *epoch-stamped*; superseded
//! copies are discarded lazily on pop instead of being searched for
//! and removed from the heap. The two kinds use different epoch
//! spaces:
//!
//! * **Reschedule points** carry the global round counter — every
//!   round re-derives the next bound, so older stamps are stale
//!   ([`Event::is_stale`]).
//! * **Completions** carry a *per-job* epoch (tracked by the engine,
//!   not by this module): a job's event is re-derived only when its
//!   group's effective step rate changed bitwise, its progress broke
//!   continuity (eviction rollback), or it started/stopped running —
//!   an untouched group's completion instant is invariant across
//!   rounds, so its event stays live and heap churn is O(touched ×
//!   rounds) instead of O(running × rounds). The dirty-vs-global
//!   differential in `tests/integration_perf.rs` pins that this
//!   discards exactly the events a global per-round bump would have.
//!
//! Arrivals and fault events (node and GPU failure / recovery,
//! degrade / restore, preemption) are *exogenous*: they come from the
//! trace or the seeded fault model, not from the schedule, so they
//! never go stale.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use crate::util::f64_cmp;

/// What happened at an event's timestamp.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A job reaches its submit time and enters the queue.
    Arrival,
    /// A running job finishes its final training step.
    Completion,
    /// A node goes down (`job_id` = node index). Groups whose
    /// allocation touches the node are evicted.
    NodeFailure,
    /// A down node returns to the allocatable pool (`job_id` = node
    /// index).
    NodeRecovery,
    /// A single GPU fails (`job_id` = flat device index
    /// `node * gpus_per_node + gpu`): the allocator strands that slot,
    /// and only the gangs whose allocation touches the device are
    /// evicted — the node keeps serving from the survivors.
    GpuFailure,
    /// A failed GPU heals and returns to the allocatable pool
    /// (`job_id` = flat device index).
    GpuRecovery,
    /// A node starts straggling (`job_id` = node index): its GPUs stay
    /// allocatable but every co-located group runs at the episode's
    /// sampled speed multiplier.
    NodeDegraded,
    /// A straggling node returns to full speed (`job_id` = node
    /// index).
    NodeRestored,
    /// One job (`job_id`) is exogenously evicted; a no-op if it is not
    /// currently placed.
    Preemption,
    /// Upper bound on the interval between scheduling rounds.
    ReschedulePoint,
}

impl EventKind {
    /// Tie-break rank at equal timestamps: arrivals first (a job
    /// arriving exactly when another completes sees the freed GPUs in
    /// the same round), then completions (a final step that lands at
    /// the failure instant still counts), then node failure before
    /// node recovery before GPU failure before GPU recovery (whole
    /// nodes subsume same-instant single-device faults) before degrade
    /// before restore before preemption, reschedule points last.
    fn rank(self) -> u8 {
        match self {
            EventKind::Arrival => 0,
            EventKind::Completion => 1,
            EventKind::NodeFailure => 2,
            EventKind::NodeRecovery => 3,
            EventKind::GpuFailure => 4,
            EventKind::GpuRecovery => 5,
            EventKind::NodeDegraded => 6,
            EventKind::NodeRestored => 7,
            EventKind::Preemption => 8,
            EventKind::ReschedulePoint => 9,
        }
    }
}

/// One scheduled event. `job_id` is 0 for reschedule points and the
/// node index for failure/recovery; `epoch` is the scheduling-round
/// counter the event was issued under. Exogenous kinds never go stale,
/// so their `epoch` is free for other use: arrivals carry 0, and the
/// engine stamps fault events with an *origin tag* (0 = scripted,
/// 1 = seeded-model — model events chain the next draw from their
/// stream when handled; see `sim::engine::FAULT_MODEL_ORIGIN`).
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub time: f64,
    pub kind: EventKind,
    pub job_id: u64,
    pub epoch: u64,
}

impl Event {
    /// Is this event obsolete under `current_epoch`? Schedule-derived
    /// kinds (completions, reschedule points) go stale when a newer
    /// stamp supersedes theirs; exogenous events — arrivals and the
    /// fault kinds — are facts about the outside world and are never
    /// stale. The engine passes the global round counter for
    /// reschedule points and the owning job's *per-job* completion
    /// epoch for completions (see the module docs).
    pub fn is_stale(&self, current_epoch: u64) -> bool {
        match self.kind {
            EventKind::Arrival
            | EventKind::NodeFailure
            | EventKind::NodeRecovery
            | EventKind::GpuFailure
            | EventKind::GpuRecovery
            | EventKind::NodeDegraded
            | EventKind::NodeRestored
            | EventKind::Preemption => false,
            EventKind::Completion | EventKind::ReschedulePoint => {
                self.epoch != current_epoch
            }
        }
    }
}

impl PartialEq for Event {
    fn eq(&self, other: &Event) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Event) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Event) -> Ordering {
        f64_cmp(self.time, other.time)
            .then(self.kind.rank().cmp(&other.kind.rank()))
            .then(self.job_id.cmp(&other.job_id))
            .then(self.epoch.cmp(&other.epoch))
    }
}

/// Min-heap of events under the deterministic order above.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue {
            heap: BinaryHeap::new(),
        }
    }

    pub fn push(&mut self, ev: Event) {
        self.heap.push(Reverse(ev));
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(ev)| ev)
    }

    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek().map(|Reverse(ev)| ev)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(time: f64, kind: EventKind, job_id: u64) -> Event {
        Event {
            time,
            kind,
            job_id,
            epoch: 0,
        }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(ev(30.0, EventKind::Arrival, 1));
        q.push(ev(10.0, EventKind::Completion, 2));
        q.push(ev(20.0, EventKind::ReschedulePoint, 0));
        let times: Vec<f64> = std::iter::from_fn(|| q.pop())
            .map(|e| e.time)
            .collect();
        assert_eq!(times, vec![10.0, 20.0, 30.0]);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_time_ties_break_on_kind_then_job_id() {
        let mut q = EventQueue::new();
        q.push(ev(5.0, EventKind::ReschedulePoint, 0));
        q.push(ev(5.0, EventKind::Completion, 9));
        q.push(ev(5.0, EventKind::Completion, 3));
        q.push(ev(5.0, EventKind::Arrival, 7));
        let order: Vec<(EventKind, u64)> = std::iter::from_fn(|| q.pop())
            .map(|e| (e.kind, e.job_id))
            .collect();
        assert_eq!(
            order,
            vec![
                (EventKind::Arrival, 7),
                (EventKind::Completion, 3),
                (EventKind::Completion, 9),
                (EventKind::ReschedulePoint, 0),
            ]
        );
    }

    #[test]
    fn insertion_order_never_leaks_into_pop_order() {
        // push the same event set in two different orders: pops match
        let evs = vec![
            ev(1.0, EventKind::Completion, 4),
            ev(1.0, EventKind::Arrival, 4),
            ev(0.5, EventKind::ReschedulePoint, 0),
            ev(1.0, EventKind::Completion, 1),
        ];
        let mut a = EventQueue::new();
        let mut b = EventQueue::new();
        for e in &evs {
            a.push(*e);
        }
        for e in evs.iter().rev() {
            b.push(*e);
        }
        loop {
            match (a.pop(), b.pop()) {
                (None, None) => break,
                (x, y) => assert_eq!(x, y),
            }
        }
    }

    #[test]
    fn fault_kinds_rank_between_completions_and_reschedule() {
        let mut q = EventQueue::new();
        q.push(ev(5.0, EventKind::ReschedulePoint, 0));
        q.push(ev(5.0, EventKind::Preemption, 4));
        q.push(ev(5.0, EventKind::NodeRestored, 3));
        q.push(ev(5.0, EventKind::NodeDegraded, 3));
        q.push(ev(5.0, EventKind::GpuRecovery, 17));
        q.push(ev(5.0, EventKind::GpuFailure, 17));
        q.push(ev(5.0, EventKind::NodeRecovery, 2));
        q.push(ev(5.0, EventKind::NodeFailure, 2));
        q.push(ev(5.0, EventKind::Completion, 1));
        q.push(ev(5.0, EventKind::Arrival, 9));
        let order: Vec<EventKind> = std::iter::from_fn(|| q.pop())
            .map(|e| e.kind)
            .collect();
        assert_eq!(
            order,
            vec![
                EventKind::Arrival,
                EventKind::Completion,
                EventKind::NodeFailure,
                EventKind::NodeRecovery,
                EventKind::GpuFailure,
                EventKind::GpuRecovery,
                EventKind::NodeDegraded,
                EventKind::NodeRestored,
                EventKind::Preemption,
                EventKind::ReschedulePoint,
            ]
        );
    }

    #[test]
    fn staleness_only_applies_to_schedule_derived_kinds() {
        let stamped = |kind, epoch| Event {
            time: 1.0,
            kind,
            job_id: 0,
            epoch,
        };
        // schedule-derived kinds: stale iff the epoch moved on
        for kind in [EventKind::Completion, EventKind::ReschedulePoint] {
            assert!(!stamped(kind, 3).is_stale(3));
            assert!(stamped(kind, 2).is_stale(3));
        }
        // exogenous kinds: never stale, whatever the stamp
        for kind in [
            EventKind::Arrival,
            EventKind::NodeFailure,
            EventKind::NodeRecovery,
            EventKind::GpuFailure,
            EventKind::GpuRecovery,
            EventKind::NodeDegraded,
            EventKind::NodeRestored,
            EventKind::Preemption,
        ] {
            assert!(!stamped(kind, 0).is_stale(7));
        }
    }

    #[test]
    fn peek_does_not_remove() {
        let mut q = EventQueue::new();
        q.push(ev(2.0, EventKind::Arrival, 0));
        assert_eq!(q.peek().unwrap().time, 2.0);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().time, 2.0);
        assert!(q.pop().is_none());
    }
}
