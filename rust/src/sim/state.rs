//! Simulation state: per-job bookkeeping, running groups, and the
//! allocation/admission mechanics the engine drives.
//!
//! Everything here is *mechanism* — how jobs are admitted, absorbed,
//! advanced, and released. *Policy* (which groups to form, which group
//! absorbs a queued job) lives behind
//! [`crate::scheduler::PolicyHooks`], implemented per baseline in
//! [`crate::baselines`].

use std::collections::HashMap;

use crate::cluster::{Allocation, Allocator, GpuId};
use crate::config::{ExperimentConfig, SchedulerConfig};
use crate::kernelsim::overlap::iter_time;
use crate::kernelsim::AimdController;
use crate::scheduler::predictor::GroupPerf;
use crate::scheduler::predictor::Predictor;
use crate::scheduler::{
    urgency, Candidate, GroupState, NodeView, PolicyHooks,
};
use crate::util::f64_cmp;
use crate::workload::JobSpec;

/// Per-job bookkeeping during the run.
#[derive(Debug, Clone)]
pub struct JobState {
    pub spec: JobSpec,
    pub steps_done: f64,
    /// isolated-execution step time on its provisioned GPUs (slowdown
    /// reference), computed lazily at admission
    pub iso_step_time: f64,
    /// first time the job started making progress (own allocation or
    /// elastic shared admission); refreshed if it later reclaims its
    /// own GPUs, matching the urgency bookkeeping
    pub admitted_at: Option<f64>,
    pub completed_at: Option<f64>,
    /// seconds spent in a group of size > 1
    pub grouped_time: f64,
    pub running_time: f64,
    /// earliest time the job may run again after an eviction (its
    /// checkpoint-restore window); 0 until the first eviction
    pub restart_at: f64,
    /// evictions suffered (node failures + preemptions)
    pub restarts: u64,
}

/// One job evicted by a node failure or preemption: what it lost and
/// what restoring it costs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Eviction {
    pub job_id: u64,
    /// simulated seconds of rolled-back in-flight work (progress past
    /// the last durable checkpoint boundary — every
    /// `FaultConfig::ckpt_interval_steps` steps; at the default
    /// cadence of 1 this is just the fractional step in progress)
    pub lost_s: f64,
    /// checkpoint-restore delay charged before the job may run again
    pub penalty_s: f64,
}

/// Outcome of a shrink-in-place reaction to a single-GPU failure
/// ([`SimState::shrink_gpu`]): who spilled, who kept training at the
/// shrunken width, and what the survivors' checkpoint rollback cost.
#[derive(Debug, Default)]
pub struct ShrinkOutcome {
    /// Members spilled through the normal eviction path — Δ^max
    /// violated at the shrunken rate, an infeasible shrunken-width
    /// plan, or a gang shrunk to nothing — in job-id order per gang.
    pub evictions: Vec<Eviction>,
    /// Members kept training in gangs shrunk in place (plus
    /// held-but-not-running owners whose gang lost the device),
    /// sorted by id. These run under-provisioned until
    /// [`SimState::regrow_shrunken`] tops them back up.
    pub shrunk_jobs: Vec<u64>,
    /// Gangs shrunk in place (kept running at surviving width).
    pub groups_shrunk: u64,
    /// Simulated seconds of checkpoint-boundary rollback across the
    /// *surviving* members (the spilled members' lost work is on
    /// their `Eviction` records), summed in job-id order.
    pub rollback_lost_s: f64,
}

/// A group currently executing at a fixed step rate. The rate only
/// changes at scheduling rounds (regroup or AIMD update) or at a
/// straggler degrade/restore instant ([`SimState::set_node_speed`]),
/// which is what lets the engine compute completion times exactly.
///
/// `step_time` is the *effective* step time — the planned
/// `base_step_time` divided by `speed`, the slowest multiplier among
/// the gang's nodes (a fused group is gang-synchronous, so one
/// degraded node paces every step). With all nodes healthy
/// `speed == 1.0` and `step_time` is bit-identical to
/// `base_step_time` (IEEE division by 1.0 is exact), which is what
/// keeps straggler-free runs byte-identical to the pre-straggler
/// engine.
#[derive(Debug)]
pub struct RunningGroup {
    pub job_ids: Vec<u64>,
    pub alloc: Allocation,
    /// effective step time: `base_step_time / speed`
    pub step_time: f64,
    /// planned speed-1 step time (plan or AIMD-refreshed)
    pub base_step_time: f64,
    /// slowest node multiplier across the gang (1.0 = healthy)
    pub speed: f64,
    pub compute_util: f64,
    pub aimd: Option<AimdController>,
    /// comp/comm decomposition for online AIMD re-evaluation
    pub comp_s: f64,
    pub comm_s: f64,
    pub oh: f64,
    pub lat: f64,
}

/// Cap on AIMD observations consumed per advance — the same per-window
/// bound the horizon loop used, now applied per inter-event interval.
const AIMD_OBS_PER_ADVANCE: f64 = 16.0;

/// The full mutable simulation state.
pub struct SimState {
    pub states: HashMap<u64, JobState>,
    /// arrived jobs waiting for GPUs (or for elastic absorption)
    pub queue: Vec<u64>,
    /// owned gang allocations by job id
    pub allocations: HashMap<u64, Allocation>,
    pub running: Vec<RunningGroup>,
    pub allocator: Allocator,
    pub completed: usize,
    /// current simulated time; advances only via [`SimState::advance_to`]
    pub now: f64,
    /// checkpoint cadence in steps, as f64
    /// (`FaultConfig::ckpt_interval_steps`, >= 1): a durable
    /// checkpoint exists at every multiple, and evictions roll back to
    /// the last such boundary. At the default of 1.0 the rollback is
    /// bit-identical to the legacy fractional-step accounting
    /// (`floor(x / 1.0) * 1.0 == floor(x)` in IEEE bits).
    ckpt_interval: f64,
    /// periodic checkpoint-write cost amortized per step
    /// (`ckpt_write_s / ckpt_interval_steps`), charged into every
    /// group's base step time; 0.0 by default (`x + 0.0 == x`)
    ckpt_oh_per_step: f64,
}

impl SimState {
    pub fn new(cfg: &ExperimentConfig, jobs: &[JobSpec]) -> SimState {
        let states = jobs
            .iter()
            .map(|j| {
                (
                    j.id,
                    JobState {
                        spec: j.clone(),
                        steps_done: 0.0,
                        iso_step_time: 0.0,
                        admitted_at: None,
                        completed_at: None,
                        grouped_time: 0.0,
                        running_time: 0.0,
                        restart_at: 0.0,
                        restarts: 0,
                    },
                )
            })
            .collect();
        let k = cfg.faults.ckpt_interval_steps.max(1) as f64;
        SimState {
            states,
            queue: vec![],
            allocations: HashMap::new(),
            running: vec![],
            allocator: Allocator::new(cfg.cluster.clone()),
            completed: 0,
            now: 0.0,
            ckpt_interval: k,
            ckpt_oh_per_step: cfg.faults.ckpt_write_s / k,
        }
    }

    /// Advance simulated time to `t`: accrue progress for every running
    /// group at the step rate in effect over `[now, t)`, then step each
    /// group's AIMD controller by the elapsed simulated steps (capped,
    /// as the horizon loop capped per-horizon observations) and refresh
    /// its step rate for the *next* interval.
    pub fn advance_to(&mut self, t: f64) {
        let dt = t - self.now;
        let ckpt_oh = self.ckpt_oh_per_step;
        if dt > 0.0 {
            for g in &mut self.running {
                let step = g.step_time;
                let grouped = g.job_ids.len() > 1;
                for id in &g.job_ids {
                    let st = self.states.get_mut(id).unwrap();
                    if st.completed_at.is_some() {
                        continue;
                    }
                    st.steps_done += dt / step;
                    st.running_time += dt;
                    if grouped {
                        st.grouped_time += dt;
                    }
                }
                if let Some(c) = &mut g.aimd {
                    let steps = (dt / step)
                        .max(1.0)
                        .min(AIMD_OBS_PER_ADVANCE)
                        as usize;
                    for _ in 0..steps {
                        // the controller sees what a wall clock would:
                        // the *effective* step time, straggler drag
                        // and amortized checkpoint writes included
                        // (÷1.0 and +0.0 are exact when healthy/free)
                        let t_step = (iter_time(
                            g.comp_s, g.comm_s, c.n(), g.oh, g.lat,
                        ) + ckpt_oh)
                            / g.speed;
                        c.observe(t_step);
                    }
                    g.base_step_time = iter_time(
                        g.comp_s, g.comm_s, c.n(), g.oh, g.lat,
                    ) + ckpt_oh;
                    g.step_time = g.base_step_time / g.speed;
                }
            }
        }
        self.now = t;
    }

    /// Mark `id` complete at exactly `t` (the event's timestamp, which
    /// was computed from the group's step rate — no interpolation).
    /// Returns whether the job was newly completed.
    pub fn complete(&mut self, id: u64, t: f64) -> bool {
        let st = self.states.get_mut(&id).unwrap();
        if st.completed_at.is_some() {
            return false;
        }
        st.completed_at = Some(t);
        st.steps_done = st.steps_done.max(st.spec.total_steps as f64);
        self.completed += 1;
        true
    }

    /// Release completed jobs' GPUs and drop empty groups.
    pub fn release_completed(&mut self) {
        let states = &self.states;
        let mut freed = vec![];
        for g in &mut self.running {
            g.job_ids.retain(|id| {
                let done = states[id].completed_at.is_some();
                if done {
                    freed.push(*id);
                }
                !done
            });
        }
        self.running.retain(|g| !g.job_ids.is_empty());
        for id in freed {
            if let Some(a) = self.allocations.remove(&id) {
                self.allocator.release(&a);
            }
        }
    }

    /// Dissolve shared placements: group members without owned GPUs
    /// return to the queue and are re-admitted this round (possibly
    /// onto their own allocation now — the elastic "reclaim resources
    /// later" of §3.4). Progress and admission timestamps persist in
    /// `states`.
    pub fn requeue_shared(&mut self) {
        for g in &self.running {
            for id in &g.job_ids {
                if !self.allocations.contains_key(id)
                    && self.states[id].completed_at.is_none()
                {
                    self.queue.push(*id);
                }
            }
        }
    }

    /// Evict one uncompleted job at time `t`: roll back its progress to
    /// the last durable checkpoint boundary (every
    /// `ckpt_interval` steps; `step_time` prices the lost work, 0 when
    /// the job was not running), release its owned gang, stamp its
    /// restore window, and requeue it. At the default cadence of 1 the
    /// boundary is the last whole step — the historical optimistic
    /// accounting, bit-for-bit.
    fn evict(
        &mut self,
        id: u64,
        t: f64,
        step_time: f64,
        penalty: &HashMap<u64, f64>,
    ) -> Eviction {
        if let Some(a) = self.allocations.remove(&id) {
            self.allocator.release(&a);
        }
        let p = *penalty.get(&id).unwrap_or(&0.0);
        let st = self.states.get_mut(&id).unwrap();
        let k = self.ckpt_interval;
        let boundary = (st.steps_done / k).floor() * k;
        let lost = (st.steps_done - boundary) * step_time;
        st.steps_done = boundary;
        st.restart_at = t + p;
        st.restarts += 1;
        self.queue.push(id);
        Eviction {
            job_id: id,
            lost_s: lost,
            penalty_s: p,
        }
    }

    /// Fail `node` at time `t`: the allocator stops handing out its
    /// GPUs, and every group whose allocation touches the node dies —
    /// its gang's sharded adapter/optimizer state is gone, so all
    /// uncompleted members are evicted (restore from the adapter-only
    /// checkpoint, priced per job by `penalty`) and requeued. How they
    /// come back is the *policy's* reaction at the next round: tLoRA
    /// re-fuses them elastically, mLoRA repacks FIFO, Megatron restarts
    /// each in isolation. Returns the evictions in job-id order.
    pub fn fail_node(
        &mut self,
        node: usize,
        t: f64,
        penalty: &HashMap<u64, f64>,
    ) -> Vec<Eviction> {
        self.allocator.set_down(node, true);
        // (member id, its group's step rate) — the rate prices the
        // rolled-back in-flight fraction and dies with the group
        let mut affected: Vec<(u64, f64)> = vec![];
        let mut keep = vec![];
        for g in self.running.drain(..) {
            if g.alloc.gpus.iter().any(|gpu| gpu.node == node) {
                for id in &g.job_ids {
                    affected.push((*id, g.step_time));
                }
            } else {
                keep.push(g);
            }
        }
        self.running = keep;
        let mut evictions = vec![];
        affected.sort_unstable_by_key(|&(id, _)| id);
        for (id, step_time) in affected {
            if self.states[&id].completed_at.is_some() {
                // the member finished at this very timestamp; just free
                // its gang (release_completed would have, but its group
                // no longer exists)
                if let Some(a) = self.allocations.remove(&id) {
                    self.allocator.release(&a);
                }
                continue;
            }
            evictions.push(self.evict(id, t, step_time, penalty));
        }
        // admitted-but-not-running holders (a dispatch probe failure
        // can leave a job with a gang but no group): sweep any
        // remaining allocation touching the node, in id order
        let mut held: Vec<u64> = self
            .allocations
            .iter()
            .filter(|(_, a)| a.gpus.iter().any(|g| g.node == node))
            .map(|(id, _)| *id)
            .collect();
        held.sort_unstable();
        for id in held {
            if self.states[&id].completed_at.is_some() {
                if let Some(a) = self.allocations.remove(&id) {
                    self.allocator.release(&a);
                }
            } else {
                evictions.push(self.evict(id, t, 0.0, penalty));
            }
        }
        evictions
    }

    /// Recover `node`: its GPUs return to the allocatable pool.
    /// Individually-holed GPUs stay stranded until their own
    /// [`SimState::recover_gpu`] — node recovery with a live hole
    /// restores exactly `gpus_per_node - holes` GPUs.
    pub fn recover_node(&mut self, node: usize) {
        self.allocator.set_down(node, false);
    }

    /// Fail a single GPU `(node, idx)` at time `t`: the allocator
    /// strands the slot ([`Allocator::set_gpu_down`]) and only the
    /// groups whose allocation actually *touches the device* die — the
    /// node's surviving GPUs keep serving their gangs untouched, which
    /// is the whole fidelity point of partial-node faults
    /// ([`SimState::fail_node`] one level down the hardware tree).
    /// Evicted members restore from checkpoint exactly like a node
    /// failure; the next round's admission re-shards them around the
    /// hole. Returns the evictions in job-id order.
    pub fn fail_gpu(
        &mut self,
        node: usize,
        idx: usize,
        t: f64,
        penalty: &HashMap<u64, f64>,
    ) -> Vec<Eviction> {
        self.allocator.set_gpu_down(node, idx, true);
        let touches = |a: &Allocation| {
            a.gpus
                .iter()
                .any(|gpu| gpu.node == node && gpu.idx == idx)
        };
        let mut affected: Vec<(u64, f64)> = vec![];
        let mut keep = vec![];
        for g in self.running.drain(..) {
            if touches(&g.alloc) {
                for id in &g.job_ids {
                    affected.push((*id, g.step_time));
                }
            } else {
                keep.push(g);
            }
        }
        self.running = keep;
        let mut evictions = vec![];
        affected.sort_unstable_by_key(|&(id, _)| id);
        for (id, step_time) in affected {
            if self.states[&id].completed_at.is_some() {
                if let Some(a) = self.allocations.remove(&id) {
                    self.allocator.release(&a);
                }
                continue;
            }
            evictions.push(self.evict(id, t, step_time, penalty));
        }
        // admitted-but-not-running holders touching the device, in
        // id order (same sweep as fail_node)
        let mut held: Vec<u64> = self
            .allocations
            .iter()
            .filter(|(_, a)| touches(a))
            .map(|(id, _)| *id)
            .collect();
        held.sort_unstable();
        for id in held {
            if self.states[&id].completed_at.is_some() {
                if let Some(a) = self.allocations.remove(&id) {
                    self.allocator.release(&a);
                }
            } else {
                evictions.push(self.evict(id, t, 0.0, penalty));
            }
        }
        evictions
    }

    /// Heal a single GPU: the slot returns to the allocatable pool
    /// (a no-op for the slot until any gang holding it releases).
    pub fn recover_gpu(&mut self, node: usize, idx: usize) {
        self.allocator.set_gpu_down(node, idx, false);
    }

    /// Graceful-degradation reaction to a single-GPU failure
    /// ([`SimState::fail_gpu`]'s shrink-in-place alternative, gated by
    /// `faults.shrink` + [`PolicyHooks::shrinks_in_place`] in the
    /// engine): instead of tearing down the touched gang, drop the
    /// dead device from its owner's gang, re-plan the fused group at
    /// the surviving width, and keep training at reduced throughput.
    ///
    /// Per member, the elastic Δ^max machinery decides shrink vs
    /// spill: the member stays when the shrunken gang's effective
    /// step time over its *admission-time* isolated baseline
    /// (`JobState::iso_step_time`, its provisioned-width reference)
    /// respects its `max_slowdown`; otherwise it spills through the
    /// normal eviction path — rollback, restore penalty, requeue,
    /// `restarts += 1` — exactly like [`SimState::fail_gpu`] would
    /// have treated it. Survivors roll back only to the last durable
    /// checkpoint boundary (the dead shard's in-flight state is gone)
    /// but pay **no** restore penalty and keep their admission record:
    /// the super-model re-shards elastically instead of restarting.
    ///
    /// The dead slot strands into the allocator's holed side-list
    /// immediately (its owner no longer holds it, and `release` routes
    /// by the down-mask), preserving the strand-but-account
    /// conservation `free_gpus() + held == capacity`. The same-instant
    /// scheduling round then re-forms groups from the shrunken owned
    /// allocations through the ordinary hole-aware dispatch path.
    /// Falls back to full eviction for a gang whose shrunken width
    /// cannot hold the fused plan at all. Deterministic: gangs in
    /// running order, members and holders in job-id order.
    pub fn shrink_gpu(
        &mut self,
        node: usize,
        idx: usize,
        t: f64,
        penalty: &HashMap<u64, f64>,
        predictor: &mut Predictor,
    ) -> ShrinkOutcome {
        self.allocator.set_gpu_down(node, idx, true);
        let dead = GpuId { node, idx };
        let touches = |a: &Allocation| {
            a.gpus
                .iter()
                .any(|gpu| gpu.node == node && gpu.idx == idx)
        };
        let mut out = ShrinkOutcome::default();
        let ckpt_oh = self.ckpt_oh_per_step;
        let mut gi = 0;
        while gi < self.running.len() {
            if !touches(&self.running[gi].alloc) {
                gi += 1;
                continue;
            }
            let old_step = self.running[gi].step_time;
            let mut members = self.running[gi].job_ids.clone();
            members.sort_unstable();
            // the device's owner loses it from its gang; the masked
            // slot strands now (release routes by the down-mask)
            let mut owner_ids: Vec<u64> =
                self.allocations.keys().copied().collect();
            owner_ids.sort_unstable();
            if let Some(oid) =
                owner_ids.into_iter().find(|id| {
                    touches(&self.allocations[id])
                })
            {
                let a = self.allocations.get_mut(&oid).unwrap();
                a.gpus.retain(|g| {
                    !(g.node == node && g.idx == idx)
                });
                if a.gpus.is_empty() {
                    // shrunk to nothing: the owner stays a member as
                    // an elastic rider (requeued + re-absorbed or
                    // re-admitted by the following round)
                    self.allocations.remove(&oid);
                }
                self.allocator
                    .release(&Allocation { gpus: vec![dead] });
            }
            // members completed at this very timestamp just release
            // (mirrors fail_gpu)
            for id in &members {
                if self.states[id].completed_at.is_some() {
                    if let Some(a) = self.allocations.remove(id) {
                        self.allocator.release(&a);
                    }
                }
            }
            members.retain(|id| {
                self.states[id].completed_at.is_none()
            });
            // the surviving gang: union of live members' owned gangs
            // (riders own nothing), re-planned at that width
            let gang_alloc = |state: &Self, ids: &[u64]| {
                let mut al = Allocation { gpus: vec![] };
                for id in ids {
                    if let Some(a) = state.allocations.get(id) {
                        al = al.union(a);
                    }
                }
                al
            };
            let specs = |state: &Self, ids: &[u64]| -> Vec<JobSpec> {
                ids.iter()
                    .map(|id| state.states[id].spec.clone())
                    .collect()
            };
            let shrunk = gang_alloc(self, &members);
            let perf = if shrunk.gpus.is_empty() {
                None
            } else {
                predictor.group_perf(&specs(self, &members), &shrunk)
            };
            let Some(perf) = perf else {
                // nothing left to run on, or the fused plan does not
                // fit the surviving width: the whole gang dies the
                // historic way
                self.running.remove(gi);
                for id in members {
                    out.evictions.push(
                        self.evict(id, t, old_step, penalty),
                    );
                }
                continue;
            };
            // Δ^max spill at the shrunken rate: gang cadence over the
            // member's provisioned-width baseline
            let eff = |state: &Self, p: &GroupPerf, al: &Allocation| {
                (p.step_time_s + ckpt_oh)
                    / state.allocator.alloc_speed(al)
            };
            let step = eff(self, &perf, &shrunk);
            let (mut survivors, mut spilled) = (vec![], vec![]);
            for id in members {
                let st = &self.states[&id];
                let slow = step / st.iso_step_time.max(1e-12);
                if slow > st.spec.max_slowdown {
                    spilled.push(id);
                } else {
                    survivors.push(id);
                }
            }
            for id in &spilled {
                out.evictions.push(
                    self.evict(*id, t, old_step, penalty),
                );
            }
            // spilled owners took their GPUs with them: re-plan the
            // remainder (fewer members sharing can only help)
            let (alloc2, perf2) = if spilled.is_empty() {
                (shrunk, perf)
            } else {
                let al = gang_alloc(self, &survivors);
                let p = if survivors.is_empty()
                    || al.gpus.is_empty()
                {
                    None
                } else {
                    predictor
                        .group_perf(&specs(self, &survivors), &al)
                };
                match p {
                    Some(p) => (al, p),
                    None => {
                        self.running.remove(gi);
                        for id in survivors {
                            out.evictions.push(self.evict(
                                id, t, old_step, penalty,
                            ));
                        }
                        continue;
                    }
                }
            };
            // survivors: checkpoint-boundary rollback, no restore
            // penalty, no restart, no requeue — they keep training
            let k = self.ckpt_interval;
            for id in &survivors {
                let st = self.states.get_mut(id).unwrap();
                let boundary = (st.steps_done / k).floor() * k;
                out.rollback_lost_s +=
                    (st.steps_done - boundary) * old_step;
                st.steps_done = boundary;
            }
            let step2 = eff(self, &perf2, &alloc2);
            let speed2 = self.allocator.alloc_speed(&alloc2);
            let g = &mut self.running[gi];
            g.job_ids = survivors.clone();
            g.alloc = alloc2;
            g.base_step_time = perf2.step_time_s + ckpt_oh;
            g.speed = speed2;
            g.step_time = step2;
            g.compute_util = perf2.compute_util;
            g.comp_s = perf2.plan.comp_s;
            g.comm_s = perf2.plan.comm_s;
            out.groups_shrunk += 1;
            out.shrunk_jobs.extend(survivors);
            gi += 1;
        }
        // held-but-not-running owners touching the device (a dispatch
        // probe failure can leave a job with a gang but no group):
        // shrink the gang in place too, in id order
        let mut held: Vec<u64> = self
            .allocations
            .iter()
            .filter(|(_, a)| touches(a))
            .map(|(id, _)| *id)
            .collect();
        held.sort_unstable();
        for id in held {
            if self.states[&id].completed_at.is_some() {
                if let Some(a) = self.allocations.remove(&id) {
                    self.allocator.release(&a);
                }
                continue;
            }
            let a = self.allocations.get_mut(&id).unwrap();
            a.gpus.retain(|g| !(g.node == node && g.idx == idx));
            let emptied = a.gpus.is_empty();
            if emptied {
                self.allocations.remove(&id);
            }
            self.allocator.release(&Allocation { gpus: vec![dead] });
            if emptied {
                // nothing left to hold: requeue through the normal
                // path (priced at 0 — it was not running)
                out.evictions
                    .push(self.evict(id, t, 0.0, penalty));
            } else {
                out.shrunk_jobs.push(id);
            }
        }
        out.shrunk_jobs.sort_unstable();
        out
    }

    /// Regrow shrunken gangs: owners left under-provisioned by
    /// [`SimState::shrink_gpu`] (owned width below their spec width —
    /// nothing else creates that state) are topped back up to full
    /// width from the free pool. Runs every scheduling round while
    /// shrink scenarios are active, which covers both regrow triggers:
    /// a `GpuRecovery` returning the healed slot, and ordinary
    /// completions freeing backfill capacity. Deterministic contract:
    /// candidates in job-id order, all-or-nothing per job (a partial
    /// top-up would churn the gang rate every round for no policy
    /// gain), degraded running jobs made whole before the same
    /// round's fresh admissions. Returns the regrown job ids.
    pub fn regrow_shrunken(&mut self) -> Vec<u64> {
        let states = &self.states;
        let mut ids: Vec<u64> = self
            .allocations
            .iter()
            .filter(|(id, a)| {
                states[*id].completed_at.is_none()
                    && a.n_gpus() < states[*id].spec.gpus
            })
            .map(|(id, _)| *id)
            .collect();
        ids.sort_unstable();
        let mut regrown = vec![];
        for id in ids {
            let need = self.states[&id].spec.gpus
                - self.allocations[&id].n_gpus();
            let Some(extra) = self.allocator.allocate(need) else {
                continue;
            };
            let a = self.allocations.get_mut(&id).unwrap();
            *a = a.union(&extra);
            regrown.push(id);
        }
        regrown
    }

    /// Set `node`'s throughput multiplier (straggler degrade/restore)
    /// and re-price every running group whose gang touches it *at this
    /// instant*: progress already accrued at the old rate stays
    /// (the engine advances time before applying the event), and the
    /// group's effective step time switches to
    /// `base_step_time / min-node-speed` from now on — the in-progress
    /// fractional step is thereby re-priced exactly at the transition,
    /// with no discretization. The following scheduling round
    /// re-derives completion events from the new rates through the
    /// ordinary epoch-staleness machinery.
    pub fn set_node_speed(&mut self, node: usize, speed: f64) {
        self.allocator.set_speed(node, speed);
        for g in &mut self.running {
            if g.alloc.gpus.iter().any(|gpu| gpu.node == node) {
                g.speed = self.allocator.alloc_speed(&g.alloc);
                g.step_time = g.base_step_time / g.speed;
            }
        }
    }

    /// Straggler migration (mechanism half; the *decision* — which
    /// nodes are flagged — comes from the detection estimator via the
    /// engine). Every uncompleted job whose owned gang touches a
    /// `flagged` node (estimated slowdown past the migrate threshold)
    /// is evicted exactly like a preemption: in-flight fractional step
    /// rolled back at the group's effective rate, gang released,
    /// checkpoint-restore penalty charged, requeued — admission then
    /// re-places it preferring nodes outside `avoid` (the suspected
    /// set, a superset of `flagged`). Jobs are migrated only while
    /// enough capacity to re-place them all exists outside `avoid`,
    /// tracked through an **in-round reservation ledger**: a per-node
    /// residual seeded from the live free lists, credited with the
    /// GPUs the migrating gang itself releases on usable nodes (a
    /// gang straddling one slow node frees its healthy-node share as
    /// part of the move), and debited by each accepted migration's
    /// full re-placement need — so the second migration in a round
    /// sees the residual the first one left, never the round-start
    /// snapshot. Slots that are individually *holed*
    /// ([`Allocator::gpu_is_down`]) release into the strand, not the
    /// pool, and are never credited — counting them over-committed
    /// exactly the partially-failed gang this PR models. The ledger
    /// still reserves against the allocator only for this instant:
    /// competing queued jobs admitted during the restore window can
    /// take the capacity first, in which case the avoid-fallback may
    /// land a migrated job back on a slow node (a slow GPU beats no
    /// GPU). Returns the evictions in job-id order.
    pub fn migrate_stragglers(
        &mut self,
        flagged: &[bool],
        avoid: &[bool],
        t: f64,
        penalty: &HashMap<u64, f64>,
    ) -> Vec<Eviction> {
        let usable = |alloc: &Allocator, node: usize| -> bool {
            !alloc.is_down(node)
                && !avoid.get(node).copied().unwrap_or(false)
        };
        // the reservation ledger: free GPUs per usable node right now
        let n_nodes = self.allocator.spec().n_nodes;
        let mut avail: Vec<usize> = (0..n_nodes)
            .map(|node| {
                if usable(&self.allocator, node) {
                    self.allocator.free_on(node)
                } else {
                    0
                }
            })
            .collect();
        let mut ids: Vec<u64> = self
            .allocations
            .iter()
            .filter(|(id, a)| {
                self.states[*id].completed_at.is_none()
                    && a.gpus.iter().any(|g| {
                        flagged.get(g.node).copied().unwrap_or(false)
                    })
            })
            .map(|(id, _)| *id)
            .collect();
        ids.sort_unstable();
        let mut evictions = vec![];
        for id in ids {
            let need = self.states[&id].spec.gpus;
            // GPUs this gang gives back on usable nodes when it
            // moves: they join the pool its own re-placement draws
            // from. Holed slots strand on release and must not count.
            let mut credit = vec![0usize; n_nodes];
            for g in &self.allocations[&id].gpus {
                if usable(&self.allocator, g.node)
                    && !self.allocator.gpu_is_down(g.node, g.idx)
                {
                    credit[g.node] += 1;
                }
            }
            let total: usize = avail.iter().sum::<usize>()
                + credit.iter().sum::<usize>();
            if need > total {
                continue;
            }
            // commit the reservation: fold the credit in, then debit
            // the full need (node order is bookkeeping only — the
            // accept decision is capacity-total, like the allocator's
            // own spill)
            for (node, c) in credit.into_iter().enumerate() {
                avail[node] += c;
            }
            let mut debit = need;
            for a in avail.iter_mut() {
                let take = (*a).min(debit);
                *a -= take;
                debit -= take;
                if debit == 0 {
                    break;
                }
            }
            // mechanically identical to an exogenous preemption:
            // group removal, rollback priced at the group rate, gang
            // release, restore window, requeue (the job holds an
            // allocation, so this never returns None)
            if let Some(e) = self.preempt(id, t, penalty) {
                evictions.push(e);
            }
        }
        evictions
    }

    /// Exogenously preempt job `id` at time `t` (spot reclaim /
    /// higher-priority tenant). A no-op unless the job is currently
    /// placed (running in a group or holding a gang). If its group had
    /// other members they keep running until the round that follows
    /// regroups them.
    pub fn preempt(
        &mut self,
        id: u64,
        t: f64,
        penalty: &HashMap<u64, f64>,
    ) -> Option<Eviction> {
        let st = self.states.get(&id)?;
        if st.completed_at.is_some() {
            return None;
        }
        let gi = self
            .running
            .iter()
            .position(|g| g.job_ids.contains(&id));
        if gi.is_none() && !self.allocations.contains_key(&id) {
            return None; // queued / restoring: nothing to take away
        }
        let mut step_time = 0.0;
        if let Some(gi) = gi {
            let g = &mut self.running[gi];
            step_time = g.step_time;
            g.job_ids.retain(|j| *j != id);
            if g.job_ids.is_empty() {
                self.running.remove(gi);
            }
        }
        Some(self.evict(id, t, step_time, penalty))
    }

    /// Allocate GPUs to queued jobs (FIFO; id breaks submit-time ties
    /// so the order never depends on map order). When a detection-aware
    /// policy supplies `avoid` (suspected stragglers), placements
    /// prefer unflagged nodes and fall back to flagged ones only when
    /// nothing else fits ([`Allocator::allocate_avoiding`]); `None` is
    /// the ordinary oblivious path, bit-identical to the
    /// pre-straggler engine. Returns jobs admitted for the first time
    /// (for observers).
    pub fn admit_queued(
        &mut self,
        max_concurrent: usize,
        predictor: &mut Predictor,
        t: f64,
        avoid: Option<&[bool]>,
    ) -> Vec<u64> {
        let states = &self.states;
        self.queue.sort_by(|a, b| {
            f64_cmp(
                states[a].spec.submit_time,
                states[b].spec.submit_time,
            )
            .then(a.cmp(b))
        });
        // owned, uncompleted jobs (shared members are re-queued above
        // and counted as they are re-admitted)
        let running_count: usize = self
            .allocations
            .iter()
            .filter(|(id, _)| states[id].completed_at.is_none())
            .count();
        let drained: Vec<u64> = self.queue.drain(..).collect();
        let mut still = vec![];
        let mut newly = vec![];
        let mut admitted_now = 0usize;
        for id in drained {
            // an evicted job is unrunnable until its checkpoint restore
            // finishes; it waits in the queue without consuming a slot
            if self.states[&id].restart_at > t {
                still.push(id);
                continue;
            }
            let spec = self.states[&id].spec.clone();
            let cap_ok = running_count + admitted_now < max_concurrent;
            if cap_ok {
                let got = match avoid {
                    Some(av) => {
                        self.allocator.allocate_avoiding(spec.gpus, av)
                    }
                    None => self.allocator.allocate(spec.gpus),
                };
                if let Some(a) = got {
                    let iso = predictor
                        .isolated_step_time(&spec, &a)
                        .unwrap_or(f64::INFINITY);
                    let st = self.states.get_mut(&id).unwrap();
                    let first = st.admitted_at.is_none();
                    st.admitted_at = Some(t);
                    st.iso_step_time = iso;
                    self.allocations.insert(id, a);
                    admitted_now += 1;
                    if first {
                        newly.push(id);
                    }
                    continue;
                }
            }
            still.push(id);
        }
        self.queue = still;
        newly
    }

    /// Build the scheduler's candidate list from all admitted,
    /// unfinished jobs. Walks allocations in job-id order: HashMap
    /// iteration order is nondeterministic per instance, and the
    /// candidate order feeds the scheduler's tie-breaking —
    /// bit-identical reruns require a canonical order here.
    pub fn build_candidates(
        &self,
        predictor: &mut Predictor,
        t: f64,
    ) -> Vec<Candidate> {
        let mut candidates = vec![];
        let mut alloc_ids: Vec<u64> =
            self.allocations.keys().copied().collect();
        alloc_ids.sort_unstable();
        for id in alloc_ids {
            let a = &self.allocations[&id];
            let st = &self.states[&id];
            if st.completed_at.is_some() {
                continue;
            }
            // current slowdown estimate from the group it last ran in
            let cur_slow = self
                .running
                .iter()
                .find(|g| g.job_ids.contains(&id))
                .map(|g| g.step_time / st.iso_step_time.max(1e-12))
                .unwrap_or(1.0);
            let wait_frac = if t > st.spec.submit_time {
                (t - st.admitted_at.unwrap_or(t))
                    .max(0.0)
                    .min(t - st.spec.submit_time)
                    / (t - st.spec.submit_time)
            } else {
                0.0
            };
            let residual =
                predictor.residual(&st.spec, a).unwrap_or(0.5);
            candidates.push(Candidate {
                job: st.spec.clone(),
                alloc: a.clone(),
                urgency: urgency(
                    cur_slow,
                    st.spec.max_slowdown,
                    wait_frac,
                ),
                residual,
            });
        }
        candidates
    }

    /// Elastic shared admission (the Shared Super-Model's headline
    /// mechanism, §3.4): jobs still queued because no GPUs are free may
    /// be absorbed into an existing group, sharing its GPUs. *Which*
    /// group absorbs is the policy's call
    /// ([`PolicyHooks::elastic_admit`]); committing the absorption —
    /// perf refresh, iso baseline, admission timestamp — is mechanism
    /// and happens here. Returns jobs admitted for the first time.
    #[allow(clippy::too_many_arguments)]
    pub fn absorb_queued(
        &mut self,
        groups: &mut Vec<(GroupState, GroupPerf)>,
        hooks: &dyn PolicyHooks,
        view: &NodeView,
        predictor: &mut Predictor,
        sched: &SchedulerConfig,
        max_concurrent: usize,
        t: f64,
    ) -> Vec<u64> {
        let drained: Vec<u64> = self.queue.drain(..).collect();
        let mut still = vec![];
        let mut newly = vec![];
        let mut shared_now = 0usize;
        for id in drained {
            // restore window not elapsed: not even elastic absorption
            // can run the job yet
            if self.states[&id].restart_at > t {
                still.push(id);
                continue;
            }
            let n_running: usize =
                groups.iter().map(|(g, _)| g.jobs.len()).sum();
            if n_running + shared_now >= max_concurrent {
                still.push(id);
                continue;
            }
            let spec = self.states[&id].spec.clone();
            match hooks.elastic_admit(
                &spec,
                groups.as_slice(),
                view,
                predictor,
                sched,
            ) {
                Some(gi) => {
                    let (g, _) = &mut groups[gi];
                    g.jobs.push(spec.clone());
                    let alloc = g.alloc.clone();
                    // hooks are not required to have probed
                    // feasibility; an infeasible choice leaves the job
                    // queued instead of crashing the run
                    let Some(perf2) =
                        predictor.group_perf(&g.jobs, &alloc)
                    else {
                        g.jobs.pop();
                        still.push(id);
                        continue;
                    };
                    let iso = {
                        // the job's nominal share of the gang: its
                        // first `gpus` devices (same baseline the
                        // predictor's slowdown accounting uses)
                        let sub = Allocation {
                            gpus: alloc
                                .gpus
                                .iter()
                                .take(spec.gpus.max(1))
                                .cloned()
                                .collect(),
                        };
                        predictor
                            .isolated_step_time(&spec, &sub)
                            .unwrap_or(f64::INFINITY)
                    };
                    let st = self.states.get_mut(&id).unwrap();
                    // set exactly once: re-absorptions on later rounds
                    // must not churn the admission record
                    if st.admitted_at.is_none() {
                        st.admitted_at = Some(t);
                        st.iso_step_time = iso;
                        newly.push(id);
                    }
                    groups[gi].1 = perf2;
                    shared_now += 1;
                }
                None => still.push(id),
            }
        }
        self.queue = still;
        newly
    }

    /// Replace the running set with this round's groups, carrying AIMD
    /// controllers across rounds keyed by group membership. Step rates
    /// come from the carried controller's current nano count (fused
    /// policies) or the plain plan (unfused).
    ///
    /// **Bitwise-rate contract** (the engine's dirty-set completion
    /// re-derivation depends on it): for a group whose membership,
    /// allocation, plan, AIMD nano count, and node speeds are all
    /// unchanged, this recomputes *bit-identical* `step_time` —
    /// every input below is either carried state or a pure function
    /// of it (`iter_time`, `alloc_speed`, IEEE division). The engine
    /// compares `step_time.to_bits()` against each job's anchored
    /// completion record; equal bits ⇒ the live event stays valid.
    pub fn install_groups(
        &mut self,
        groups: Vec<(GroupState, GroupPerf)>,
        aimd_enabled: bool,
        cfg: &ExperimentConfig,
    ) {
        let mut prev_aimd: HashMap<Vec<u64>, AimdController> = self
            .running
            .drain(..)
            .filter_map(|g| {
                let mut ids = g.job_ids.clone();
                ids.sort_unstable();
                g.aimd.map(|c| (ids, c))
            })
            .collect();
        for (g, perf) in groups {
            let mut ids: Vec<u64> =
                g.jobs.iter().map(|j| j.id).collect();
            ids.sort_unstable();
            let aimd = if aimd_enabled {
                Some(prev_aimd.remove(&ids).unwrap_or_else(|| {
                    AimdController::new(cfg.aimd.clone())
                }))
            } else {
                None
            };
            let gpu = &cfg.cluster.gpu;
            let oh = gpu.launch_overhead_s * 4.0;
            let lat = if g.alloc.spans_nodes() {
                cfg.cluster.ib_latency_s
            } else {
                1e-6
            };
            // amortized periodic checkpoint writes ride on every step
            // (+0.0 — bit-exact — at the default free cadence)
            let base_step_time = match &aimd {
                Some(c) => iter_time(
                    perf.plan.comp_s,
                    perf.plan.comm_s,
                    c.n(),
                    oh,
                    lat,
                ),
                None => perf.step_time_s,
            } + self.ckpt_oh_per_step;
            // straggler drag: the gang runs at its slowest node's
            // multiplier (exactly base/1.0 = base when healthy)
            let speed = self.allocator.alloc_speed(&g.alloc);
            self.running.push(RunningGroup {
                job_ids: ids,
                alloc: g.alloc,
                step_time: base_step_time / speed,
                base_step_time,
                speed,
                compute_util: perf.compute_util,
                comp_s: perf.plan.comp_s,
                comm_s: perf.plan.comm_s,
                oh,
                lat,
                aimd,
            });
        }
    }

    /// All job states sorted by id — the canonical order for final
    /// accumulations (f64 addition is not associative-in-bits, so
    /// summing in HashMap order would break bit-determinism).
    pub fn sorted_states(&self) -> Vec<&JobState> {
        let mut ids: Vec<u64> = self.states.keys().copied().collect();
        ids.sort_unstable();
        ids.iter().map(|id| &self.states[id]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::planner::PlanOptions;

    fn job(id: u64, gpus: usize) -> JobSpec {
        JobSpec {
            id,
            base_model: "llama3-8b".into(),
            rank: 8,
            batch_size: 4,
            seq_len: 512,
            gpus,
            total_steps: 100,
            submit_time: 0.0,
            max_slowdown: 1.5,
        }
    }

    /// Place `id` on `alloc` with a synthetic running group at a fixed
    /// step rate, so evictions price rolled-back work.
    fn place(st: &mut SimState, id: u64, alloc: Allocation, step: f64) {
        st.states.get_mut(&id).unwrap().admitted_at = Some(0.0);
        st.running.push(RunningGroup {
            job_ids: vec![id],
            alloc: alloc.clone(),
            step_time: step,
            base_step_time: step,
            speed: 1.0,
            compute_util: 0.5,
            aimd: None,
            comp_s: step,
            comm_s: 0.0,
            oh: 0.0,
            lat: 0.0,
        });
        st.allocations.insert(id, alloc);
    }

    #[test]
    fn eviction_rolls_back_to_checkpoint_boundary() {
        let mut cfg = ExperimentConfig::default();
        cfg.faults.ckpt_interval_steps = 5;
        let jobs = vec![job(1, 2)];
        let mut st = SimState::new(&cfg, &jobs);
        let a = st.allocator.allocate(2).unwrap();
        place(&mut st, 1, a, 3.0);
        st.states.get_mut(&1).unwrap().steps_done = 12.7;
        let penalty: HashMap<u64, f64> = [(1, 7.0)].into();
        let e = st.preempt(1, 50.0, &penalty).unwrap();
        // last durable boundary is step 10, not step 12: the whole
        // steps since it are lost too
        assert_eq!(st.states[&1].steps_done, 10.0);
        assert!((e.lost_s - 2.7 * 3.0).abs() < 1e-9, "{}", e.lost_s);
        assert_eq!(e.penalty_s, 7.0);
        assert_eq!(st.states[&1].restart_at, 57.0);
        assert_eq!(st.states[&1].restarts, 1);
    }

    #[test]
    fn default_cadence_rollback_is_bitwise_legacy() {
        // the differential the byte-identity criterion rests on:
        // floor(x / 1.0) * 1.0 == floor(x) in IEEE bits
        for x in [0.0, 0.25, 7.6, 123.999, 1e6 + 0.5, 3.9e15] {
            assert_eq!(
                ((x / 1.0).floor() * 1.0).to_bits(),
                x.floor().to_bits(),
                "{x}"
            );
        }
        // and through the public eviction path at the default config
        let cfg = ExperimentConfig::default();
        let jobs = vec![job(1, 2)];
        let mut st = SimState::new(&cfg, &jobs);
        let a = st.allocator.allocate(2).unwrap();
        place(&mut st, 1, a, 2.0);
        st.states.get_mut(&1).unwrap().steps_done = 7.6;
        let e = st.preempt(1, 10.0, &HashMap::new()).unwrap();
        assert_eq!(st.states[&1].steps_done, 7.0);
        assert!((e.lost_s - 0.6 * 2.0).abs() < 1e-9);
    }

    #[test]
    fn ckpt_write_overhead_charged_into_step_time() {
        let jobs = vec![job(1, 2)];
        let mut cfg = ExperimentConfig::default();
        cfg.faults.ckpt_interval_steps = 10;
        cfg.faults.ckpt_write_s = 5.0;
        let mut pred = Predictor::new(
            cfg.cluster.clone(),
            PlanOptions::default(),
        );
        let mut st = SimState::new(&cfg, &jobs);
        let a = st.allocator.allocate(2).unwrap();
        let perf = pred.group_perf(&jobs, &a).unwrap();
        let g = GroupState {
            jobs: jobs.clone(),
            alloc: a.clone(),
            urgency: 0.0,
            residual: 0.0,
        };
        st.allocations.insert(1, a.clone());
        st.install_groups(vec![(g, perf.clone())], false, &cfg);
        // 5 s every 10 steps = 0.5 s/step on top of the planned rate
        assert_eq!(
            st.running[0].base_step_time.to_bits(),
            (perf.step_time_s + 0.5).to_bits()
        );
        // default cadence charges exactly nothing, bit-for-bit
        let cfg0 = ExperimentConfig::default();
        let mut st0 = SimState::new(&cfg0, &jobs);
        let a0 = st0.allocator.allocate(2).unwrap();
        let perf0 = pred.group_perf(&jobs, &a0).unwrap();
        let g0 = GroupState {
            jobs: jobs.clone(),
            alloc: a0.clone(),
            urgency: 0.0,
            residual: 0.0,
        };
        st0.allocations.insert(1, a0);
        st0.install_groups(vec![(g0, perf0.clone())], false, &cfg0);
        assert_eq!(
            st0.running[0].base_step_time.to_bits(),
            perf0.step_time_s.to_bits()
        );
    }

    #[test]
    fn migration_credits_gang_self_released_capacity() {
        // 3 nodes x 8 GPUs; the gang holds node 0 + node 1, node 0 is
        // flagged. Free capacity outside `avoid` is only node 2's
        // 8 GPUs — less than the 16 needed — but the move itself frees
        // the gang's 8 GPUs on (unflagged) node 1. Pre-fix this
        // migration was refused; it must now proceed and re-place
        // entirely off the flagged node.
        let mut cfg = ExperimentConfig::default();
        cfg.cluster = ClusterSpec::with_gpus(24);
        let jobs = vec![job(1, 16)];
        let mut st = SimState::new(&cfg, &jobs);
        let a = st.allocator.allocate(16).unwrap();
        assert_eq!(a.nodes(), vec![0, 1], "spill layout changed");
        place(&mut st, 1, a, 2.0);
        st.states.get_mut(&1).unwrap().steps_done = 3.5;
        let flagged = [true, false, false];
        let ev = st.migrate_stragglers(
            &flagged,
            &flagged,
            100.0,
            &HashMap::new(),
        );
        assert_eq!(ev.len(), 1, "partially-affected gang not migrated");
        assert_eq!(ev[0].job_id, 1);
        assert_eq!(st.states[&1].steps_done, 3.0);
        assert!((ev[0].lost_s - 0.5 * 2.0).abs() < 1e-9);
        // re-placement lands entirely on unflagged nodes
        let mut pred = Predictor::new(
            cfg.cluster.clone(),
            PlanOptions::default(),
        );
        st.admit_queued(128, &mut pred, 100.0, Some(&flagged));
        let a = &st.allocations[&1];
        assert_eq!(a.n_gpus(), 16);
        assert!(a.gpus.iter().all(|g| g.node != 0));
    }

    #[test]
    fn gpu_failure_evicts_only_touching_gangs() {
        // two gangs on two nodes; one device on the first node dies.
        // Only the touching gang is evicted — the second keeps
        // running, and the node's survivors return to the pool while
        // the holed slot strands.
        let mut cfg = ExperimentConfig::default();
        cfg.cluster = ClusterSpec::with_gpus(24);
        let jobs = vec![job(1, 8), job(2, 8)];
        let mut st = SimState::new(&cfg, &jobs);
        let a1 = st.allocator.allocate(8).unwrap();
        assert_eq!(a1.nodes(), vec![0]);
        let a2 = st.allocator.allocate(8).unwrap();
        assert_eq!(a2.nodes(), vec![1]);
        place(&mut st, 1, a1, 2.0);
        place(&mut st, 2, a2, 2.0);
        st.states.get_mut(&1).unwrap().steps_done = 3.5;
        let penalty: HashMap<u64, f64> = [(1, 5.0)].into();
        let ev = st.fail_gpu(0, 3, 10.0, &penalty);
        assert_eq!(ev.len(), 1, "only the touching gang dies");
        assert_eq!(ev[0].job_id, 1);
        assert_eq!(ev[0].penalty_s, 5.0);
        assert!((ev[0].lost_s - 0.5 * 2.0).abs() < 1e-9);
        assert_eq!(st.running.len(), 1);
        assert_eq!(st.running[0].job_ids, vec![2]);
        assert_eq!(st.states[&2].restarts, 0);
        // 7 survivors free, 1 stranded, job 2's 8 still held
        assert_eq!(st.allocator.available_gpus(), 15);
        assert_eq!(st.allocator.free_gpus(), 16);
        // a hit on a *free* device evicts nobody
        let ev2 = st.fail_gpu(2, 0, 11.0, &HashMap::new());
        assert!(ev2.is_empty());
        assert_eq!(st.allocator.available_gpus(), 14);
        st.recover_gpu(0, 3);
        st.recover_gpu(2, 0);
        assert_eq!(st.allocator.available_gpus(), 16);
    }

    #[test]
    fn migration_ledger_sees_residual_and_skips_holed_credit() {
        // the pinned 2-migration over-commit scenario: 5 nodes x 8.
        // Gang A holds nodes 0+1 (8+4), gang B nodes 2+3 (8+4);
        // nodes 0 and 2 are flagged. Two of A's node-1 slots are
        // holed (devices failed under the gang), so on eviction they
        // strand instead of freeing. The round-start snapshot plus
        // full self-credit would accept both migrations (14 free + 4
        // credit each >= 12 each) — but real post-move capacity is
        // only 22 of the 24 needed, landing one job back on a flagged
        // node. The reservation ledger credits only the 2 non-holed
        // slots and debits A's full need, so B correctly refuses.
        let mut cfg = ExperimentConfig::default();
        cfg.cluster = ClusterSpec::with_gpus(40);
        let jobs = vec![job(1, 12), job(2, 12)];
        let mut st = SimState::new(&cfg, &jobs);
        let a1 = st.allocator.allocate(12).unwrap();
        assert_eq!(a1.nodes(), vec![0, 1], "spill layout changed");
        let a2 = st.allocator.allocate(12).unwrap();
        assert_eq!(a2.nodes(), vec![2, 3], "spill layout changed");
        place(&mut st, 1, a1, 2.0);
        place(&mut st, 2, a2, 2.0);
        st.states.get_mut(&1).unwrap().steps_done = 3.5;
        st.states.get_mut(&2).unwrap().steps_done = 3.5;
        // holes open under A's node-1 share
        st.allocator.set_gpu_down(1, 0, true);
        st.allocator.set_gpu_down(1, 1, true);
        let flagged = [true, false, true, false, false];
        let ev = st.migrate_stragglers(
            &flagged,
            &flagged,
            100.0,
            &HashMap::new(),
        );
        assert_eq!(ev.len(), 1, "second migration must see residual");
        assert_eq!(ev[0].job_id, 1);
        assert_eq!(st.states[&2].restarts, 0, "B over-committed");
        assert_eq!(st.states[&2].steps_done, 3.5);
        // A's re-placement fits entirely off the flagged nodes and
        // off the stranded slots
        let mut pred = Predictor::new(
            cfg.cluster.clone(),
            PlanOptions::default(),
        );
        st.admit_queued(128, &mut pred, 100.0, Some(&flagged));
        let a = &st.allocations[&1];
        assert_eq!(a.n_gpus(), 12);
        assert!(a.gpus.iter().all(|g| g.node != 0 && g.node != 2));
        assert!(a
            .gpus
            .iter()
            .all(|g| !(g.node == 1 && g.idx < 2)));
    }

    #[test]
    fn gpu_shrink_keeps_gang_running_and_strands_the_slot() {
        // one 8-GPU gang fills a single node; one device dies. With a
        // loose Δ^max the gang shrinks in place: rollback to the last
        // checkpoint boundary, NO restart/penalty/requeue, the gang
        // keeps running at width 7, and the dead slot strands. Regrow
        // tops it back up only once the slot heals (no other free
        // capacity exists on this 8-GPU fleet).
        let mut cfg = ExperimentConfig::default();
        cfg.cluster = ClusterSpec::with_gpus(8);
        cfg.faults.ckpt_interval_steps = 5;
        let mut j = job(1, 8);
        j.max_slowdown = 10.0;
        let jobs = vec![j];
        let mut pred = Predictor::new(
            cfg.cluster.clone(),
            PlanOptions::default(),
        );
        let mut st = SimState::new(&cfg, &jobs);
        let a = st.allocator.allocate(8).unwrap();
        let iso = pred
            .isolated_step_time(&jobs[0], &a)
            .unwrap();
        place(&mut st, 1, a, 2.0);
        st.states.get_mut(&1).unwrap().iso_step_time = iso;
        st.states.get_mut(&1).unwrap().steps_done = 12.7;
        let penalty: HashMap<u64, f64> = [(1, 5.0)].into();
        let out = st.shrink_gpu(0, 3, 50.0, &penalty, &mut pred);
        assert!(out.evictions.is_empty(), "{:?}", out.evictions);
        assert_eq!(out.shrunk_jobs, vec![1]);
        assert_eq!(out.groups_shrunk, 1);
        assert!((out.rollback_lost_s - 2.7 * 2.0).abs() < 1e-9);
        // survivor semantics: boundary rollback, no restart machinery
        assert_eq!(st.states[&1].steps_done, 10.0);
        assert_eq!(st.states[&1].restarts, 0);
        assert_eq!(st.states[&1].restart_at, 0.0);
        assert!(st.queue.is_empty());
        // the gang keeps running at width 7, dead device dropped
        assert_eq!(st.running.len(), 1);
        assert_eq!(st.running[0].job_ids, vec![1]);
        assert_eq!(st.running[0].alloc.n_gpus(), 7);
        assert_eq!(st.allocations[&1].n_gpus(), 7);
        assert!(st.allocations[&1]
            .gpus
            .iter()
            .all(|g| !(g.node == 0 && g.idx == 3)));
        // a 7-wide gang is strictly slower than its 8-wide baseline
        assert!(st.running[0].step_time > iso);
        // strand-but-account: the holed slot is free-but-unusable
        assert_eq!(st.allocator.free_gpus(), 1);
        assert_eq!(st.allocator.available_gpus(), 0);
        // no spare capacity: regrow cannot top up yet
        assert!(st.regrow_shrunken().is_empty());
        // the device heals; regrow makes the gang whole again
        st.recover_gpu(0, 3);
        assert_eq!(st.regrow_shrunken(), vec![1]);
        assert_eq!(st.allocations[&1].n_gpus(), 8);
        assert_eq!(st.allocator.free_gpus(), 0);
        assert!(st.regrow_shrunken().is_empty(), "already whole");
    }

    #[test]
    fn shrink_spills_members_past_their_slowdown_budget() {
        // Δ^max = 1.0 cannot absorb any shrink (a 7-wide gang is
        // strictly slower than the 8-wide admission baseline), so the
        // member spills through the normal eviction path: rollback,
        // restore penalty, requeue, restarts += 1 — exactly the
        // fail_gpu outcome.
        let mut cfg = ExperimentConfig::default();
        cfg.cluster = ClusterSpec::with_gpus(8);
        cfg.faults.ckpt_interval_steps = 5;
        let mut j = job(1, 8);
        j.max_slowdown = 1.0;
        let jobs = vec![j];
        let mut pred = Predictor::new(
            cfg.cluster.clone(),
            PlanOptions::default(),
        );
        let mut st = SimState::new(&cfg, &jobs);
        let a = st.allocator.allocate(8).unwrap();
        let iso = pred
            .isolated_step_time(&jobs[0], &a)
            .unwrap();
        place(&mut st, 1, a, 2.0);
        st.states.get_mut(&1).unwrap().iso_step_time = iso;
        st.states.get_mut(&1).unwrap().steps_done = 12.7;
        let penalty: HashMap<u64, f64> = [(1, 5.0)].into();
        let out = st.shrink_gpu(0, 3, 50.0, &penalty, &mut pred);
        assert_eq!(out.evictions.len(), 1);
        assert_eq!(out.evictions[0].job_id, 1);
        assert_eq!(out.evictions[0].penalty_s, 5.0);
        assert!(
            (out.evictions[0].lost_s - 2.7 * 2.0).abs() < 1e-9
        );
        assert!(out.shrunk_jobs.is_empty());
        assert_eq!(out.groups_shrunk, 0);
        assert_eq!(out.rollback_lost_s, 0.0);
        assert_eq!(st.states[&1].steps_done, 10.0);
        assert_eq!(st.states[&1].restarts, 1);
        assert_eq!(st.states[&1].restart_at, 55.0);
        assert_eq!(st.queue, vec![1]);
        assert!(st.running.is_empty());
        assert!(st.allocations.is_empty());
        // 7 survivors released back to the pool, 1 slot stranded
        assert_eq!(st.allocator.free_gpus(), 8);
        assert_eq!(st.allocator.available_gpus(), 7);
    }

    #[test]
    fn shrink_on_held_but_not_running_gang_drops_the_device() {
        // a dispatch probe failure can leave a job holding a gang with
        // no running group; a shrink there just drops the device from
        // the held allocation (no eviction — it was not running)
        let mut cfg = ExperimentConfig::default();
        cfg.cluster = ClusterSpec::with_gpus(24);
        let jobs = vec![job(1, 8)];
        let mut pred = Predictor::new(
            cfg.cluster.clone(),
            PlanOptions::default(),
        );
        let mut st = SimState::new(&cfg, &jobs);
        let a = st.allocator.allocate(8).unwrap();
        st.allocations.insert(1, a);
        let out = st.shrink_gpu(0, 3, 10.0, &HashMap::new(), &mut pred);
        assert!(out.evictions.is_empty());
        assert_eq!(out.shrunk_jobs, vec![1]);
        assert_eq!(out.groups_shrunk, 0);
        assert_eq!(st.allocations[&1].n_gpus(), 7);
        assert_eq!(st.allocator.free_gpus(), 17);
        assert_eq!(st.allocator.available_gpus(), 16);
        // plenty of spare capacity on the other nodes: regrow
        // backfills immediately, no recovery needed
        assert_eq!(st.regrow_shrunken(), vec![1]);
        assert_eq!(st.allocations[&1].n_gpus(), 8);
    }

    #[test]
    fn migration_still_refused_without_real_capacity() {
        // both gang nodes flagged: the self-credit is zero and node 2
        // alone cannot host 16 GPUs — the guard must still refuse
        let mut cfg = ExperimentConfig::default();
        cfg.cluster = ClusterSpec::with_gpus(24);
        let jobs = vec![job(1, 16)];
        let mut st = SimState::new(&cfg, &jobs);
        let a = st.allocator.allocate(16).unwrap();
        place(&mut st, 1, a, 2.0);
        st.states.get_mut(&1).unwrap().steps_done = 3.5;
        let flagged = [true, true, false];
        let ev = st.migrate_stragglers(
            &flagged,
            &flagged,
            100.0,
            &HashMap::new(),
        );
        assert!(ev.is_empty());
        assert_eq!(st.states[&1].steps_done, 3.5);
        assert_eq!(st.states[&1].restarts, 0);
    }
}
