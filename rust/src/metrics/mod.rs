//! Reporters shared by the benches and the CLI: aligned tables, CSV
//! dumps, CDF series, and paper-vs-measured comparison rows.

use crate::util::stats::Cdf;

/// A simple aligned-column table printer.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v)
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncols)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// RFC 4180 CSV: fields containing a comma, double quote, or
    /// newline are quoted (inner quotes doubled). Plain numeric /
    /// identifier fields emit unchanged, so existing consumers see the
    /// same bytes — only fields that would have corrupted the row
    /// (e.g. warning text with commas) change representation.
    pub fn to_csv(&self) -> String {
        let mut out = csv_row(&self.headers);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&csv_row(r));
            out.push('\n');
        }
        out
    }
}

/// RFC-4180 field quoting. Public so the streaming report writer
/// emits rows through the exact same bytes as [`Table::to_csv`].
pub fn csv_field(f: &str) -> String {
    if f.contains(',') || f.contains('"') || f.contains('\n')
        || f.contains('\r')
    {
        format!("\"{}\"", f.replace('"', "\"\""))
    } else {
        f.to_string()
    }
}

/// One CSV row (no trailing newline) — see [`csv_field`].
pub fn csv_row(cells: &[String]) -> String {
    let mut out = String::new();
    for (i, c) in cells.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&csv_field(c));
    }
    out
}

/// Paper-vs-measured comparison row: the benches print these so
/// EXPERIMENTS.md can quote them directly.
pub fn compare_row(
    table: &mut Table,
    label: &str,
    paper: &str,
    measured: f64,
    unit: &str,
    shape_holds: bool,
) {
    table.row(&[
        label.to_string(),
        paper.to_string(),
        format!("{measured:.3} {unit}"),
        if shape_holds { "yes".into() } else { "NO".into() },
    ]);
}

/// Render a CDF as a gnuplot-ready two-column block.
pub fn cdf_block(name: &str, cdf: &Cdf) -> String {
    let mut out = format!("# CDF {name}\n");
    for &(v, q) in &cdf.points {
        out.push_str(&format!("{v:.4} {q:.4}\n"));
    }
    out
}

/// Write a report file under `out/` (created on demand); returns the
/// path. Failures are soft (benches still print to stdout).
pub fn write_report(name: &str, content: &str) -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new("out");
    std::fs::create_dir_all(dir).ok()?;
    let path = dir.join(name);
    std::fs::write(&path, content).ok()?;
    Some(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-name"));
        // all data lines equal width
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic(expected = "column mismatch")]
    fn table_checks_columns() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_output() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn csv_quotes_fields_with_commas_quotes_newlines() {
        let mut t = Table::new("x", &["a", "b", "c"]);
        t.row(&[
            "plain".into(),
            "has,comma".into(),
            "says \"hi\"".into(),
        ]);
        t.row(&["line\nbreak".into(), "3".into(), "4".into()]);
        let csv = t.to_csv();
        let mut lines = csv.split('\n');
        assert_eq!(lines.next().unwrap(), "a,b,c");
        assert_eq!(
            lines.next().unwrap(),
            "plain,\"has,comma\",\"says \"\"hi\"\"\""
        );
        // the embedded newline stays inside its quoted field
        assert_eq!(lines.next().unwrap(), "\"line");
        assert_eq!(lines.next().unwrap(), "break\",3,4");
    }

    #[test]
    fn csv_unquoted_fields_byte_stable() {
        // the warning-column style values the sweep report emits must
        // not change representation unless they actually need quoting
        let mut t = Table::new("x", &["w"]);
        t.row(&["3 UNFINISHED".into()]);
        t.row(&["-".into()]);
        t.row(&["tlora/j8/g16/r2x/m1/f0".into()]);
        assert_eq!(
            t.to_csv(),
            "w\n3 UNFINISHED\n-\ntlora/j8/g16/r2x/m1/f0\n"
        );
    }

    #[test]
    fn cdf_block_format() {
        let cdf = Cdf::of(&[1.0, 2.0, 3.0, 4.0], 4);
        let s = cdf_block("jct", &cdf);
        assert!(s.starts_with("# CDF jct\n"));
        assert_eq!(s.lines().count(), 5);
    }
}
