//! L3 leader: owns the event loop and process topology for *real*
//! (non-simulated) fused training.
//!
//! The PJRT handles are thread-local by construction (raw C pointers, not
//! `Send`), so the coordinator spawns a dedicated executor thread that
//! builds the `Runtime`/`Trainer` in place; the leader talks to it over
//! channels. Job streams submit per-adapter work, the leader composes
//! round-robin fused batches (the nano-batch-friendly layout), and jobs
//! retire independently as their step budgets complete — the "elastic"
//! part of the Shared Super-Model: remaining jobs keep the fused
//! executable warm and simply mask retired slots.

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::runtime::StepStats;
use crate::train::data::SyntheticCorpus;

enum Request {
    Step {
        tokens: Vec<i32>,
        adapter_ids: Vec<i32>,
        reply: mpsc::Sender<Result<StepStats>>,
    },
    VariantInfo {
        reply: mpsc::Sender<VariantInfo>,
    },
    Shutdown,
}

/// Static info the leader needs from the executor side.
#[derive(Debug, Clone)]
pub struct VariantInfo {
    pub num_adapters: usize,
    pub batch_sizes: Vec<usize>,
    pub seq_len: usize,
    pub vocab: usize,
}

/// Handle to the executor thread.
pub struct Coordinator {
    tx: mpsc::Sender<Request>,
    handle: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the executor thread for `variant`; the PJRT client, the
    /// compiled step, and all device state live on that thread.
    pub fn spawn(artifacts_dir: PathBuf, variant: String, seed: i32)
        -> Result<Coordinator> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::spawn(move || {
            let built = (|| -> Result<_> {
                let rt = crate::runtime::Runtime::new(&artifacts_dir)?;
                let trainer =
                    crate::runtime::Trainer::new(&rt, &variant, seed)?;
                Ok(trainer)
            })();
            let mut trainer = match built {
                Ok(t) => {
                    let _ = ready_tx.send(Ok(()));
                    t
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Step {
                        tokens,
                        adapter_ids,
                        reply,
                    } => {
                        let r = trainer.step(&tokens, &adapter_ids);
                        let _ = reply.send(r);
                    }
                    Request::VariantInfo { reply } => {
                        let cfg = &trainer.variant().config;
                        let _ = reply.send(VariantInfo {
                            num_adapters: cfg.num_adapters,
                            batch_sizes: cfg.batch_sizes.clone(),
                            seq_len: cfg.seq_len,
                            vocab: cfg.vocab,
                        });
                    }
                    Request::Shutdown => break,
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during init"))??;
        Ok(Coordinator {
            tx,
            handle: Some(handle),
        })
    }

    pub fn variant_info(&self) -> Result<VariantInfo> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::VariantInfo { reply })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor gone"))
    }

    /// Synchronous fused step RPC.
    pub fn step(&self, tokens: Vec<i32>, adapter_ids: Vec<i32>)
        -> Result<StepStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Step {
                tokens,
                adapter_ids,
                reply,
            })
            .map_err(|_| anyhow!("executor gone"))?;
        rx.recv().map_err(|_| anyhow!("executor gone"))?
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One client job in a fused run: an adapter slot + a step budget.
#[derive(Debug, Clone)]
pub struct FusedJob {
    pub adapter_slot: usize,
    pub steps: u64,
}

/// Outcome of [`run_fused_jobs`].
#[derive(Debug, Clone)]
pub struct FusedRunReport {
    /// per job: (slot, steps executed, final per-adapter loss)
    pub jobs: Vec<(usize, u64, f32)>,
    pub fused_steps: u64,
    pub mean_step_s: f64,
    /// (fused step, per-adapter losses)
    pub loss_log: Vec<(u64, Vec<f32>)>,
}

/// Drive K jobs with heterogeneous step budgets through one SSM.
/// Jobs retire independently (elastic): once a job's budget is done its
/// slot is masked (adapter id -1 ⇒ zero contribution, frozen adapter).
pub fn run_fused_jobs(
    coord: &Coordinator,
    jobs: &[FusedJob],
    seed: u64,
    log_every: u64,
) -> Result<FusedRunReport> {
    let info = coord.variant_info()?;
    let mut remaining: Vec<u64> = vec![0; info.num_adapters];
    for j in jobs {
        if j.adapter_slot >= info.num_adapters {
            return Err(anyhow!(
                "job slot {} out of range (K={})",
                j.adapter_slot,
                info.num_adapters
            ));
        }
        remaining[j.adapter_slot] = j.steps;
    }
    let mut corpus = SyntheticCorpus::new(
        info.vocab,
        info.seq_len,
        info.num_adapters,
        seed,
    );
    let mut executed: Vec<u64> = vec![0; info.num_adapters];
    let mut last_per: Vec<f32> = vec![f32::NAN; info.num_adapters];
    let mut loss_log = vec![];
    let mut fused_steps = 0u64;
    let t0 = std::time::Instant::now();

    while remaining.iter().any(|&r| r > 0) {
        let (tokens, mut ids) = corpus.fused_batch(&info.batch_sizes);
        // mask retired jobs' slots
        for id in ids.iter_mut() {
            let slot = *id as usize;
            if remaining[slot] == 0 {
                *id = -1;
            }
        }
        let stats = coord.step(tokens, ids)?;
        for slot in 0..info.num_adapters {
            if remaining[slot] > 0 {
                remaining[slot] -= 1;
                executed[slot] += 1;
                last_per[slot] = stats.per_adapter_loss[slot];
            }
        }
        if fused_steps % log_every.max(1) == 0 {
            loss_log.push((fused_steps, stats.per_adapter_loss.clone()));
        }
        fused_steps += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    Ok(FusedRunReport {
        jobs: jobs
            .iter()
            .map(|j| {
                (
                    j.adapter_slot,
                    executed[j.adapter_slot],
                    last_per[j.adapter_slot],
                )
            })
            .collect(),
        fused_steps,
        mean_step_s: elapsed / fused_steps.max(1) as f64,
        loss_log,
    })
}
