//! Shared Super-Model (SSM) graph and the Model Fuser (§3.2).
//!
//! An SSM consolidates K LoRA jobs that share one frozen backbone into a
//! single composite computation graph: nodes are backbone operators
//! (embedding, transformer layers, LM head) and per-job adapter branches;
//! edges carry activation data-flow. The Model Fuser performs the
//! layer-wise architectural fusion, and the resulting graph is what the
//! [`crate::planner`] cost-models to derive a parallel execution plan —
//! "presenting the SSM as a single composite model to existing planning
//! frameworks" (§3.2).
//!
//! The *executable* counterpart of this graph is the AOT-lowered JAX
//! program (`python/compile/model.py`); this Rust representation carries
//! the cost/memory annotations scheduling decisions are made from.

use crate::model::arch::{arch_by_name, LoraSpec, ModelArch};
use crate::model::cost::{layer_cost, lora_layer_cost};
use crate::workload::JobSpec;

/// A LoRA branch attached to a fused backbone layer.
#[derive(Debug, Clone, PartialEq)]
pub struct AdapterBranch {
    pub job_id: u64,
    pub rank: usize,
    pub batch_size: usize,
    pub seq_len: usize,
}

impl AdapterBranch {
    pub fn tokens(&self) -> f64 {
        (self.batch_size * self.seq_len) as f64
    }
}

/// Node kinds in the SSM graph.
#[derive(Debug, Clone, PartialEq)]
pub enum NodeKind {
    /// token embedding (shared)
    Embed,
    /// fused transformer layer `i` (shared backbone compute)
    Layer(usize),
    /// adapter branch of job `job_id` on layer `layer`
    Adapter { layer: usize, job_id: u64 },
    /// LM head + per-job losses
    Head,
}

/// One node with its cost annotations.
#[derive(Debug, Clone, PartialEq)]
pub struct SsmNode {
    pub id: usize,
    pub kind: NodeKind,
    pub fwd_flops: f64,
    pub bwd_flops: f64,
    /// activation bytes flowing out of this node per microbatch
    pub out_bytes: f64,
    /// resident parameter bytes
    pub param_bytes: f64,
}

/// Directed activation-dependency edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SsmEdge {
    pub from: usize,
    pub to: usize,
}

/// Errors from fusing incompatible jobs.
#[derive(Debug, Clone, PartialEq)]
pub enum FuseError {
    EmptyGroup,
    UnknownArch(String),
    MixedBaseModels(String, String),
}

impl std::fmt::Display for FuseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FuseError::EmptyGroup => write!(f, "cannot fuse an empty group"),
            FuseError::UnknownArch(a) => write!(f, "unknown base model {a}"),
            FuseError::MixedBaseModels(a, b) => {
                write!(f, "jobs use different base models: {a} vs {b}")
            }
        }
    }
}

impl std::error::Error for FuseError {}

/// The Shared Super-Model.
#[derive(Debug, Clone)]
pub struct Ssm {
    pub arch: ModelArch,
    pub jobs: Vec<JobSpec>,
    pub adapters: Vec<AdapterBranch>,
    pub nodes: Vec<SsmNode>,
    pub edges: Vec<SsmEdge>,
}

impl Ssm {
    /// The Model Fuser: layer-wise architectural fusion of jobs sharing
    /// one backbone (Alg. 1 line 18: `S_SSM ← M_base ⊕ {Adapter(j)}`).
    pub fn fuse(jobs: &[JobSpec]) -> Result<Ssm, FuseError> {
        let first = jobs.first().ok_or(FuseError::EmptyGroup)?;
        for j in jobs {
            if j.base_model != first.base_model {
                return Err(FuseError::MixedBaseModels(
                    first.base_model.clone(),
                    j.base_model.clone(),
                ));
            }
        }
        let arch = arch_by_name(&first.base_model)
            .ok_or_else(|| FuseError::UnknownArch(first.base_model.clone()))?;

        let adapters: Vec<AdapterBranch> = jobs
            .iter()
            .map(|j| AdapterBranch {
                job_id: j.id,
                rank: j.rank,
                batch_size: j.batch_size,
                seq_len: j.seq_len,
            })
            .collect();

        let total_tokens: f64 = adapters.iter().map(|a| a.tokens()).sum();
        // weighted mean sequence length for the attention term
        let mean_seq = adapters
            .iter()
            .map(|a| a.tokens() * a.seq_len as f64)
            .sum::<f64>()
            / total_tokens;

        let mut nodes = vec![];
        let mut edges = vec![];
        let d = arch.d_model as f64;
        let embed_flops = 2.0 * total_tokens * d; // gather + pos add
        nodes.push(SsmNode {
            id: 0,
            kind: NodeKind::Embed,
            fwd_flops: embed_flops,
            bwd_flops: embed_flops,
            out_bytes: total_tokens * d * arch.dtype_bytes as f64,
            param_bytes: (arch.vocab * arch.d_model * arch.dtype_bytes)
                as f64,
        });

        let mut prev = 0usize;
        for l in 0..arch.n_layers {
            let lc = layer_cost(&arch, total_tokens, mean_seq);
            let layer_id = nodes.len();
            nodes.push(SsmNode {
                id: layer_id,
                kind: NodeKind::Layer(l),
                fwd_flops: lc.fwd_flops,
                bwd_flops: lc.bwd_flops,
                out_bytes: lc.boundary_bytes,
                param_bytes: arch.weight_bytes_per_layer() as f64,
            });
            edges.push(SsmEdge {
                from: prev,
                to: layer_id,
            });
            // adapter branches hang off the layer node
            for a in &adapters {
                let ac = lora_layer_cost(&arch, a.rank, a.tokens());
                let aid = nodes.len();
                nodes.push(SsmNode {
                    id: aid,
                    kind: NodeKind::Adapter {
                        layer: l,
                        job_id: a.job_id,
                    },
                    fwd_flops: ac.fwd_flops,
                    bwd_flops: ac.bwd_flops,
                    out_bytes: 0.0, // rejoins the layer output in place
                    param_bytes: LoraSpec::new(a.rank)
                        .train_state_bytes(&arch)
                        as f64
                        / arch.n_layers as f64,
                });
                edges.push(SsmEdge {
                    from: layer_id,
                    to: aid,
                });
                edges.push(SsmEdge {
                    from: aid,
                    to: layer_id,
                });
            }
            prev = layer_id;
        }

        let head_flops = 2.0 * total_tokens
            * arch.vocab as f64
            * arch.d_model as f64;
        let head_id = nodes.len();
        nodes.push(SsmNode {
            id: head_id,
            kind: NodeKind::Head,
            fwd_flops: head_flops,
            bwd_flops: head_flops,
            out_bytes: 0.0,
            param_bytes: 0.0, // tied to embedding
        });
        edges.push(SsmEdge {
            from: prev,
            to: head_id,
        });

        Ok(Ssm {
            arch,
            jobs: jobs.to_vec(),
            adapters,
            nodes,
            edges,
        })
    }

    /// Total fused tokens per step.
    pub fn total_tokens(&self) -> f64 {
        self.adapters.iter().map(|a| a.tokens()).sum()
    }

    /// Total fused sequences (batch rows) per step.
    pub fn total_batch(&self) -> usize {
        self.adapters.iter().map(|a| a.batch_size).sum()
    }

    /// Per-layer total cost (backbone + all adapter branches), the
    /// vector the pipeline partitioner consumes. Index 0 is the
    /// embedding, 1..=L the layers (with adapters folded in), L+1 the
    /// head — matching how a pipeline would actually cut the model.
    pub fn layer_flops(&self) -> Vec<f64> {
        let l_num = self.arch.n_layers;
        let mut per = vec![0.0; l_num + 2];
        for n in &self.nodes {
            let total = n.fwd_flops + n.bwd_flops;
            match n.kind {
                NodeKind::Embed => per[0] += total,
                NodeKind::Layer(l) => per[l + 1] += total,
                NodeKind::Adapter { layer, .. } => per[layer + 1] += total,
                NodeKind::Head => per[l_num + 1] += total,
            }
        }
        per
    }

    /// Per-layer parameter bytes (same indexing as [`Self::layer_flops`]).
    pub fn layer_param_bytes(&self) -> Vec<f64> {
        let l_num = self.arch.n_layers;
        let mut per = vec![0.0; l_num + 2];
        for n in &self.nodes {
            match n.kind {
                NodeKind::Embed => per[0] += n.param_bytes,
                NodeKind::Layer(l) => per[l + 1] += n.param_bytes,
                NodeKind::Adapter { layer, .. } => {
                    per[layer + 1] += n.param_bytes
                }
                NodeKind::Head => per[l_num + 1] += n.param_bytes,
            }
        }
        per
    }

    /// Activation bytes crossing a cut between consecutive backbone
    /// layers (pipeline-stage boundary traffic per full batch).
    pub fn boundary_bytes(&self) -> f64 {
        self.total_tokens()
            * self.arch.d_model as f64
            * self.arch.dtype_bytes as f64
    }

    /// Adapter-gradient bytes that data-parallel replicas must
    /// all-reduce each step.
    pub fn grad_sync_bytes(&self) -> f64 {
        self.adapters
            .iter()
            .map(|a| LoraSpec::new(a.rank).params(&self.arch) as f64 * 4.0)
            .sum()
    }

    /// Heterogeneity diagnostics (§2's three dimensions): (rank spread,
    /// token spread) as max/min ratios.
    pub fn heterogeneity(&self) -> (f64, f64) {
        let ranks: Vec<f64> =
            self.adapters.iter().map(|a| a.rank as f64).collect();
        let toks: Vec<f64> =
            self.adapters.iter().map(|a| a.tokens()).collect();
        let spread = |v: &[f64]| {
            let mx = v.iter().cloned().fold(f64::MIN, f64::max);
            let mn = v.iter().cloned().fold(f64::MAX, f64::min);
            if mn > 0.0 {
                mx / mn
            } else {
                1.0
            }
        };
        (spread(&ranks), spread(&toks))
    }

    /// Structural validation: the backbone chain is connected, adapters
    /// attach to exactly one layer with a round-trip edge, and node ids
    /// are dense.
    pub fn validate(&self) -> Result<(), String> {
        let l_num = self.arch.n_layers;
        let expect_nodes = 1 + l_num * (1 + self.adapters.len()) + 1;
        if self.nodes.len() != expect_nodes {
            return Err(format!(
                "node count {} != expected {expect_nodes}",
                self.nodes.len()
            ));
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(format!("node {i} has id {}", n.id));
            }
            if n.fwd_flops < 0.0 || n.bwd_flops < 0.0 {
                return Err(format!("node {i} has negative flops"));
            }
        }
        // every adapter node has exactly one in and one out edge to its
        // layer node
        for n in &self.nodes {
            if let NodeKind::Adapter { .. } = n.kind {
                let ins = self.edges.iter().filter(|e| e.to == n.id).count();
                let outs =
                    self.edges.iter().filter(|e| e.from == n.id).count();
                if ins != 1 || outs != 1 {
                    return Err(format!(
                        "adapter node {} has {ins} in / {outs} out edges",
                        n.id
                    ));
                }
            }
        }
        // backbone chain: embed -> layer_0 -> ... -> head reachable
        let mut cur = 0usize;
        for _ in 0..=l_num {
            let next = self
                .edges
                .iter()
                .find(|e| {
                    e.from == cur
                        && matches!(
                            self.nodes[e.to].kind,
                            NodeKind::Layer(_) | NodeKind::Head
                        )
                })
                .map(|e| e.to);
            match next {
                Some(n) => cur = n,
                None => {
                    return Err(format!("backbone chain broken at {cur}"))
                }
            }
        }
        if !matches!(self.nodes[cur].kind, NodeKind::Head) {
            return Err("backbone chain does not end at head".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, rank: usize, batch: usize, model: &str) -> JobSpec {
        JobSpec {
            id,
            base_model: model.into(),
            rank,
            batch_size: batch,
            seq_len: 512,
            gpus: 1,
            total_steps: 100,
            submit_time: 0.0,
            max_slowdown: 1.5,
        }
    }

    #[test]
    fn fuse_two_jobs() {
        let jobs = vec![job(0, 8, 4, "llama3-8b"), job(1, 16, 2, "llama3-8b")];
        let ssm = Ssm::fuse(&jobs).unwrap();
        assert_eq!(ssm.adapters.len(), 2);
        assert_eq!(ssm.total_batch(), 6);
        ssm.validate().unwrap();
    }

    #[test]
    fn fuse_rejects_empty_and_mixed() {
        assert!(matches!(Ssm::fuse(&[]), Err(FuseError::EmptyGroup)));
        let jobs = vec![job(0, 8, 4, "llama3-8b"), job(1, 8, 4, "qwen3-8b")];
        assert!(matches!(
            Ssm::fuse(&jobs),
            Err(FuseError::MixedBaseModels(_, _))
        ));
        let jobs = vec![job(0, 8, 4, "no-such-model")];
        assert!(matches!(Ssm::fuse(&jobs), Err(FuseError::UnknownArch(_))));
    }

    #[test]
    fn backbone_flops_shared_adapters_added() {
        let one = Ssm::fuse(&[job(0, 8, 4, "llama3-8b")]).unwrap();
        let two = Ssm::fuse(&[
            job(0, 8, 4, "llama3-8b"),
            job(1, 8, 4, "llama3-8b"),
        ])
        .unwrap();
        let f1: f64 = one.layer_flops().iter().sum();
        let f2: f64 = two.layer_flops().iter().sum();
        let ratio = f2 / f1;
        assert!((1.9..2.1).contains(&ratio), "{ratio}");
    }

    #[test]
    fn layer_flops_indexing() {
        let ssm = Ssm::fuse(&[job(0, 8, 2, "tiny")]).unwrap();
        let per = ssm.layer_flops();
        assert_eq!(per.len(), ssm.arch.n_layers + 2);
        assert!(per.iter().all(|&f| f > 0.0));
        // head (vocab proj) dominates embed for tiny models
        assert!(per[per.len() - 1] > per[0]);
    }

    #[test]
    fn heterogeneity_spreads() {
        let ssm = Ssm::fuse(&[
            job(0, 2, 1, "llama3-8b"),
            job(1, 16, 8, "llama3-8b"),
        ])
        .unwrap();
        let (rank_spread, tok_spread) = ssm.heterogeneity();
        assert_eq!(rank_spread, 8.0);
        assert_eq!(tok_spread, 8.0);
        let homo = Ssm::fuse(&[
            job(0, 8, 4, "llama3-8b"),
            job(1, 8, 4, "llama3-8b"),
        ])
        .unwrap();
        assert_eq!(homo.heterogeneity(), (1.0, 1.0));
    }

    #[test]
    fn grad_sync_bytes_sum_over_jobs() {
        let a = Ssm::fuse(&[job(0, 8, 4, "tiny")]).unwrap();
        let b = Ssm::fuse(&[job(0, 8, 4, "tiny"), job(1, 8, 4, "tiny")])
            .unwrap();
        assert!((b.grad_sync_bytes() - 2.0 * a.grad_sync_bytes()).abs()
            < 1e-6);
    }

    #[test]
    fn validate_catches_tampering() {
        let mut ssm = Ssm::fuse(&[job(0, 4, 2, "tiny")]).unwrap();
        ssm.edges.pop(); // break the head link
        assert!(ssm.validate().is_err());
    }

    #[test]
    fn node_kinds_counted() {
        let ssm =
            Ssm::fuse(&[job(0, 4, 2, "tiny"), job(1, 8, 2, "tiny")]).unwrap();
        let layers = ssm
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Layer(_)))
            .count();
        let adapters = ssm
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Adapter { .. }))
            .count();
        assert_eq!(layers, ssm.arch.n_layers);
        assert_eq!(adapters, ssm.arch.n_layers * 2);
    }

    #[test]
    fn boundary_bytes_scale_with_tokens() {
        let a = Ssm::fuse(&[job(0, 8, 2, "tiny")]).unwrap();
        let b = Ssm::fuse(&[job(0, 8, 4, "tiny")]).unwrap();
        assert!((b.boundary_bytes() - 2.0 * a.boundary_bytes()).abs() < 1e-9);
    }
}
