//! Zero-dependency CLI argument parser (clap substitute).
//!
//! Supports `tlora <subcommand> [--flag value] [--switch]` with typed
//! accessors and helpful errors. Used by `main.rs` and the examples.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (tests) — tokens exclude argv[0].
    pub fn parse_from(tokens: &[&str]) -> Result<Args, String> {
        let mut flags = HashMap::new();
        let mut switches = vec![];
        let mut positional = vec![];
        let mut subcommand = None;
        let mut i = 0;
        while i < tokens.len() {
            let tok = tokens[i];
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len()
                    && !tokens[i + 1].starts_with("--")
                {
                    flags.insert(
                        name.to_string(),
                        tokens[i + 1].to_string(),
                    );
                    i += 1;
                } else {
                    switches.push(name.to_string());
                }
            } else if subcommand.is_none() && positional.is_empty() {
                subcommand = Some(tok.to_string());
            } else {
                positional.push(tok.to_string());
            }
            i += 1;
        }
        Ok(Args {
            subcommand,
            flags,
            switches,
            positional,
        })
    }

    /// Parse from the process environment.
    pub fn parse() -> Result<Args, String> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let refs: Vec<&str> = argv.iter().map(String::as_str).collect();
        Args::parse_from(&refs)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize)
        -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got {v}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected number, got {v}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got {v}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_flags_switches() {
        // note: a bare `--switch` must come last or be followed by
        // another `--flag` (positional-after-switch is read as its
        // value, as documented)
        let a = Args::parse_from(&[
            "simulate",
            "extra",
            "--n-jobs",
            "50",
            "--policy=mlora",
            "--full",
        ])
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get("n-jobs"), Some("50"));
        assert_eq!(a.get("policy"), Some("mlora"));
        assert!(a.has("full"));
        assert_eq!(a.positional(), &["extra".to_string()]);
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse_from(&["x", "--n", "5", "--f", "2.5"]).unwrap();
        assert_eq!(a.get_usize("n", 1).unwrap(), 5);
        assert_eq!(a.get_f64("f", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(Args::parse_from(&["x", "--n", "abc"])
            .unwrap()
            .get_usize("n", 1)
            .is_err());
    }

    #[test]
    fn trailing_switch() {
        let a = Args::parse_from(&["run", "--verbose"]).unwrap();
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), None);
    }

    #[test]
    fn empty() {
        let a = Args::parse_from(&[]).unwrap();
        assert!(a.subcommand.is_none());
    }
}
