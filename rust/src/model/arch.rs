//! Model architecture registry.
//!
//! Also the natural home for the *hardware-generation* axis the
//! planner prices models against: [`HardwareTier`] (re-exported from
//! [`crate::cluster`]) describes a GPU generation as multipliers
//! relative to the reference A100-80G, and
//! [`crate::model::cost::known_tiers`] is the per-generation
//! calibration table the `--hardware-mix` parser resolves names
//! through.

pub use crate::cluster::HardwareTier;

/// A decoder-only transformer architecture (the frozen backbone of an
/// SSM). Dimensions follow the usual GPT/Llama conventions.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelArch {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    /// bytes per parameter (2 = bf16, 4 = f32)
    pub dtype_bytes: usize,
}

impl ModelArch {
    pub fn params_per_layer(&self) -> u64 {
        let d = self.d_model as u64;
        let f = self.d_ff as u64;
        // 4 attention projections + 2 MLP mats + 2 norm scales
        4 * d * d + 2 * d * f + 2 * d
    }

    pub fn params_total(&self) -> u64 {
        self.vocab as u64 * self.d_model as u64
            + self.n_layers as u64 * self.params_per_layer()
            + self.d_model as u64
    }

    pub fn weight_bytes(&self) -> u64 {
        self.params_total() * self.dtype_bytes as u64
    }

    pub fn weight_bytes_per_layer(&self) -> u64 {
        self.params_per_layer() * self.dtype_bytes as u64
    }
}

/// A LoRA adapter attached to the q and v projections of every layer
/// (the standard placement, matching `python/compile/model.py`).
#[derive(Debug, Clone, PartialEq)]
pub struct LoraSpec {
    pub rank: usize,
    pub alpha: f64,
}

impl LoraSpec {
    pub fn new(rank: usize) -> LoraSpec {
        LoraSpec {
            rank,
            alpha: 16.0,
        }
    }

    /// Trainable parameters for one adapter on `arch` (A and B on q and
    /// v of every layer).
    pub fn params(&self, arch: &ModelArch) -> u64 {
        let d = arch.d_model as u64;
        let r = self.rank as u64;
        arch.n_layers as u64 * 2 * (d * r + r * d)
    }

    /// Adapter + Adam state bytes (param + m + v, f32).
    pub fn train_state_bytes(&self, arch: &ModelArch) -> u64 {
        self.params(arch) * 4 * 3
    }
}

/// Architectures used by the paper's evaluation (§4.1) plus the AOT'd
/// small variants (python/compile/aot.py VARIANTS must stay in sync —
/// checked by integration tests against artifacts/manifest.json).
pub fn known_archs() -> Vec<ModelArch> {
    vec![
        ModelArch {
            name: "llama3-8b".into(),
            vocab: 128_256,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            d_ff: 14_336,
            dtype_bytes: 2,
        },
        ModelArch {
            name: "qwen3-8b".into(),
            vocab: 151_936,
            d_model: 4096,
            n_layers: 36,
            n_heads: 32,
            d_ff: 12_288,
            dtype_bytes: 2,
        },
        ModelArch {
            name: "e2e100m".into(),
            vocab: 16_384,
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            d_ff: 3072,
            dtype_bytes: 4,
        },
        ModelArch {
            name: "med".into(),
            vocab: 8192,
            d_model: 512,
            n_layers: 8,
            n_heads: 8,
            d_ff: 2048,
            dtype_bytes: 4,
        },
        ModelArch {
            name: "small".into(),
            vocab: 2048,
            d_model: 256,
            n_layers: 4,
            n_heads: 8,
            d_ff: 1024,
            dtype_bytes: 4,
        },
        ModelArch {
            name: "tiny".into(),
            vocab: 256,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 256,
            dtype_bytes: 4,
        },
    ]
}

/// Look up an architecture by name.
pub fn arch_by_name(name: &str) -> Option<ModelArch> {
    known_archs().into_iter().find(|a| a.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama3_8b_param_count_plausible() {
        let a = arch_by_name("llama3-8b").unwrap();
        // MHA approximation of the GQA model: slightly under 8B is fine
        let p = a.params_total() as f64 / 1e9;
        assert!((6.0..9.0).contains(&p), "{p}B");
    }

    #[test]
    fn e2e100m_is_about_100m() {
        let a = arch_by_name("e2e100m").unwrap();
        let p = a.params_total() as f64 / 1e6;
        assert!((90.0..115.0).contains(&p), "{p}M");
    }

    #[test]
    fn lora_params_small_fraction() {
        let a = arch_by_name("llama3-8b").unwrap();
        let l = LoraSpec::new(16);
        let frac = l.params(a_ref(&a)) as f64 / a.params_total() as f64;
        assert!(frac < 0.01, "{frac}");
    }

    fn a_ref(a: &ModelArch) -> &ModelArch {
        a
    }

    #[test]
    fn lora_params_scale_with_rank() {
        let a = arch_by_name("tiny").unwrap();
        assert_eq!(
            LoraSpec::new(8).params(&a),
            2 * LoraSpec::new(4).params(&a)
        );
    }

    #[test]
    fn unknown_arch_is_none() {
        assert!(arch_by_name("gpt5").is_none());
    }
}
