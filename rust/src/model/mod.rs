//! Transformer + LoRA architecture descriptions and analytic cost model.
//!
//! The paper's planner and scheduler reason about per-layer compute,
//! communication, and memory (§3.2: "standard layer-wise profiling and
//! cost modeling"). This module provides those costs analytically,
//! calibrated against real PJRT step measurements by
//! [`crate::train::microbench`] (the Fig. 10 accuracy check).
//!
//! Conventions: FLOPs are multiply-accumulate*2; bytes are parameter
//! bytes at `dtype_bytes`; "tokens" means `batch_size * seq_len`.

pub mod arch;
pub mod cost;

pub use arch::{ModelArch, LoraSpec, known_archs};
pub use cost::{LayerCost, ModelCost, MemoryModel, cost_of};
