//! Analytic FLOPs / bytes / memory cost model.
//!
//! Standard transformer accounting (Megatron-style): per layer and per
//! token, forward matmul FLOPs are `2 * params_per_layer` plus the
//! attention score/value terms that scale with sequence length; backward
//! is 2x forward (dgrad + wgrad). For *frozen* backbone layers the wgrad
//! is skipped, so backbone backward is ~1x forward (dgrad only) — the key
//! asymmetry that makes LoRA training cheap and co-location attractive.

use super::arch::{LoraSpec, ModelArch};
use crate::cluster::HardwareTier;

/// Per-generation calibration table: hardware tiers as multipliers
/// relative to the reference A100-80G ([`GpuSpec::a100_80g`]
/// (crate::cluster::GpuSpec::a100_80g)). Compute multipliers follow
/// peak dense bf16 FLOP/s ratios, bandwidth multipliers the NVLink
/// generation, memory multipliers the HBM capacity. `--hardware-mix`
/// strings resolve generation names through this table.
pub fn known_tiers() -> Vec<HardwareTier> {
    vec![
        // the reference itself: A100-80G, all multipliers 1.0
        HardwareTier::reference(),
        // H100-80G: ~989 vs 312 TFLOP/s bf16, NVLink4 900 vs 600 GB/s
        HardwareTier {
            name: "h100".into(),
            compute_mult: 3.17,
            bw_mult: 1.5,
            mem_mult: 1.0,
        },
        // A100-40G: same silicon, half the HBM
        HardwareTier {
            name: "a100-40g".into(),
            compute_mult: 1.0,
            bw_mult: 1.0,
            mem_mult: 0.5,
        },
        // V100-32G: ~125 TFLOP/s fp16, NVLink2 300 GB/s, 32 GB
        HardwareTier {
            name: "v100".into(),
            compute_mult: 0.4,
            bw_mult: 0.5,
            mem_mult: 0.4,
        },
        // A10G-24G: ~125 TFLOP/s bf16, PCIe-class links, 24 GB
        HardwareTier {
            name: "a10g".into(),
            compute_mult: 0.4,
            bw_mult: 0.11,
            mem_mult: 0.3,
        },
    ]
}

/// Look up a calibration tier by generation name (case-insensitive).
pub fn tier_by_name(name: &str) -> Option<HardwareTier> {
    known_tiers()
        .into_iter()
        .find(|t| t.name.eq_ignore_ascii_case(name))
}

/// Cost of one transformer layer for a given token count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerCost {
    /// forward FLOPs
    pub fwd_flops: f64,
    /// backward FLOPs (frozen backbone: dgrad only)
    pub bwd_flops: f64,
    /// activation bytes that cross a pipeline-stage boundary per
    /// microbatch (d_model * tokens * dtype)
    pub boundary_bytes: f64,
    /// activation memory resident per microbatch
    pub act_bytes: f64,
}

impl LayerCost {
    pub fn total_flops(&self) -> f64 {
        self.fwd_flops + self.bwd_flops
    }
}

/// Per-layer backbone cost for `tokens` tokens of sequence length `seq`.
pub fn layer_cost(arch: &ModelArch, tokens: f64, seq: f64) -> LayerCost {
    let d = arch.d_model as f64;
    let f = arch.d_ff as f64;
    // projections + MLP: 2 FLOPs per MAC
    let matmul = 2.0 * tokens * (4.0 * d * d + 2.0 * d * f);
    // attention scores + weighted values: 2 * 2 * tokens * seq * d
    let attn = 4.0 * tokens * seq * d;
    let fwd = matmul + attn;
    LayerCost {
        fwd_flops: fwd,
        // frozen backbone: activation-gradient path only (~1x fwd)
        bwd_flops: fwd,
        boundary_bytes: tokens * d * arch.dtype_bytes as f64,
        // rough: ~8 activation tensors of (tokens, d) + attention probs
        act_bytes: tokens * d * 8.0 * arch.dtype_bytes as f64
            + tokens * seq * arch.n_heads as f64 * arch.dtype_bytes as f64
                / arch.n_heads as f64,
    }
}

/// Extra cost of one LoRA adapter branch on one layer (q and v targets),
/// for `tokens` tokens owned by that adapter. Trainable => full fwd +
/// dgrad + wgrad (3x fwd).
pub fn lora_layer_cost(arch: &ModelArch, rank: usize, tokens: f64)
    -> LayerCost {
    let d = arch.d_model as f64;
    let r = rank as f64;
    // per target: X@A (2*t*d*r) + (XA)@B (2*t*r*d); two targets (q, v)
    let fwd = 2.0 * (2.0 * tokens * d * r + 2.0 * tokens * r * d);
    LayerCost {
        fwd_flops: fwd,
        bwd_flops: 2.0 * fwd, // dgrad + wgrad
        boundary_bytes: 0.0,
        act_bytes: tokens * r * 2.0 * 4.0, // (t, r) intermediates, f32
    }
}

/// Whole-model cost for one training step of one job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelCost {
    pub fwd_flops: f64,
    pub bwd_flops: f64,
    pub total_flops: f64,
    /// all-reduce bytes for the adapter gradients (what DP syncs)
    pub grad_sync_bytes: f64,
}

/// Cost of one job's step: `batch * seq` tokens through the backbone +
/// its adapter branches.
pub fn cost_of(arch: &ModelArch, lora: &LoraSpec, batch: usize, seq: usize)
    -> ModelCost {
    let tokens = (batch * seq) as f64;
    let lc = layer_cost(arch, tokens, seq as f64);
    let ac = lora_layer_cost(arch, lora.rank, tokens);
    let n = arch.n_layers as f64;
    // embedding + lm head: 2 * tokens * vocab * d each way
    let head = 2.0 * tokens * arch.vocab as f64 * arch.d_model as f64;
    let fwd = n * (lc.fwd_flops + ac.fwd_flops) + head;
    let bwd = n * (lc.bwd_flops + ac.bwd_flops) + head;
    ModelCost {
        fwd_flops: fwd,
        bwd_flops: bwd,
        total_flops: fwd + bwd,
        grad_sync_bytes: lora.params(arch) as f64 * 4.0,
    }
}

/// Adapter-only checkpoint size in bytes: LoRA params + Adam moments,
/// f32 — exactly what `runtime::Checkpoint` serializes (the frozen
/// backbone is reproducible from the init seed and is never stored,
/// which is why an 8B-backbone job checkpoints in tens of MB).
pub fn checkpoint_bytes(arch: &ModelArch, lora: &LoraSpec) -> f64 {
    lora.train_state_bytes(arch) as f64
}

/// Restore time charged when a job restarts after eviction: fixed
/// overhead (reschedule + backbone re-init from the recorded seed)
/// plus reading the adapter-only checkpoint at `read_bw` bytes/s. The
/// simulator's failure rounds charge this per evicted job.
pub fn restore_time_s(
    arch: &ModelArch,
    lora: &LoraSpec,
    overhead_s: f64,
    read_bw: f64,
) -> f64 {
    overhead_s + checkpoint_bytes(arch, lora) / read_bw
}

/// Memory model for placement feasibility (used by the planner and by
/// mLoRA's memory-capacity grouping rule).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryModel {
    pub weight_bytes: f64,
    pub adapter_state_bytes: f64,
    pub activation_bytes: f64,
}

impl MemoryModel {
    pub fn total(&self) -> f64 {
        self.weight_bytes + self.adapter_state_bytes + self.activation_bytes
    }
}

/// Memory for a set of co-located jobs sharing one backbone replica,
/// with per-stage weights divided across `pp * tp` model-parallel ways.
pub fn memory_of(
    arch: &ModelArch,
    jobs: &[(LoraSpec, usize, usize)], // (lora, batch, seq)
    model_parallel_ways: usize,
) -> MemoryModel {
    let weight = arch.weight_bytes() as f64
        / model_parallel_ways.max(1) as f64;
    let mut adapter = 0.0;
    let mut act = 0.0;
    for (lora, batch, seq) in jobs {
        adapter += lora.train_state_bytes(arch) as f64
            / model_parallel_ways.max(1) as f64;
        let tokens = (batch * seq) as f64;
        let lc = layer_cost(arch, tokens, *seq as f64);
        // activations for layers resident on one device
        act += lc.act_bytes
            * (arch.n_layers as f64 / model_parallel_ways.max(1) as f64);
    }
    MemoryModel {
        weight_bytes: weight,
        adapter_state_bytes: adapter,
        activation_bytes: act,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::arch::arch_by_name;

    #[test]
    fn flops_scale_linearly_with_batch() {
        let a = arch_by_name("llama3-8b").unwrap();
        let l = LoraSpec::new(8);
        let c1 = cost_of(&a, &l, 1, 512);
        let c4 = cost_of(&a, &l, 4, 512);
        let ratio = c4.total_flops / c1.total_flops;
        assert!((ratio - 4.0).abs() < 0.05, "{ratio}");
    }

    #[test]
    fn fwd_flops_match_6nd_rule() {
        // fwd ≈ 2 * params * tokens for big models (ignoring attention)
        let a = arch_by_name("llama3-8b").unwrap();
        let l = LoraSpec::new(8);
        let c = cost_of(&a, &l, 1, 512);
        let approx = 2.0 * a.params_total() as f64 * 512.0;
        let ratio = c.fwd_flops / approx;
        assert!((0.8..1.3).contains(&ratio), "{ratio}");
    }

    #[test]
    fn lora_cost_small_vs_backbone() {
        let a = arch_by_name("llama3-8b").unwrap();
        let lc = layer_cost(&a, 512.0, 512.0);
        let ac = lora_layer_cost(&a, 16, 512.0);
        assert!(ac.total_flops() < 0.02 * lc.total_flops());
    }

    #[test]
    fn backbone_bwd_cheaper_than_trainable() {
        // frozen backbone: bwd == fwd; trainable adapter: bwd == 2x fwd
        let a = arch_by_name("tiny").unwrap();
        let lc = layer_cost(&a, 64.0, 32.0);
        assert_eq!(lc.bwd_flops, lc.fwd_flops);
        let ac = lora_layer_cost(&a, 4, 64.0);
        assert_eq!(ac.bwd_flops, 2.0 * ac.fwd_flops);
    }

    #[test]
    fn memory_shrinks_with_model_parallel() {
        let a = arch_by_name("llama3-8b").unwrap();
        let jobs = vec![(LoraSpec::new(8), 4usize, 512usize)];
        let m1 = memory_of(&a, &jobs, 1);
        let m4 = memory_of(&a, &jobs, 4);
        assert!(m4.weight_bytes < m1.weight_bytes / 3.9);
        assert!(m4.total() < m1.total());
    }

    #[test]
    fn memory_grows_with_jobs() {
        let a = arch_by_name("llama3-8b").unwrap();
        let one = memory_of(&a, &[(LoraSpec::new(8), 4, 512)], 1);
        let two = memory_of(
            &a,
            &[(LoraSpec::new(8), 4, 512), (LoraSpec::new(16), 8, 512)],
            1,
        );
        // backbone shared: grows by adapter+activation only
        assert!(two.total() > one.total());
        assert_eq!(two.weight_bytes, one.weight_bytes);
    }

    #[test]
    fn restore_time_scales_with_rank_and_floors_at_overhead() {
        let a = arch_by_name("llama3-8b").unwrap();
        let t8 = restore_time_s(&a, &LoraSpec::new(8), 10.0, 1e9);
        let t16 = restore_time_s(&a, &LoraSpec::new(16), 10.0, 1e9);
        assert!(t16 > t8, "{t16} vs {t8}");
        assert!(t8 > 10.0);
        // adapter-only: the checkpoint is a small fraction of the
        // backbone weights
        assert!(
            checkpoint_bytes(&a, &LoraSpec::new(16))
                < 0.05 * a.weight_bytes() as f64
        );
        // exact size model: params * 4 bytes * (param + m + v)
        assert_eq!(
            checkpoint_bytes(&a, &LoraSpec::new(8)),
            LoraSpec::new(8).params(&a) as f64 * 12.0
        );
    }

    #[test]
    fn calibration_table_is_reference_anchored_and_valid() {
        let tiers = known_tiers();
        assert!(tiers[0].is_reference(), "tier 0 must be the reference");
        for t in &tiers {
            t.validate().unwrap();
        }
        // every generation resolves by name, case-insensitively
        assert_eq!(tier_by_name("a100").unwrap(), tiers[0]);
        assert_eq!(tier_by_name("H100").unwrap().name, "h100");
        assert!(tier_by_name("h100").unwrap().compute_mult > 1.0);
        assert!(tier_by_name("v100").unwrap().compute_mult < 1.0);
        assert!(tier_by_name("a100-40g").unwrap().mem_mult < 1.0);
        assert!(tier_by_name("tpu").is_none());
    }

    #[test]
    fn grad_sync_bytes_match_lora_params() {
        let a = arch_by_name("tiny").unwrap();
        let l = LoraSpec::new(4);
        let c = cost_of(&a, &l, 2, 32);
        assert_eq!(c.grad_sync_bytes, l.params(&a) as f64 * 4.0);
    }
}
