//! Cluster topology model and gang allocator.
//!
//! Substitutes the paper's physical 12×A100 testbed / 128-GPU emulated
//! cluster (§4.1): nodes of GPUs joined by NVLink intra-node and
//! InfiniBand inter-node. The simulator and planner query bandwidth
//! tiers and the allocator hands out gang allocations.

use crate::util::rng::Rng;

/// A GPU device model. Defaults model an NVIDIA A100-80GB.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// peak dense bf16 FLOP/s
    pub peak_flops: f64,
    /// HBM capacity in bytes
    pub mem_bytes: f64,
    /// HBM bandwidth bytes/s
    pub hbm_bw: f64,
    /// achievable fraction of peak on well-shaped GEMMs
    pub mfu_cap: f64,
    /// fixed kernel launch overhead (seconds)
    pub launch_overhead_s: f64,
}

impl GpuSpec {
    pub fn a100_80g() -> GpuSpec {
        GpuSpec {
            name: "A100-80G".into(),
            peak_flops: 312e12,
            mem_bytes: 80e9,
            hbm_bw: 2.0e12,
            mfu_cap: 0.55,
            launch_overhead_s: 8e-6,
        }
    }
}

/// One hardware generation ("tier"): static performance multipliers
/// relative to the reference GPU ([`GpuSpec::a100_80g`]).
///
/// Tiers model *fleet heterogeneity* — a permanent property of a node's
/// hardware — and are deliberately distinct from the straggler
/// subsystem's dynamic per-node `speed` multipliers (a transient fault
/// property). The planner prices tiers into every plan's step time, so
/// the detection estimator's observed/planned ratio stays ~1.0 on a
/// slow generation: **a slow generation is not a straggler**.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareTier {
    /// generation label, e.g. "a100", "h100", "v100"
    pub name: String,
    /// effective FLOP/s multiplier vs the reference GPU
    pub compute_mult: f64,
    /// link-bandwidth multiplier (NVLink/IB endpoints on this tier)
    pub bw_mult: f64,
    /// HBM-capacity multiplier
    pub mem_mult: f64,
}

impl HardwareTier {
    /// The reference tier: the A100-80G every multiplier is 1.0 of.
    pub fn reference() -> HardwareTier {
        HardwareTier {
            name: "a100".into(),
            compute_mult: 1.0,
            bw_mult: 1.0,
            mem_mult: 1.0,
        }
    }

    /// Exactly the reference multipliers (all 1.0): nodes on such a
    /// tier take the homogeneous code paths bit-for-bit.
    pub fn is_reference(&self) -> bool {
        self.compute_mult == 1.0
            && self.bw_mult == 1.0
            && self.mem_mult == 1.0
    }

    pub fn validate(&self) -> Result<(), String> {
        for (what, v) in [
            ("compute_mult", self.compute_mult),
            ("bw_mult", self.bw_mult),
            ("mem_mult", self.mem_mult),
        ] {
            if !(v.is_finite() && v > 0.0) {
                return Err(format!(
                    "hardware tier {:?}: {what} must be finite and \
                     > 0, got {v}",
                    self.name
                ));
            }
        }
        Ok(())
    }
}

/// Cluster shape: `n_nodes` nodes × `gpus_per_node` GPUs.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub n_nodes: usize,
    pub gpus_per_node: usize,
    pub gpu: GpuSpec,
    /// NVLink bytes/s between GPUs in a node
    pub nvlink_bw: f64,
    /// InfiniBand bytes/s between nodes (per link)
    pub ib_bw: f64,
    /// inter-node latency seconds
    pub ib_latency_s: f64,
    /// hardware generations present in the fleet (never empty; a
    /// homogeneous cluster carries a single reference tier)
    pub tiers: Vec<HardwareTier>,
    /// per-node tier assignment, applied cyclically
    /// (`node_tier[node % len]`); empty = every node on tier 0
    pub node_tier: Vec<usize>,
    /// the `--hardware-mix` string this spec was built from (empty for
    /// homogeneous clusters; label only, never consulted for pricing)
    pub hardware_mix: String,
}

impl ClusterSpec {
    /// The paper's default 128-GPU cluster: 16 nodes × 8 A100s.
    pub fn default_128() -> ClusterSpec {
        ClusterSpec::with_gpus(128)
    }

    /// A cluster with `n` GPUs in 8-GPU nodes (Fig. 9b sweeps this).
    pub fn with_gpus(n: usize) -> ClusterSpec {
        let gpus_per_node = 8.min(n.max(1));
        ClusterSpec {
            n_nodes: n.div_ceil(gpus_per_node),
            gpus_per_node,
            gpu: GpuSpec::a100_80g(),
            nvlink_bw: 600e9,
            ib_bw: 12.5e9, // 100 Gb/s
            ib_latency_s: 5e-6,
            tiers: vec![HardwareTier::reference()],
            node_tier: vec![],
            hardware_mix: String::new(),
        }
    }

    /// [`ClusterSpec::with_gpus`] with a `--hardware-mix` applied (see
    /// [`parse_hardware_mix`]). An empty mix string is exactly
    /// `with_gpus`.
    pub fn with_gpus_mix(n: usize, mix: &str) -> Result<ClusterSpec, String> {
        let mut spec = ClusterSpec::with_gpus(n);
        spec.apply_hardware_mix(mix)?;
        Ok(spec)
    }

    /// Install the tiers and cyclic node pattern described by `mix`
    /// (empty = reset to the homogeneous reference fleet).
    pub fn apply_hardware_mix(&mut self, mix: &str) -> Result<(), String> {
        if mix.is_empty() {
            self.tiers = vec![HardwareTier::reference()];
            self.node_tier = vec![];
            self.hardware_mix = String::new();
            return Ok(());
        }
        let (tiers, pattern) = parse_hardware_mix(mix)?;
        self.tiers = tiers;
        self.node_tier = pattern;
        self.hardware_mix = mix.to_string();
        Ok(())
    }

    pub fn total_gpus(&self) -> usize {
        self.n_nodes * self.gpus_per_node
    }

    /// Tier index of `node` (cyclic pattern; tier 0 when no pattern).
    pub fn tier_index(&self, node: usize) -> usize {
        if self.node_tier.is_empty() {
            0
        } else {
            self.node_tier[node % self.node_tier.len()]
                .min(self.tiers.len().saturating_sub(1))
        }
    }

    pub fn tier_of(&self, node: usize) -> &HardwareTier {
        &self.tiers[self.tier_index(node)]
    }

    /// Effective-FLOP/s multiplier of `node` vs the reference GPU.
    pub fn compute_mult(&self, node: usize) -> f64 {
        self.tier_of(node).compute_mult
    }

    /// Link-bandwidth multiplier of `node`.
    pub fn bw_mult(&self, node: usize) -> f64 {
        self.tier_of(node).bw_mult
    }

    /// HBM capacity of one GPU on `node` (tier-scaled).
    pub fn mem_bytes_of(&self, node: usize) -> f64 {
        self.gpu.mem_bytes * self.tier_of(node).mem_mult
    }

    /// Does every node sit on a reference (all-1.0) tier? Homogeneous
    /// clusters take the pre-tier code paths bit-for-bit; callers gate
    /// summation-order-sensitive math on this (repeated per-GPU
    /// addition is not bit-equal to `n as f64 *`).
    pub fn is_uniform_reference(&self) -> bool {
        self.tiers.iter().all(HardwareTier::is_reference)
            || (0..self.n_nodes)
                .all(|n| self.tier_of(n).is_reference())
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.tiers.is_empty() {
            return Err("cluster has no hardware tiers".into());
        }
        for t in &self.tiers {
            t.validate()?;
        }
        for &ti in &self.node_tier {
            if ti >= self.tiers.len() {
                return Err(format!(
                    "node_tier index {ti} out of range ({} tiers)",
                    self.tiers.len()
                ));
            }
        }
        Ok(())
    }
}

/// Parse a `--hardware-mix` string into a tier list and a cyclic
/// per-node tier pattern.
///
/// Syntax: colon-separated generations, each optionally weighted —
/// `"a100*3:h100"` means "repeating groups of 3 A100 nodes then 1 H100
/// node". Generation names resolve through the calibration table in
/// [`crate::model::cost::tier_by_name`]. A single unweighted
/// generation (e.g. `"h100"`) is a homogeneous non-reference fleet.
pub fn parse_hardware_mix(
    mix: &str,
) -> Result<(Vec<HardwareTier>, Vec<usize>), String> {
    let mut tiers: Vec<HardwareTier> = vec![];
    let mut pattern: Vec<usize> = vec![];
    for part in mix.split(':') {
        let part = part.trim();
        if part.is_empty() {
            return Err(format!("empty generation in mix {mix:?}"));
        }
        let (name, count) = match part.split_once('*') {
            Some((n, c)) => {
                let count: usize = c.trim().parse().map_err(|_| {
                    format!("bad weight {c:?} in mix {mix:?}")
                })?;
                if count == 0 {
                    return Err(format!(
                        "zero weight for {n:?} in mix {mix:?}"
                    ));
                }
                (n.trim(), count)
            }
            None => (part, 1),
        };
        let tier = crate::model::cost::tier_by_name(name)
            .ok_or_else(|| {
                format!("unknown hardware generation {name:?}")
            })?;
        let idx = match tiers.iter().position(|t| t == &tier) {
            Some(i) => i,
            None => {
                tiers.push(tier);
                tiers.len() - 1
            }
        };
        pattern.extend(std::iter::repeat(idx).take(count));
    }
    if tiers.is_empty() {
        return Err(format!("empty hardware mix {mix:?}"));
    }
    Ok((tiers, pattern))
}

/// Identifies one GPU as (node, local index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuId {
    pub node: usize,
    pub idx: usize,
}

/// Bandwidth tier between two GPUs — the hierarchy the scheduler's
/// bottom-up grouping walks (§3.4 "node, then across nodes, then ranks").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    SameGpu,
    IntraNode,
    InterNode,
}

impl ClusterSpec {
    pub fn tier(&self, a: GpuId, b: GpuId) -> Tier {
        if a == b {
            Tier::SameGpu
        } else if a.node == b.node {
            Tier::IntraNode
        } else {
            Tier::InterNode
        }
    }

    /// Point-to-point bandwidth between two GPUs (bytes/s), scaled by
    /// the slower endpoint's hardware-tier bandwidth multiplier (×1.0
    /// — bit-exact — on homogeneous fleets). `bottleneck_bandwidth`,
    /// `allreduce_time` and `p2p_time` inherit the scaling, so every
    /// comm term the planner prices is tier-aware.
    pub fn bandwidth(&self, a: GpuId, b: GpuId) -> f64 {
        let base = match self.tier(a, b) {
            Tier::SameGpu => self.gpu.hbm_bw,
            Tier::IntraNode => self.nvlink_bw,
            Tier::InterNode => self.ib_bw,
        };
        base * self.bw_mult(a.node).min(self.bw_mult(b.node))
    }

    /// Slowest link bandwidth across a set of GPUs — ring-collective
    /// bottleneck.
    pub fn bottleneck_bandwidth(&self, gpus: &[GpuId]) -> f64 {
        let mut bw = self.gpu.hbm_bw;
        for (i, &a) in gpus.iter().enumerate() {
            for &b in gpus.iter().skip(i + 1) {
                bw = bw.min(self.bandwidth(a, b));
            }
        }
        bw
    }

    /// Time for a ring all-reduce of `bytes` across `gpus`.
    pub fn allreduce_time(&self, gpus: &[GpuId], bytes: f64) -> f64 {
        let n = gpus.len();
        if n <= 1 {
            return 0.0;
        }
        let bw = self.bottleneck_bandwidth(gpus);
        let cross_node = gpus.iter().any(|g| g.node != gpus[0].node);
        let lat = if cross_node { self.ib_latency_s } else { 1e-6 };
        // ring: 2(n-1)/n * bytes over the bottleneck link + per-step lat
        2.0 * (n as f64 - 1.0) / n as f64 * bytes / bw
            + 2.0 * (n as f64 - 1.0) * lat
    }

    /// Time for a point-to-point activation transfer (pipeline edge).
    pub fn p2p_time(&self, a: GpuId, b: GpuId, bytes: f64) -> f64 {
        if a == b {
            return 0.0;
        }
        let lat = if a.node == b.node {
            1e-6
        } else {
            self.ib_latency_s
        };
        bytes / self.bandwidth(a, b) + lat
    }
}

/// Gang allocator with node-packing preference: allocations avoid
/// spanning nodes when a single node can hold them (keeps groups in the
/// cheap bandwidth tier). Tracks node health: down nodes keep their
/// free-list bookkeeping (releases still land there) but are excluded
/// from every allocation path until [`Allocator::set_down`] marks them
/// up again. Also tracks per-node *speed* multipliers (the straggler
/// fault mode): a degraded node stays fully allocatable — degradation
/// is a throughput property, not a capacity one — and the simulator
/// prices every group touching it at the slowest member node's rate
/// ([`Allocator::alloc_speed`]).
#[derive(Debug, Clone)]
pub struct Allocator {
    spec: ClusterSpec,
    /// free[node] = list of free local indices
    free: Vec<Vec<usize>>,
    /// down[node] = node is failed; its GPUs are unallocatable
    down: Vec<bool>,
    /// speed[node] = throughput multiplier (1.0 healthy; a straggler
    /// episode samples a value in (0, 1))
    speed: Vec<f64>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub gpus: Vec<GpuId>,
}

impl Allocation {
    pub fn n_gpus(&self) -> usize {
        self.gpus.len()
    }

    pub fn nodes(&self) -> Vec<usize> {
        let mut n: Vec<usize> = self.gpus.iter().map(|g| g.node).collect();
        n.sort_unstable();
        n.dedup();
        n
    }

    pub fn spans_nodes(&self) -> bool {
        self.nodes().len() > 1
    }

    /// Union of two allocations (group merge).
    pub fn union(&self, other: &Allocation) -> Allocation {
        let mut gpus = self.gpus.clone();
        gpus.extend_from_slice(&other.gpus);
        gpus.sort_unstable();
        gpus.dedup();
        Allocation { gpus }
    }
}

impl Allocator {
    pub fn new(spec: ClusterSpec) -> Allocator {
        let free = (0..spec.n_nodes)
            .map(|_| (0..spec.gpus_per_node).rev().collect())
            .collect();
        let down = vec![false; spec.n_nodes];
        let speed = vec![1.0; spec.n_nodes];
        Allocator {
            spec,
            free,
            down,
            speed,
        }
    }

    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// All free GPUs, including those stranded on down nodes.
    pub fn free_gpus(&self) -> usize {
        self.free.iter().map(|f| f.len()).sum()
    }

    /// Free GPUs on healthy nodes — what [`Allocator::allocate`] can
    /// actually hand out.
    pub fn available_gpus(&self) -> usize {
        self.free
            .iter()
            .enumerate()
            .filter(|(node, _)| !self.down[*node])
            .map(|(_, f)| f.len())
            .sum()
    }

    /// Mark a node failed (`down = true`) or recovered. While down, the
    /// node's GPUs are excluded from allocation; releases onto a down
    /// node still return GPUs to its free list, so recovery restores
    /// full capacity with no extra bookkeeping.
    pub fn set_down(&mut self, node: usize, down: bool) {
        self.down[node] = down;
    }

    pub fn is_down(&self, node: usize) -> bool {
        self.down[node]
    }

    /// Set a node's throughput multiplier (straggler degrade/restore).
    /// Must be > 0: a node at speed 0 is a failure, not a straggler.
    pub fn set_speed(&mut self, node: usize, speed: f64) {
        assert!(speed > 0.0, "node speed must be > 0, got {speed}");
        self.speed[node] = speed;
    }

    pub fn node_speed(&self, node: usize) -> f64 {
        self.speed[node]
    }

    /// Hardware tier of `node` — the *static* fleet-heterogeneity
    /// axis, deliberately distinct from the dynamic straggler `speed`
    /// above: tiers are priced into plans, speeds are observed faults.
    pub fn tier_of(&self, node: usize) -> &HardwareTier {
        self.spec.tier_of(node)
    }

    /// Static compute multiplier of `node`'s generation.
    pub fn compute_mult(&self, node: usize) -> f64 {
        self.spec.compute_mult(node)
    }

    /// Effective speed of a gang allocation: the *slowest* node it
    /// touches — a fused group is gang-synchronous, so one degraded
    /// member node paces every step (1.0 for an empty allocation).
    pub fn alloc_speed(&self, alloc: &Allocation) -> f64 {
        alloc
            .gpus
            .iter()
            .map(|g| self.speed[g.node])
            .fold(1.0, f64::min)
    }

    /// Free GPUs on nodes that are neither down nor flagged in
    /// `avoid` — the capacity [`Allocator::allocate_avoiding`] can
    /// hand out without touching a suspected straggler.
    pub fn available_gpus_avoiding(&self, avoid: &[bool]) -> usize {
        self.free
            .iter()
            .enumerate()
            .filter(|(node, _)| {
                !self.down[*node]
                    && !avoid.get(*node).copied().unwrap_or(false)
            })
            .map(|(_, f)| f.len())
            .sum()
    }

    /// [`Allocator::allocate`], preferring nodes not flagged in
    /// `avoid` (suspected stragglers): first try the allocation with
    /// avoided nodes treated as down; if that cannot be satisfied,
    /// fall back to the ordinary path — a slow GPU still beats no GPU.
    /// With an all-false `avoid` this is *exactly* `allocate` (the
    /// straggler-free differential fixture depends on that).
    pub fn allocate_avoiding(
        &mut self,
        n: usize,
        avoid: &[bool],
    ) -> Option<Allocation> {
        if avoid.iter().any(|&a| a) {
            let saved = self.down.clone();
            for (node, &a) in avoid.iter().enumerate() {
                if a && node < self.down.len() {
                    self.down[node] = true;
                }
            }
            let got = self.allocate(n);
            self.down = saved;
            if got.is_some() {
                return got;
            }
        }
        self.allocate(n)
    }

    pub fn total_gpus(&self) -> usize {
        self.spec.total_gpus()
    }

    /// Allocate `n` GPUs from healthy nodes, preferring (1) the single
    /// node with the tightest fit, then (2) spilling across the
    /// emptiest nodes.
    pub fn allocate(&mut self, n: usize) -> Option<Allocation> {
        if n == 0 || self.available_gpus() < n {
            return None;
        }
        // best-fit single node
        let mut best: Option<(usize, usize)> = None; // (node, slack)
        for (node, f) in self.free.iter().enumerate() {
            if !self.down[node] && f.len() >= n {
                let slack = f.len() - n;
                if best.map_or(true, |(_, s)| slack < s) {
                    best = Some((node, slack));
                }
            }
        }
        let mut gpus = Vec::with_capacity(n);
        if let Some((node, _)) = best {
            for _ in 0..n {
                let idx = self.free[node].pop().unwrap();
                gpus.push(GpuId { node, idx });
            }
            return Some(Allocation { gpus });
        }
        // spill: fill from healthy nodes with the most free capacity
        // first
        let mut order: Vec<usize> = (0..self.free.len())
            .filter(|&i| !self.down[i])
            .collect();
        order.sort_by_key(|&i| std::cmp::Reverse(self.free[i].len()));
        let mut need = n;
        for node in order {
            while need > 0 {
                match self.free[node].pop() {
                    Some(idx) => {
                        gpus.push(GpuId { node, idx });
                        need -= 1;
                    }
                    None => break,
                }
            }
            if need == 0 {
                break;
            }
        }
        debug_assert_eq!(need, 0);
        Some(Allocation { gpus })
    }

    /// Return an allocation's GPUs to the free pool.
    pub fn release(&mut self, alloc: &Allocation) {
        for g in &alloc.gpus {
            debug_assert!(
                !self.free[g.node].contains(&g.idx),
                "double free of {g:?}"
            );
            self.free[g.node].push(g.idx);
        }
    }

    /// Randomized allocation order (trace replay uses this to model
    /// fragmented production clusters). Down nodes are excluded like in
    /// [`Allocator::allocate`].
    pub fn allocate_random(&mut self, n: usize, rng: &mut Rng)
        -> Option<Allocation> {
        if self.available_gpus() < n || n == 0 {
            return None;
        }
        let mut candidates: Vec<GpuId> = vec![];
        for (node, f) in self.free.iter().enumerate() {
            if self.down[node] {
                continue;
            }
            for &idx in f {
                candidates.push(GpuId { node, idx });
            }
        }
        rng.shuffle(&mut candidates);
        let chosen: Vec<GpuId> = candidates.into_iter().take(n).collect();
        for g in &chosen {
            self.free[g.node].retain(|&i| i != g.idx);
        }
        Some(Allocation { gpus: chosen })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec4x4() -> ClusterSpec {
        let mut s = ClusterSpec::with_gpus(16);
        s.n_nodes = 4;
        s.gpus_per_node = 4;
        s
    }

    #[test]
    fn tiers() {
        let s = spec4x4();
        let a = GpuId { node: 0, idx: 0 };
        let b = GpuId { node: 0, idx: 1 };
        let c = GpuId { node: 1, idx: 0 };
        assert_eq!(s.tier(a, a), Tier::SameGpu);
        assert_eq!(s.tier(a, b), Tier::IntraNode);
        assert_eq!(s.tier(a, c), Tier::InterNode);
        assert!(s.bandwidth(a, b) > s.bandwidth(a, c));
    }

    #[test]
    fn allreduce_zero_for_single() {
        let s = spec4x4();
        assert_eq!(s.allreduce_time(&[GpuId { node: 0, idx: 0 }], 1e9), 0.0);
    }

    #[test]
    fn allreduce_slower_across_nodes() {
        let s = spec4x4();
        let intra = vec![GpuId { node: 0, idx: 0 }, GpuId { node: 0, idx: 1 }];
        let inter = vec![GpuId { node: 0, idx: 0 }, GpuId { node: 1, idx: 0 }];
        assert!(s.allreduce_time(&inter, 1e8) > s.allreduce_time(&intra, 1e8));
    }

    #[test]
    fn allocator_prefers_single_node() {
        let mut a = Allocator::new(spec4x4());
        let alloc = a.allocate(4).unwrap();
        assert!(!alloc.spans_nodes());
        assert_eq!(a.free_gpus(), 12);
    }

    #[test]
    fn allocator_best_fit() {
        let mut a = Allocator::new(spec4x4());
        let two = a.allocate(2).unwrap(); // node X now has 2 free
        let four = a.allocate(4).unwrap(); // must use a different full node
        assert!(!four.spans_nodes());
        assert_ne!(four.gpus[0].node, two.gpus[0].node);
        // 2-gpu ask should best-fit into the half-empty node
        let two2 = a.allocate(2).unwrap();
        assert_eq!(two2.gpus[0].node, two.gpus[0].node);
    }

    #[test]
    fn allocator_spills_when_needed() {
        let mut a = Allocator::new(spec4x4());
        let alloc = a.allocate(6).unwrap();
        assert!(alloc.spans_nodes());
        assert_eq!(alloc.n_gpus(), 6);
        assert_eq!(a.free_gpus(), 10);
    }

    #[test]
    fn allocator_exhaustion() {
        let mut a = Allocator::new(spec4x4());
        assert!(a.allocate(17).is_none());
        let x = a.allocate(16).unwrap();
        assert!(a.allocate(1).is_none());
        a.release(&x);
        assert_eq!(a.free_gpus(), 16);
    }

    #[test]
    fn release_restores_exact_capacity() {
        let mut a = Allocator::new(spec4x4());
        let x = a.allocate(3).unwrap();
        let y = a.allocate(5).unwrap();
        a.release(&x);
        a.release(&y);
        assert_eq!(a.free_gpus(), 16);
        assert!(a.allocate(16).is_some());
    }

    #[test]
    fn union_dedups() {
        let a = Allocation {
            gpus: vec![GpuId { node: 0, idx: 0 }, GpuId { node: 0, idx: 1 }],
        };
        let b = Allocation {
            gpus: vec![GpuId { node: 0, idx: 1 }, GpuId { node: 1, idx: 0 }],
        };
        assert_eq!(a.union(&b).n_gpus(), 3);
    }

    #[test]
    fn default_cluster_shape() {
        let s = ClusterSpec::default_128();
        assert_eq!(s.total_gpus(), 128);
        assert_eq!(s.gpus_per_node, 8);
    }

    #[test]
    fn down_node_excluded_from_allocation() {
        let mut a = Allocator::new(spec4x4());
        a.set_down(0, true);
        assert!(a.is_down(0));
        assert_eq!(a.free_gpus(), 16);
        assert_eq!(a.available_gpus(), 12);
        // single-node fits must land on healthy nodes only
        for _ in 0..3 {
            let alloc = a.allocate(4).unwrap();
            assert!(!alloc.spans_nodes());
            assert_ne!(alloc.gpus[0].node, 0);
        }
        // everything healthy is taken; the down node's GPUs stay out
        assert!(a.allocate(1).is_none());
        assert_eq!(a.free_gpus(), 4);
        assert_eq!(a.available_gpus(), 0);
    }

    #[test]
    fn spill_never_touches_down_nodes() {
        let mut a = Allocator::new(spec4x4());
        a.set_down(1, true);
        // 6 > any single node: spills across the 3 healthy nodes
        let alloc = a.allocate(6).unwrap();
        assert!(alloc.spans_nodes());
        assert!(alloc.gpus.iter().all(|g| g.node != 1));
    }

    #[test]
    fn release_onto_down_node_then_recover_restores_capacity() {
        let mut a = Allocator::new(spec4x4());
        let x = a.allocate(4).unwrap();
        let node = x.gpus[0].node;
        a.set_down(node, true);
        // eviction path: the holder's GPUs come back while the node is
        // still down — stranded but accounted
        a.release(&x);
        assert_eq!(a.free_gpus(), 16);
        assert_eq!(a.available_gpus(), 12);
        a.set_down(node, false);
        assert_eq!(a.available_gpus(), 16);
        assert!(a.allocate(16).is_some());
    }

    #[test]
    fn node_speeds_default_healthy_and_bottleneck_allocations() {
        let mut a = Allocator::new(spec4x4());
        for node in 0..4 {
            assert_eq!(a.node_speed(node), 1.0);
        }
        a.set_speed(1, 0.25);
        assert_eq!(a.node_speed(1), 0.25);
        let single = Allocation {
            gpus: vec![GpuId { node: 0, idx: 0 }],
        };
        assert_eq!(a.alloc_speed(&single), 1.0);
        let spanning = Allocation {
            gpus: vec![
                GpuId { node: 0, idx: 0 },
                GpuId { node: 1, idx: 0 },
                GpuId { node: 2, idx: 0 },
            ],
        };
        // gang-synchronous: the slowest node paces the whole gang
        assert_eq!(a.alloc_speed(&spanning), 0.25);
        a.set_speed(1, 1.0);
        assert_eq!(a.alloc_speed(&spanning), 1.0);
        // a degraded node stays fully allocatable
        a.set_speed(1, 0.1);
        assert_eq!(a.available_gpus(), 16);
        assert!(a.allocate(16).is_some());
    }

    #[test]
    fn allocate_avoiding_prefers_healthy_then_falls_back() {
        let mut a = Allocator::new(spec4x4());
        let avoid = [true, false, false, false];
        assert_eq!(a.available_gpus_avoiding(&avoid), 12);
        // fits on unflagged nodes: never touches node 0
        for _ in 0..3 {
            let alloc = a.allocate_avoiding(4, &avoid).unwrap();
            assert!(alloc.gpus.iter().all(|g| g.node != 0));
        }
        // only node 0 is left: fall back rather than starve
        assert_eq!(a.available_gpus_avoiding(&avoid), 0);
        let alloc = a.allocate_avoiding(2, &avoid).unwrap();
        assert!(alloc.gpus.iter().all(|g| g.node == 0));
        // but a *down* node is never a fallback
        let mut b = Allocator::new(spec4x4());
        b.set_down(0, true);
        assert!(b
            .allocate_avoiding(16, &[true, false, false, false])
            .is_none());
    }

    #[test]
    fn allocate_avoiding_all_false_matches_allocate_exactly() {
        let mut a = Allocator::new(spec4x4());
        let mut b = Allocator::new(spec4x4());
        let avoid = [false; 4];
        for n in [2usize, 4, 6, 1, 3] {
            let x = a.allocate(n);
            let y = b.allocate_avoiding(n, &avoid);
            assert_eq!(x, y, "n={n}");
        }
    }

    #[test]
    fn default_spec_is_uniform_reference() {
        let s = ClusterSpec::default_128();
        assert_eq!(s.tiers.len(), 1);
        assert!(s.tiers[0].is_reference());
        assert!(s.node_tier.is_empty());
        assert!(s.is_uniform_reference());
        assert_eq!(s.tier_index(0), 0);
        assert_eq!(s.compute_mult(5), 1.0);
        assert_eq!(s.mem_bytes_of(5), s.gpu.mem_bytes);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn hardware_mix_parses_weighted_round_robin() {
        let (tiers, pattern) =
            parse_hardware_mix("a100*3:h100").unwrap();
        assert_eq!(tiers.len(), 2);
        assert_eq!(tiers[0].name, "a100");
        assert!(tiers[0].is_reference());
        assert_eq!(tiers[1].name, "h100");
        assert!(tiers[1].compute_mult > 1.0);
        assert_eq!(pattern, vec![0, 0, 0, 1]);
        // pattern applies cyclically over nodes
        let s = ClusterSpec::with_gpus_mix(128, "a100*3:h100").unwrap();
        assert!(!s.is_uniform_reference());
        assert_eq!(s.tier_of(0).name, "a100");
        assert_eq!(s.tier_of(3).name, "h100");
        assert_eq!(s.tier_of(7).name, "h100");
        assert_eq!(s.tier_of(4).name, "a100");
        assert!(s.validate().is_ok());
        assert_eq!(s.hardware_mix, "a100*3:h100");
    }

    #[test]
    fn hardware_mix_rejects_garbage() {
        assert!(parse_hardware_mix("notagpu").is_err());
        assert!(parse_hardware_mix("a100*0").is_err());
        assert!(parse_hardware_mix("a100*x").is_err());
        assert!(parse_hardware_mix("a100::h100").is_err());
        let mut s = ClusterSpec::with_gpus(16);
        s.tiers.clear();
        assert!(s.validate().is_err());
        let mut s = ClusterSpec::with_gpus(16);
        s.node_tier = vec![3];
        assert!(s.validate().is_err());
        let mut s = ClusterSpec::with_gpus(16);
        s.tiers[0].compute_mult = 0.0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn empty_mix_resets_to_reference() {
        let mut s = ClusterSpec::with_gpus_mix(32, "v100").unwrap();
        assert!(!s.is_uniform_reference());
        s.apply_hardware_mix("").unwrap();
        assert_eq!(s, ClusterSpec::with_gpus(32));
    }

    #[test]
    fn bandwidth_scales_with_slower_endpoint_tier() {
        let mut s = spec4x4();
        // node 1 on a half-bandwidth tier
        s.tiers.push(HardwareTier {
            name: "slowlink".into(),
            compute_mult: 1.0,
            bw_mult: 0.5,
            mem_mult: 1.0,
        });
        s.node_tier = vec![0, 1, 0, 0];
        let a = GpuId { node: 0, idx: 0 };
        let b = GpuId { node: 2, idx: 0 };
        let c = GpuId { node: 1, idx: 0 };
        // reference-pair links keep the base rate bit-for-bit
        assert_eq!(s.bandwidth(a, b), s.ib_bw);
        // any link touching the slow tier runs at its multiplier
        assert_eq!(s.bandwidth(a, c), s.ib_bw * 0.5);
        let d = GpuId { node: 1, idx: 1 };
        assert_eq!(s.bandwidth(c, d), s.nvlink_bw * 0.5);
        // collectives inherit the scaled bottleneck
        assert!(
            s.allreduce_time(&[a, c], 1e8)
                > s.allreduce_time(&[a, b], 1e8)
        );
        assert!(s.p2p_time(a, c, 1e8) > s.p2p_time(a, b, 1e8));
    }

    #[test]
    fn allocate_random_skips_down_nodes() {
        let mut a = Allocator::new(spec4x4());
        a.set_down(2, true);
        let mut rng = crate::util::rng::Rng::new(9);
        let alloc = a.allocate_random(10, &mut rng).unwrap();
        assert_eq!(alloc.n_gpus(), 10);
        assert!(alloc.gpus.iter().all(|g| g.node != 2));
        assert!(a.allocate_random(3, &mut rng).is_none());
    }
}
