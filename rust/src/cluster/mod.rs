//! Cluster topology model and gang allocator.
//!
//! Substitutes the paper's physical 12×A100 testbed / 128-GPU emulated
//! cluster (§4.1): nodes of GPUs joined by NVLink intra-node and
//! InfiniBand inter-node. The simulator and planner query bandwidth
//! tiers and the allocator hands out gang allocations.

use crate::util::rng::Rng;

/// A GPU device model. Defaults model an NVIDIA A100-80GB.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// peak dense bf16 FLOP/s
    pub peak_flops: f64,
    /// HBM capacity in bytes
    pub mem_bytes: f64,
    /// HBM bandwidth bytes/s
    pub hbm_bw: f64,
    /// achievable fraction of peak on well-shaped GEMMs
    pub mfu_cap: f64,
    /// fixed kernel launch overhead (seconds)
    pub launch_overhead_s: f64,
}

impl GpuSpec {
    pub fn a100_80g() -> GpuSpec {
        GpuSpec {
            name: "A100-80G".into(),
            peak_flops: 312e12,
            mem_bytes: 80e9,
            hbm_bw: 2.0e12,
            mfu_cap: 0.55,
            launch_overhead_s: 8e-6,
        }
    }
}

/// One hardware generation ("tier"): static performance multipliers
/// relative to the reference GPU ([`GpuSpec::a100_80g`]).
///
/// Tiers model *fleet heterogeneity* — a permanent property of a node's
/// hardware — and are deliberately distinct from the straggler
/// subsystem's dynamic per-node `speed` multipliers (a transient fault
/// property). The planner prices tiers into every plan's step time, so
/// the detection estimator's observed/planned ratio stays ~1.0 on a
/// slow generation: **a slow generation is not a straggler**.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareTier {
    /// generation label, e.g. "a100", "h100", "v100"
    pub name: String,
    /// effective FLOP/s multiplier vs the reference GPU
    pub compute_mult: f64,
    /// link-bandwidth multiplier (NVLink/IB endpoints on this tier)
    pub bw_mult: f64,
    /// HBM-capacity multiplier
    pub mem_mult: f64,
}

impl HardwareTier {
    /// The reference tier: the A100-80G every multiplier is 1.0 of.
    pub fn reference() -> HardwareTier {
        HardwareTier {
            name: "a100".into(),
            compute_mult: 1.0,
            bw_mult: 1.0,
            mem_mult: 1.0,
        }
    }

    /// Exactly the reference multipliers (all 1.0): nodes on such a
    /// tier take the homogeneous code paths bit-for-bit.
    pub fn is_reference(&self) -> bool {
        self.compute_mult == 1.0
            && self.bw_mult == 1.0
            && self.mem_mult == 1.0
    }

    pub fn validate(&self) -> Result<(), String> {
        for (what, v) in [
            ("compute_mult", self.compute_mult),
            ("bw_mult", self.bw_mult),
            ("mem_mult", self.mem_mult),
        ] {
            if !(v.is_finite() && v > 0.0) {
                return Err(format!(
                    "hardware tier {:?}: {what} must be finite and \
                     > 0, got {v}",
                    self.name
                ));
            }
        }
        Ok(())
    }
}

/// Physical topology tree above the node level: GPU < node < rack
/// (shared switch / power domain) < region. Nodes pack into racks as
/// contiguous blocks, racks into regions the same way. Cross-rack and
/// cross-region links run at a multiplier of the IB base rate with
/// their own latencies, and every rack with more than one configured
/// rack becomes a named correlated-failure domain
/// ([`ClusterSpec::failure_domains`]).
///
/// Byte-freedom contract: a flat topology ([`TopologySpec::is_flat`],
/// the default) is never consulted — `bandwidth`/`allreduce_time`/
/// `p2p_time` early-return the pre-topology math, the allocator keeps
/// count-based scoring, and no report column or plan-cache key
/// component is emitted — so untopologized runs stay bit-identical to
/// pre-topology builds.
#[derive(Debug, Clone, PartialEq)]
pub struct TopologySpec {
    /// number of racks (contiguous node blocks); 1 = flat
    pub racks: usize,
    /// number of regions (contiguous rack blocks); 1 = single region
    pub regions: usize,
    /// cross-rack bandwidth multiplier on the inter-node base rate
    pub rack_bw: f64,
    /// cross-region bandwidth multiplier on the inter-node base rate
    pub region_bw: f64,
    /// per-hop latency of a cross-rack link (seconds)
    pub rack_latency_s: f64,
    /// per-hop latency of a cross-region link (seconds)
    pub region_latency_s: f64,
    /// the `--topology` string this spec was parsed from (empty for
    /// flat topologies; label only, never consulted for pricing)
    pub spec_str: String,
}

impl TopologySpec {
    /// The trivial single-rack tree every cluster starts with.
    pub fn flat() -> TopologySpec {
        TopologySpec {
            racks: 1,
            regions: 1,
            rack_bw: 1.0,
            region_bw: 1.0,
            rack_latency_s: 5e-6,
            region_latency_s: 1e-3,
            spec_str: String::new(),
        }
    }

    /// A trivial tree: one rack, one region. Flat topologies take the
    /// pre-topology code paths bit-for-bit.
    pub fn is_flat(&self) -> bool {
        self.racks <= 1 && self.regions <= 1
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.racks == 0 || self.regions == 0 {
            return Err(
                "topology: racks and regions must be >= 1".into()
            );
        }
        if self.regions > self.racks {
            return Err(format!(
                "topology: {} regions cannot partition {} racks",
                self.regions, self.racks
            ));
        }
        for (what, v) in [
            ("rack_bw", self.rack_bw),
            ("region_bw", self.region_bw),
            ("rack_lat", self.rack_latency_s),
            ("region_lat", self.region_latency_s),
        ] {
            if !(v.is_finite() && v > 0.0) {
                return Err(format!(
                    "topology: {what} must be finite and > 0, got {v}"
                ));
            }
        }
        Ok(())
    }
}

impl Default for TopologySpec {
    fn default() -> TopologySpec {
        TopologySpec::flat()
    }
}

/// Parse a `--topology` string into a [`TopologySpec`].
///
/// Syntax: colon-separated `key=value` pairs, e.g.
/// `"racks=4:rack_bw=0.5"`. Known keys: `racks`, `regions`, `rack_bw`,
/// `region_bw`, `rack_lat`, `region_lat` (latencies in seconds).
/// Unspecified keys keep the flat defaults (bandwidth multiplier 1.0,
/// rack latency = the IB default, region latency 1 ms). The empty
/// string is exactly the flat topology.
pub fn parse_topology(s: &str) -> Result<TopologySpec, String> {
    let mut t = TopologySpec::flat();
    if s.is_empty() {
        return Ok(t);
    }
    for part in s.split(':') {
        let part = part.trim();
        let (k, v) = part.split_once('=').ok_or_else(|| {
            format!(
                "topology {s:?}: expected key=value, got {part:?}"
            )
        })?;
        let (k, v) = (k.trim(), v.trim());
        let bad =
            || format!("topology {s:?}: bad value {v:?} for {k}");
        match k {
            "racks" => t.racks = v.parse().map_err(|_| bad())?,
            "regions" => t.regions = v.parse().map_err(|_| bad())?,
            "rack_bw" => t.rack_bw = v.parse().map_err(|_| bad())?,
            "region_bw" => {
                t.region_bw = v.parse().map_err(|_| bad())?
            }
            "rack_lat" => {
                t.rack_latency_s = v.parse().map_err(|_| bad())?
            }
            "region_lat" => {
                t.region_latency_s = v.parse().map_err(|_| bad())?
            }
            _ => {
                return Err(format!(
                    "topology {s:?}: unknown key {k:?} (known: \
                     racks, regions, rack_bw, region_bw, rack_lat, \
                     region_lat)"
                ))
            }
        }
    }
    t.spec_str = s.to_string();
    t.validate()?;
    Ok(t)
}

/// A named set of nodes that fail or degrade together — one shared
/// switch / power domain per rack, derived from the topology tree.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureDomain {
    /// domain label, e.g. `"rack3"`
    pub name: String,
    /// the nodes under the domain (sorted, non-empty)
    pub nodes: Vec<usize>,
}

/// Cluster shape: `n_nodes` nodes × `gpus_per_node` GPUs.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub n_nodes: usize,
    pub gpus_per_node: usize,
    pub gpu: GpuSpec,
    /// NVLink bytes/s between GPUs in a node
    pub nvlink_bw: f64,
    /// InfiniBand bytes/s between nodes (per link)
    pub ib_bw: f64,
    /// inter-node latency seconds
    pub ib_latency_s: f64,
    /// hardware generations present in the fleet (never empty; a
    /// homogeneous cluster carries a single reference tier)
    pub tiers: Vec<HardwareTier>,
    /// per-node tier assignment, applied cyclically
    /// (`node_tier[node % len]`); empty = every node on tier 0
    pub node_tier: Vec<usize>,
    /// the `--hardware-mix` string this spec was built from (empty for
    /// homogeneous clusters; label only, never consulted for pricing)
    pub hardware_mix: String,
    /// the rack/region tree above the node level (flat by default;
    /// see [`TopologySpec`] for the byte-freedom contract)
    pub topology: TopologySpec,
}

impl ClusterSpec {
    /// The paper's default 128-GPU cluster: 16 nodes × 8 A100s.
    pub fn default_128() -> ClusterSpec {
        ClusterSpec::with_gpus(128)
    }

    /// A cluster with `n` GPUs in 8-GPU nodes (Fig. 9b sweeps this).
    pub fn with_gpus(n: usize) -> ClusterSpec {
        let gpus_per_node = 8.min(n.max(1));
        ClusterSpec {
            n_nodes: n.div_ceil(gpus_per_node),
            gpus_per_node,
            gpu: GpuSpec::a100_80g(),
            nvlink_bw: 600e9,
            ib_bw: 12.5e9, // 100 Gb/s
            ib_latency_s: 5e-6,
            tiers: vec![HardwareTier::reference()],
            node_tier: vec![],
            hardware_mix: String::new(),
            topology: TopologySpec::flat(),
        }
    }

    /// [`ClusterSpec::with_gpus`] with a `--hardware-mix` applied (see
    /// [`parse_hardware_mix`]). An empty mix string is exactly
    /// `with_gpus`.
    pub fn with_gpus_mix(n: usize, mix: &str) -> Result<ClusterSpec, String> {
        let mut spec = ClusterSpec::with_gpus(n);
        spec.apply_hardware_mix(mix)?;
        Ok(spec)
    }

    /// Install the tiers and cyclic node pattern described by `mix`
    /// (empty = reset to the homogeneous reference fleet).
    pub fn apply_hardware_mix(&mut self, mix: &str) -> Result<(), String> {
        if mix.is_empty() {
            self.tiers = vec![HardwareTier::reference()];
            self.node_tier = vec![];
            self.hardware_mix = String::new();
            return Ok(());
        }
        let (tiers, pattern) = parse_hardware_mix(mix)?;
        self.tiers = tiers;
        self.node_tier = pattern;
        self.hardware_mix = mix.to_string();
        Ok(())
    }

    /// Install the rack/region tree described by `spec` (see
    /// [`parse_topology`]; empty = reset to the flat topology).
    pub fn apply_topology(&mut self, spec: &str) -> Result<(), String> {
        self.topology = parse_topology(spec)?;
        Ok(())
    }

    /// Rack of `node`: nodes pack into `topology.racks` contiguous
    /// blocks (0 on flat topologies).
    pub fn rack_of(&self, node: usize) -> usize {
        let racks = self.topology.racks;
        if racks <= 1 {
            return 0;
        }
        let per = self.n_nodes.div_ceil(racks).max(1);
        (node / per).min(racks - 1)
    }

    /// Region of `node`: racks pack into `topology.regions` contiguous
    /// blocks (0 on single-region topologies).
    pub fn region_of(&self, node: usize) -> usize {
        let regions = self.topology.regions;
        if regions <= 1 {
            return 0;
        }
        let per = self.topology.racks.div_ceil(regions).max(1);
        (self.rack_of(node) / per).min(regions - 1)
    }

    /// Named correlated-failure domains derived from the topology
    /// tree: one per non-empty rack. Empty on flat topologies — a
    /// single-rack cluster has no shared switch/power domain whose
    /// loss would be distinguishable from independent node churn.
    pub fn failure_domains(&self) -> Vec<FailureDomain> {
        if self.topology.racks <= 1 {
            return vec![];
        }
        let mut domains: Vec<FailureDomain> = (0..self.topology.racks)
            .map(|r| FailureDomain {
                name: format!("rack{r}"),
                nodes: vec![],
            })
            .collect();
        for node in 0..self.n_nodes {
            domains[self.rack_of(node)].nodes.push(node);
        }
        domains.retain(|d| !d.nodes.is_empty());
        domains
    }

    pub fn total_gpus(&self) -> usize {
        self.n_nodes * self.gpus_per_node
    }

    /// Tier index of `node` (cyclic pattern; tier 0 when no pattern).
    pub fn tier_index(&self, node: usize) -> usize {
        if self.node_tier.is_empty() {
            0
        } else {
            self.node_tier[node % self.node_tier.len()]
                .min(self.tiers.len().saturating_sub(1))
        }
    }

    pub fn tier_of(&self, node: usize) -> &HardwareTier {
        &self.tiers[self.tier_index(node)]
    }

    /// Effective-FLOP/s multiplier of `node` vs the reference GPU.
    pub fn compute_mult(&self, node: usize) -> f64 {
        self.tier_of(node).compute_mult
    }

    /// Link-bandwidth multiplier of `node`.
    pub fn bw_mult(&self, node: usize) -> f64 {
        self.tier_of(node).bw_mult
    }

    /// HBM capacity of one GPU on `node` (tier-scaled).
    pub fn mem_bytes_of(&self, node: usize) -> f64 {
        self.gpu.mem_bytes * self.tier_of(node).mem_mult
    }

    /// Does every node sit on a reference (all-1.0) tier? Homogeneous
    /// clusters take the pre-tier code paths bit-for-bit; callers gate
    /// summation-order-sensitive math on this (repeated per-GPU
    /// addition is not bit-equal to `n as f64 *`).
    pub fn is_uniform_reference(&self) -> bool {
        self.tiers.iter().all(HardwareTier::is_reference)
            || (0..self.n_nodes)
                .all(|n| self.tier_of(n).is_reference())
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.tiers.is_empty() {
            return Err("cluster has no hardware tiers".into());
        }
        for t in &self.tiers {
            t.validate()?;
        }
        for &ti in &self.node_tier {
            if ti >= self.tiers.len() {
                return Err(format!(
                    "node_tier index {ti} out of range ({} tiers)",
                    self.tiers.len()
                ));
            }
        }
        self.topology.validate()?;
        Ok(())
    }
}

/// Parse a `--hardware-mix` string into a tier list and a cyclic
/// per-node tier pattern.
///
/// Syntax: colon-separated generations, each optionally weighted —
/// `"a100*3:h100"` means "repeating groups of 3 A100 nodes then 1 H100
/// node". Generation names resolve through the calibration table in
/// [`crate::model::cost::tier_by_name`]. A single unweighted
/// generation (e.g. `"h100"`) is a homogeneous non-reference fleet.
pub fn parse_hardware_mix(
    mix: &str,
) -> Result<(Vec<HardwareTier>, Vec<usize>), String> {
    let mut tiers: Vec<HardwareTier> = vec![];
    let mut pattern: Vec<usize> = vec![];
    for part in mix.split(':') {
        let part = part.trim();
        if part.is_empty() {
            return Err(format!("empty generation in mix {mix:?}"));
        }
        let (name, count) = match part.split_once('*') {
            Some((n, c)) => {
                let count: usize = c.trim().parse().map_err(|_| {
                    format!("bad weight {c:?} in mix {mix:?}")
                })?;
                if count == 0 {
                    return Err(format!(
                        "zero weight for {n:?} in mix {mix:?}"
                    ));
                }
                (n.trim(), count)
            }
            None => (part, 1),
        };
        let tier = crate::model::cost::tier_by_name(name)
            .ok_or_else(|| {
                format!("unknown hardware generation {name:?}")
            })?;
        let idx = match tiers.iter().position(|t| t == &tier) {
            Some(i) => i,
            None => {
                tiers.push(tier);
                tiers.len() - 1
            }
        };
        pattern.extend(std::iter::repeat(idx).take(count));
    }
    if tiers.is_empty() {
        return Err(format!("empty hardware mix {mix:?}"));
    }
    Ok((tiers, pattern))
}

/// Identifies one GPU as (node, local index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuId {
    pub node: usize,
    pub idx: usize,
}

/// Bandwidth tier between two GPUs — the hierarchy the scheduler's
/// bottom-up grouping walks (§3.4 "node, then across nodes, then ranks").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    SameGpu,
    IntraNode,
    InterNode,
}

impl ClusterSpec {
    pub fn tier(&self, a: GpuId, b: GpuId) -> Tier {
        if a == b {
            Tier::SameGpu
        } else if a.node == b.node {
            Tier::IntraNode
        } else {
            Tier::InterNode
        }
    }

    /// Point-to-point bandwidth between two GPUs (bytes/s), scaled by
    /// the slower endpoint's hardware-tier bandwidth multiplier (×1.0
    /// — bit-exact — on homogeneous fleets) and, on non-flat
    /// topologies, by the widest structural tier the link crosses
    /// (cross-region beats cross-rack; flat topologies early-return
    /// before any topology float op touches the value).
    /// `bottleneck_bandwidth`, `allreduce_time` and `p2p_time` inherit
    /// the scaling, so every comm term the planner prices is both
    /// tier- and topology-aware.
    pub fn bandwidth(&self, a: GpuId, b: GpuId) -> f64 {
        let base = match self.tier(a, b) {
            Tier::SameGpu => self.gpu.hbm_bw,
            Tier::IntraNode => self.nvlink_bw,
            Tier::InterNode => self.ib_bw,
        };
        let bw = base * self.bw_mult(a.node).min(self.bw_mult(b.node));
        if self.topology.is_flat() || a.node == b.node {
            return bw;
        }
        if self.region_of(a.node) != self.region_of(b.node) {
            bw * self.topology.region_bw
        } else if self.rack_of(a.node) != self.rack_of(b.node) {
            bw * self.topology.rack_bw
        } else {
            bw
        }
    }

    /// Per-hop latency of a collective across `gpus`: the latency of
    /// the widest structural tier the gang spans (intra-node 1 µs,
    /// inter-node IB, then rack / region hops on non-flat topologies).
    fn gang_latency(&self, gpus: &[GpuId]) -> f64 {
        let cross_node =
            gpus.iter().any(|g| g.node != gpus[0].node);
        if !cross_node {
            return 1e-6;
        }
        if !self.topology.is_flat() {
            let r0 = self.region_of(gpus[0].node);
            if gpus.iter().any(|g| self.region_of(g.node) != r0) {
                return self.topology.region_latency_s;
            }
            let k0 = self.rack_of(gpus[0].node);
            if gpus.iter().any(|g| self.rack_of(g.node) != k0) {
                return self.topology.rack_latency_s;
            }
        }
        self.ib_latency_s
    }

    /// Slowest link bandwidth across a set of GPUs — ring-collective
    /// bottleneck.
    pub fn bottleneck_bandwidth(&self, gpus: &[GpuId]) -> f64 {
        let mut bw = self.gpu.hbm_bw;
        for (i, &a) in gpus.iter().enumerate() {
            for &b in gpus.iter().skip(i + 1) {
                bw = bw.min(self.bandwidth(a, b));
            }
        }
        bw
    }

    /// Time for a ring all-reduce of `bytes` across `gpus`.
    pub fn allreduce_time(&self, gpus: &[GpuId], bytes: f64) -> f64 {
        let n = gpus.len();
        if n <= 1 {
            return 0.0;
        }
        let bw = self.bottleneck_bandwidth(gpus);
        let lat = self.gang_latency(gpus);
        // ring: 2(n-1)/n * bytes over the bottleneck link + per-step lat
        2.0 * (n as f64 - 1.0) / n as f64 * bytes / bw
            + 2.0 * (n as f64 - 1.0) * lat
    }

    /// Time for a point-to-point activation transfer (pipeline edge).
    pub fn p2p_time(&self, a: GpuId, b: GpuId, bytes: f64) -> f64 {
        if a == b {
            return 0.0;
        }
        let lat = self.gang_latency(&[a, b]);
        bytes / self.bandwidth(a, b) + lat
    }
}

/// Gang allocator with node-packing preference: allocations avoid
/// spanning nodes when a single node can hold them (keeps groups in the
/// cheap bandwidth tier). Tracks node health: down nodes keep their
/// free-list bookkeeping (releases still land there) but are excluded
/// from every allocation path until [`Allocator::set_down`] marks them
/// up again. Also tracks per-node *speed* multipliers (the straggler
/// fault mode): a degraded node stays fully allocatable — degradation
/// is a throughput property, not a capacity one — and the simulator
/// prices every group touching it at the slowest member node's rate
/// ([`Allocator::alloc_speed`]).
///
/// **Holes** (single-GPU faults, [`Allocator::set_gpu_down`]): a
/// failed GPU inside an otherwise-healthy node is *stranded out of the
/// free lists* — a free GPU moves to the node's `holed` side-list and
/// a release onto a holed slot lands there too
/// (strand-but-account: [`Allocator::free_gpus`] still counts it, so
/// conservation `free_gpus() + held == capacity` holds through any
/// churn). Because no free list ever contains a holed GPU, every
/// allocation path — flat, scored/topology, avoiding — respects holes
/// with zero logic changes, and a hole-free fleet replays the
/// pre-hole allocation order bit-for-bit (the byte-freedom contract).
/// Node-level `set_down` composes orthogonally: recovering a node with
/// a live hole restores exactly `gpus_per_node - holes` allocatable
/// GPUs, because the holed slots never re-enter the free list until
/// their own `set_gpu_down(.., false)`.
#[derive(Debug, Clone)]
pub struct Allocator {
    spec: ClusterSpec,
    /// free[node] = list of free local indices
    free: Vec<Vec<usize>>,
    /// down[node] = node is failed; its GPUs are unallocatable
    down: Vec<bool>,
    /// speed[node] = throughput multiplier (1.0 healthy; a straggler
    /// episode samples a value in (0, 1))
    speed: Vec<f64>,
    /// gpu_down[node][idx] = that single GPU is failed (a hole)
    gpu_down: Vec<Vec<bool>>,
    /// holed[node] = free-but-failed local indices, stranded out of
    /// `free` until the hole heals
    holed: Vec<Vec<usize>>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub gpus: Vec<GpuId>,
}

impl Allocation {
    pub fn n_gpus(&self) -> usize {
        self.gpus.len()
    }

    pub fn nodes(&self) -> Vec<usize> {
        let mut n: Vec<usize> = self.gpus.iter().map(|g| g.node).collect();
        n.sort_unstable();
        n.dedup();
        n
    }

    pub fn spans_nodes(&self) -> bool {
        self.nodes().len() > 1
    }

    /// Union of two allocations (group merge).
    pub fn union(&self, other: &Allocation) -> Allocation {
        let mut gpus = self.gpus.clone();
        gpus.extend_from_slice(&other.gpus);
        gpus.sort_unstable();
        gpus.dedup();
        Allocation { gpus }
    }
}

impl Allocator {
    pub fn new(spec: ClusterSpec) -> Allocator {
        let free = (0..spec.n_nodes)
            .map(|_| (0..spec.gpus_per_node).rev().collect())
            .collect();
        let down = vec![false; spec.n_nodes];
        let speed = vec![1.0; spec.n_nodes];
        let gpu_down = (0..spec.n_nodes)
            .map(|_| vec![false; spec.gpus_per_node])
            .collect();
        let holed = vec![vec![]; spec.n_nodes];
        Allocator {
            spec,
            free,
            down,
            speed,
            gpu_down,
            holed,
        }
    }

    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// All free GPUs, including those stranded on down nodes *and*
    /// free-but-holed GPUs (strand-but-account: a holed GPU is not
    /// allocatable but is not held by any gang either, so it still
    /// counts toward the conservation invariant
    /// `free_gpus() + held == capacity`).
    pub fn free_gpus(&self) -> usize {
        self.free.iter().map(|f| f.len()).sum::<usize>()
            + self.holed.iter().map(|h| h.len()).sum::<usize>()
    }

    /// Free (allocatable) GPUs on one node: its free list, which never
    /// contains holed slots. Counts even when the node is down — pair
    /// with [`Allocator::is_down`] for usable capacity.
    pub fn free_on(&self, node: usize) -> usize {
        self.free[node].len()
    }

    /// Free GPUs on healthy nodes — what [`Allocator::allocate`] can
    /// actually hand out.
    pub fn available_gpus(&self) -> usize {
        self.free
            .iter()
            .enumerate()
            .filter(|(node, _)| !self.down[*node])
            .map(|(_, f)| f.len())
            .sum()
    }

    /// Mark a node failed (`down = true`) or recovered. While down, the
    /// node's GPUs are excluded from allocation; releases onto a down
    /// node still return GPUs to its free list, so recovery restores
    /// full capacity with no extra bookkeeping.
    pub fn set_down(&mut self, node: usize, down: bool) {
        self.down[node] = down;
    }

    pub fn is_down(&self, node: usize) -> bool {
        self.down[node]
    }

    /// Mark a single GPU failed (a *hole* in an otherwise-usable node)
    /// or healed. Idempotent. Failing a free GPU strands it out of the
    /// node's free list into the `holed` side-list; failing an
    /// allocated GPU only sets the mask — the strand happens when its
    /// gang releases ([`Allocator::release`] routes per the mask).
    /// Healing moves any stranded slot back to the free list; a healed
    /// GPU still held by a gang simply releases normally later.
    pub fn set_gpu_down(
        &mut self,
        node: usize,
        idx: usize,
        down: bool,
    ) {
        if self.gpu_down[node][idx] == down {
            return;
        }
        self.gpu_down[node][idx] = down;
        if down {
            let before = self.free[node].len();
            self.free[node].retain(|&i| i != idx);
            if self.free[node].len() < before {
                debug_assert!(
                    !self.holed[node].contains(&idx),
                    "GPU ({node},{idx}) both free and holed"
                );
                self.holed[node].push(idx);
            }
        } else if let Some(p) =
            self.holed[node].iter().position(|&i| i == idx)
        {
            self.holed[node].remove(p);
            debug_assert!(
                !self.free[node].contains(&idx),
                "double free of ({node},{idx}) on heal"
            );
            self.free[node].push(idx);
        }
    }

    /// Is this single GPU failed (holed)?
    pub fn gpu_is_down(&self, node: usize, idx: usize) -> bool {
        self.gpu_down[node][idx]
    }

    /// Number of holed GPUs on `node` — mask bits, so allocated-but-
    /// failed GPUs count too. The node's surviving capacity is
    /// `gpus_per_node - holed_gpus(node)`.
    pub fn holed_gpus(&self, node: usize) -> usize {
        self.gpu_down[node].iter().filter(|&&d| d).count()
    }

    /// Set a node's throughput multiplier (straggler degrade/restore).
    /// Must be > 0: a node at speed 0 is a failure, not a straggler.
    pub fn set_speed(&mut self, node: usize, speed: f64) {
        assert!(speed > 0.0, "node speed must be > 0, got {speed}");
        self.speed[node] = speed;
    }

    pub fn node_speed(&self, node: usize) -> f64 {
        self.speed[node]
    }

    /// Hardware tier of `node` — the *static* fleet-heterogeneity
    /// axis, deliberately distinct from the dynamic straggler `speed`
    /// above: tiers are priced into plans, speeds are observed faults.
    pub fn tier_of(&self, node: usize) -> &HardwareTier {
        self.spec.tier_of(node)
    }

    /// Static compute multiplier of `node`'s generation.
    pub fn compute_mult(&self, node: usize) -> f64 {
        self.spec.compute_mult(node)
    }

    /// Effective speed of a gang allocation: the *slowest* node it
    /// touches — a fused group is gang-synchronous, so one degraded
    /// member node paces every step (1.0 for an empty allocation).
    pub fn alloc_speed(&self, alloc: &Allocation) -> f64 {
        alloc
            .gpus
            .iter()
            .map(|g| self.speed[g.node])
            .fold(1.0, f64::min)
    }

    /// Free GPUs on nodes that are neither down nor flagged in
    /// `avoid` — the capacity [`Allocator::allocate_avoiding`] can
    /// hand out without touching a suspected straggler.
    pub fn available_gpus_avoiding(&self, avoid: &[bool]) -> usize {
        self.free
            .iter()
            .enumerate()
            .filter(|(node, _)| {
                !self.down[*node]
                    && !avoid.get(*node).copied().unwrap_or(false)
            })
            .map(|(_, f)| f.len())
            .sum()
    }

    /// [`Allocator::allocate`], preferring nodes not flagged in
    /// `avoid` (suspected stragglers): first try the allocation with
    /// avoided nodes treated as down; if that cannot be satisfied,
    /// fall back to the ordinary path — a slow GPU still beats no GPU.
    /// With an all-false `avoid` this is *exactly* `allocate` (the
    /// straggler-free differential fixture depends on that).
    pub fn allocate_avoiding(
        &mut self,
        n: usize,
        avoid: &[bool],
    ) -> Option<Allocation> {
        if avoid.iter().any(|&a| a) {
            let saved = self.down.clone();
            for (node, &a) in avoid.iter().enumerate() {
                if a && node < self.down.len() {
                    self.down[node] = true;
                }
            }
            let got = self.allocate(n);
            self.down = saved;
            if got.is_some() {
                return got;
            }
        }
        self.allocate(n)
    }

    pub fn total_gpus(&self) -> usize {
        self.spec.total_gpus()
    }

    /// Allocate `n` GPUs from healthy nodes, preferring (1) the single
    /// node with the tightest fit, then (2) spilling across the
    /// emptiest nodes.
    ///
    /// On heterogeneous fleets and non-flat topologies the spill is
    /// *placement-aware* ([`Allocator::allocate_scored`]): candidate
    /// placements are scored to prefer single-hardware-tier gangs
    /// (gang-synchronous pacing means one slow-generation member taxes
    /// every step) and minimal topology radius (fewest racks spanned),
    /// falling back to a mixed gang rather than starving. On
    /// uniform-reference flat clusters the count-based path runs
    /// unchanged, and the scored path itself degenerates to the same
    /// order there (pinned by the differential test below) — so the
    /// scoring layer is byte-free when unused.
    pub fn allocate(&mut self, n: usize) -> Option<Allocation> {
        if n == 0 || self.available_gpus() < n {
            return None;
        }
        if self.spec.is_uniform_reference()
            && self.spec.topology.is_flat()
        {
            Some(self.allocate_flat(n))
        } else {
            Some(self.allocate_scored(n))
        }
    }

    /// The pre-topology count-based path (callers guarantee
    /// `available_gpus() >= n > 0`): best-fit single node, then spill
    /// across the emptiest healthy nodes.
    fn allocate_flat(&mut self, n: usize) -> Allocation {
        // best-fit single node
        let mut best: Option<(usize, usize)> = None; // (node, slack)
        for (node, f) in self.free.iter().enumerate() {
            if !self.down[node] && f.len() >= n {
                let slack = f.len() - n;
                if best.map_or(true, |(_, s)| slack < s) {
                    best = Some((node, slack));
                }
            }
        }
        if let Some((node, _)) = best {
            return self.take_from_plan(&[(node, n)]);
        }
        // spill: fill from healthy nodes with the most free capacity
        // first
        let mut order: Vec<usize> = (0..self.free.len())
            .filter(|&i| !self.down[i])
            .collect();
        order.sort_by_key(|&i| std::cmp::Reverse(self.free[i].len()));
        let mut gpus = Vec::with_capacity(n);
        let mut need = n;
        for node in order {
            while need > 0 {
                match self.free[node].pop() {
                    Some(idx) => {
                        gpus.push(GpuId { node, idx });
                        need -= 1;
                    }
                    None => break,
                }
            }
            if need == 0 {
                break;
            }
        }
        assert_eq!(
            need, 0,
            "allocator invariant violated: spill fell {need} GPUs \
             short of {n} despite available_gpus() >= n"
        );
        Allocation { gpus }
    }

    /// Placement-aware allocation (callers guarantee
    /// `available_gpus() >= n > 0`). Single-node fits keep the
    /// tightest-slack rule, breaking slack ties first toward
    /// hole-free nodes (fewest failed devices) and then toward the
    /// faster hardware generation. Spills enumerate one candidate per
    /// hardware tier with enough healthy free capacity (a single-tier
    /// gang) plus the whole healthy fleet as the never-starve
    /// fallback, plan each rack-aware fill without mutating anything,
    /// and commit the winner: single-tier beats mixed, then fewest
    /// racks spanned, then the faster generation, then the lower tier
    /// index. On a uniform-reference flat cluster every node is one
    /// tier in one rack, so this reduces to exactly the count-based
    /// order of [`Allocator::allocate_flat`].
    fn allocate_scored(&mut self, n: usize) -> Allocation {
        // best-fit single node (slack, then fewest holed GPUs, then
        // compute_mult desc, then first index — a single node is
        // trivially single-tier and single-rack, so radius cannot
        // discriminate here). The hole tiebreak prefers a clean node
        // over an equally tight one carrying failed devices: a holed
        // node has already demonstrated device attrition, and a gang
        // packed next to a hole is first in line for the next one.
        // On a hole-free fleet every count is 0, so the comparison
        // never discriminates and the order is bit-identical.
        let mut best: Option<(usize, usize)> = None; // (node, slack)
        for (node, f) in self.free.iter().enumerate() {
            if self.down[node] || f.len() < n {
                continue;
            }
            let slack = f.len() - n;
            let better = match best {
                None => true,
                Some((b, s)) => {
                    let (holes, b_holes) =
                        (self.holed_gpus(node), self.holed_gpus(b));
                    slack < s
                        || (slack == s && holes < b_holes)
                        || (slack == s
                            && holes == b_holes
                            && self.spec.compute_mult(node)
                                > self.spec.compute_mult(b))
                }
            };
            if better {
                best = Some((node, slack));
            }
        }
        if let Some((node, _)) = best {
            return self.take_from_plan(&[(node, n)]);
        }
        // one spill candidate per hardware tier that can hold the
        // whole gang on healthy nodes
        let mut winner: Option<(Vec<(usize, usize)>, usize, f64, usize)> =
            None; // (plan, racks_spanned, compute_mult, tier idx)
        for t in 0..self.spec.tiers.len() {
            let nodes: Vec<usize> = (0..self.free.len())
                .filter(|&i| {
                    !self.down[i] && self.spec.tier_index(i) == t
                })
                .collect();
            let Some(plan) = self.plan_spill(&nodes, n) else {
                continue;
            };
            let racks = self.plan_rack_span(&plan);
            let mult = self.spec.tiers[t].compute_mult;
            let better = match &winner {
                None => true,
                Some((_, r, m, _)) => {
                    racks < *r || (racks == *r && mult > *m)
                }
            };
            if better {
                winner = Some((plan, racks, mult, t));
            }
        }
        if let Some((plan, ..)) = winner {
            return self.take_from_plan(&plan);
        }
        // no single tier can hold the gang: mixed-tier fallback over
        // the whole healthy fleet (still rack-aware) rather than
        // starving
        let nodes: Vec<usize> = (0..self.free.len())
            .filter(|&i| !self.down[i])
            .collect();
        let plan = self.plan_spill(&nodes, n).unwrap_or_else(|| {
            panic!(
                "allocator invariant violated: healthy fleet cannot \
                 hold {n} GPUs despite available_gpus() >= n"
            )
        });
        self.take_from_plan(&plan)
    }

    /// Plan a spill of `n` GPUs over `nodes` (a healthy candidate
    /// set) without mutating any free list; `None` if the set lacks
    /// capacity. Rack-aware: a single rack that can hold the gang is
    /// preferred (tightest rack wins, ties to the lower rack id);
    /// otherwise racks fill fullest-first. Within any rack, nodes
    /// fill most-free-first with index ties stable — on a flat
    /// topology everything is one rack, so the plan is exactly the
    /// count-based order.
    fn plan_spill(
        &self,
        nodes: &[usize],
        n: usize,
    ) -> Option<Vec<(usize, usize)>> {
        let total: usize =
            nodes.iter().map(|&i| self.free[i].len()).sum();
        if total < n {
            return None;
        }
        // bucket candidate nodes by rack, preserving index order
        let racks = self.spec.topology.racks.max(1);
        let mut by_rack: Vec<Vec<usize>> = vec![vec![]; racks];
        for &i in nodes {
            by_rack[self.spec.rack_of(i)].push(i);
        }
        let rack_free = |r: &Vec<usize>| -> usize {
            r.iter().map(|&i| self.free[i].len()).sum()
        };
        // a single rack that fits: tightest first, then lowest id
        let mut best: Option<(usize, usize)> = None; // (rack, slack)
        for (rid, r) in by_rack.iter().enumerate() {
            let f = rack_free(r);
            if f < n {
                continue;
            }
            let slack = f - n;
            if best.map_or(true, |(_, s)| slack < s) {
                best = Some((rid, slack));
            }
        }
        let rack_order: Vec<usize> = match best {
            Some((rid, _)) => vec![rid],
            None => {
                // spill across racks, fullest rack first (fewest
                // racks touched), ties to the lower rack id
                let mut order: Vec<usize> = (0..racks)
                    .filter(|&r| !by_rack[r].is_empty())
                    .collect();
                order.sort_by_key(|&r| {
                    std::cmp::Reverse(rack_free(&by_rack[r]))
                });
                order
            }
        };
        let mut plan: Vec<(usize, usize)> = vec![];
        let mut need = n;
        for rid in rack_order {
            let mut order = by_rack[rid].clone();
            // most-free-first, then fewest holed GPUs (prefer packing
            // spill shares onto clean nodes), index ties stable — all
            // hole counts are 0 on a hole-free fleet, so the order is
            // bit-identical there
            order.sort_by_key(|&i| {
                (
                    std::cmp::Reverse(self.free[i].len()),
                    self.holed_gpus(i),
                )
            });
            for node in order {
                if need == 0 {
                    break;
                }
                let take = self.free[node].len().min(need);
                if take > 0 {
                    plan.push((node, take));
                    need -= take;
                }
            }
            if need == 0 {
                break;
            }
        }
        if need == 0 {
            Some(plan)
        } else {
            None
        }
    }

    /// Distinct racks a planned fill would span.
    fn plan_rack_span(&self, plan: &[(usize, usize)]) -> usize {
        let mut racks: Vec<usize> = plan
            .iter()
            .map(|&(node, _)| self.spec.rack_of(node))
            .collect();
        racks.sort_unstable();
        racks.dedup();
        racks.len()
    }

    /// Commit a fill plan, popping `take` GPUs from each node's free
    /// list. The pop is a checked invariant (the plan was derived from
    /// the same free lists moments ago): a node coming up short here
    /// means the bookkeeping is corrupt, and the panic names it
    /// instead of unwrapping on `None`.
    fn take_from_plan(
        &mut self,
        plan: &[(usize, usize)],
    ) -> Allocation {
        let mut gpus = Vec::new();
        for &(node, take) in plan {
            for _ in 0..take {
                let idx =
                    self.free[node].pop().unwrap_or_else(|| {
                        panic!(
                            "allocator invariant violated: node \
                             {node} free list exhausted \
                             mid-allocation (planned {take} GPUs)"
                        )
                    });
                gpus.push(GpuId { node, idx });
            }
        }
        Allocation { gpus }
    }

    /// Return an allocation's GPUs to the free pool. A GPU whose slot
    /// is holed ([`Allocator::set_gpu_down`] while it was allocated)
    /// strands into the `holed` side-list instead — accounted but not
    /// allocatable until the hole heals. With no holes this is exactly
    /// the pre-hole push (byte-freedom).
    pub fn release(&mut self, alloc: &Allocation) {
        for g in &alloc.gpus {
            debug_assert!(
                !self.free[g.node].contains(&g.idx),
                "double free of {g:?}"
            );
            debug_assert!(
                !self.holed[g.node].contains(&g.idx),
                "double free of holed {g:?}"
            );
            if self.gpu_down[g.node][g.idx] {
                self.holed[g.node].push(g.idx);
            } else {
                self.free[g.node].push(g.idx);
            }
        }
    }

    /// Randomized allocation order (trace replay uses this to model
    /// fragmented production clusters). Down nodes are excluded like in
    /// [`Allocator::allocate`].
    pub fn allocate_random(&mut self, n: usize, rng: &mut Rng)
        -> Option<Allocation> {
        if self.available_gpus() < n || n == 0 {
            return None;
        }
        let mut candidates: Vec<GpuId> = vec![];
        for (node, f) in self.free.iter().enumerate() {
            if self.down[node] {
                continue;
            }
            for &idx in f {
                candidates.push(GpuId { node, idx });
            }
        }
        rng.shuffle(&mut candidates);
        let chosen: Vec<GpuId> = candidates.into_iter().take(n).collect();
        for g in &chosen {
            self.free[g.node].retain(|&i| i != g.idx);
        }
        Some(Allocation { gpus: chosen })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec4x4() -> ClusterSpec {
        let mut s = ClusterSpec::with_gpus(16);
        s.n_nodes = 4;
        s.gpus_per_node = 4;
        s
    }

    #[test]
    fn tiers() {
        let s = spec4x4();
        let a = GpuId { node: 0, idx: 0 };
        let b = GpuId { node: 0, idx: 1 };
        let c = GpuId { node: 1, idx: 0 };
        assert_eq!(s.tier(a, a), Tier::SameGpu);
        assert_eq!(s.tier(a, b), Tier::IntraNode);
        assert_eq!(s.tier(a, c), Tier::InterNode);
        assert!(s.bandwidth(a, b) > s.bandwidth(a, c));
    }

    #[test]
    fn allreduce_zero_for_single() {
        let s = spec4x4();
        assert_eq!(s.allreduce_time(&[GpuId { node: 0, idx: 0 }], 1e9), 0.0);
    }

    #[test]
    fn allreduce_slower_across_nodes() {
        let s = spec4x4();
        let intra = vec![GpuId { node: 0, idx: 0 }, GpuId { node: 0, idx: 1 }];
        let inter = vec![GpuId { node: 0, idx: 0 }, GpuId { node: 1, idx: 0 }];
        assert!(s.allreduce_time(&inter, 1e8) > s.allreduce_time(&intra, 1e8));
    }

    #[test]
    fn allocator_prefers_single_node() {
        let mut a = Allocator::new(spec4x4());
        let alloc = a.allocate(4).unwrap();
        assert!(!alloc.spans_nodes());
        assert_eq!(a.free_gpus(), 12);
    }

    #[test]
    fn allocator_best_fit() {
        let mut a = Allocator::new(spec4x4());
        let two = a.allocate(2).unwrap(); // node X now has 2 free
        let four = a.allocate(4).unwrap(); // must use a different full node
        assert!(!four.spans_nodes());
        assert_ne!(four.gpus[0].node, two.gpus[0].node);
        // 2-gpu ask should best-fit into the half-empty node
        let two2 = a.allocate(2).unwrap();
        assert_eq!(two2.gpus[0].node, two.gpus[0].node);
    }

    #[test]
    fn allocator_spills_when_needed() {
        let mut a = Allocator::new(spec4x4());
        let alloc = a.allocate(6).unwrap();
        assert!(alloc.spans_nodes());
        assert_eq!(alloc.n_gpus(), 6);
        assert_eq!(a.free_gpus(), 10);
    }

    #[test]
    fn allocator_exhaustion() {
        let mut a = Allocator::new(spec4x4());
        assert!(a.allocate(17).is_none());
        let x = a.allocate(16).unwrap();
        assert!(a.allocate(1).is_none());
        a.release(&x);
        assert_eq!(a.free_gpus(), 16);
    }

    #[test]
    fn release_restores_exact_capacity() {
        let mut a = Allocator::new(spec4x4());
        let x = a.allocate(3).unwrap();
        let y = a.allocate(5).unwrap();
        a.release(&x);
        a.release(&y);
        assert_eq!(a.free_gpus(), 16);
        assert!(a.allocate(16).is_some());
    }

    #[test]
    fn union_dedups() {
        let a = Allocation {
            gpus: vec![GpuId { node: 0, idx: 0 }, GpuId { node: 0, idx: 1 }],
        };
        let b = Allocation {
            gpus: vec![GpuId { node: 0, idx: 1 }, GpuId { node: 1, idx: 0 }],
        };
        assert_eq!(a.union(&b).n_gpus(), 3);
    }

    #[test]
    fn default_cluster_shape() {
        let s = ClusterSpec::default_128();
        assert_eq!(s.total_gpus(), 128);
        assert_eq!(s.gpus_per_node, 8);
    }

    #[test]
    fn down_node_excluded_from_allocation() {
        let mut a = Allocator::new(spec4x4());
        a.set_down(0, true);
        assert!(a.is_down(0));
        assert_eq!(a.free_gpus(), 16);
        assert_eq!(a.available_gpus(), 12);
        // single-node fits must land on healthy nodes only
        for _ in 0..3 {
            let alloc = a.allocate(4).unwrap();
            assert!(!alloc.spans_nodes());
            assert_ne!(alloc.gpus[0].node, 0);
        }
        // everything healthy is taken; the down node's GPUs stay out
        assert!(a.allocate(1).is_none());
        assert_eq!(a.free_gpus(), 4);
        assert_eq!(a.available_gpus(), 0);
    }

    #[test]
    fn spill_never_touches_down_nodes() {
        let mut a = Allocator::new(spec4x4());
        a.set_down(1, true);
        // 6 > any single node: spills across the 3 healthy nodes
        let alloc = a.allocate(6).unwrap();
        assert!(alloc.spans_nodes());
        assert!(alloc.gpus.iter().all(|g| g.node != 1));
    }

    #[test]
    fn release_onto_down_node_then_recover_restores_capacity() {
        let mut a = Allocator::new(spec4x4());
        let x = a.allocate(4).unwrap();
        let node = x.gpus[0].node;
        a.set_down(node, true);
        // eviction path: the holder's GPUs come back while the node is
        // still down — stranded but accounted
        a.release(&x);
        assert_eq!(a.free_gpus(), 16);
        assert_eq!(a.available_gpus(), 12);
        a.set_down(node, false);
        assert_eq!(a.available_gpus(), 16);
        assert!(a.allocate(16).is_some());
    }

    #[test]
    fn node_speeds_default_healthy_and_bottleneck_allocations() {
        let mut a = Allocator::new(spec4x4());
        for node in 0..4 {
            assert_eq!(a.node_speed(node), 1.0);
        }
        a.set_speed(1, 0.25);
        assert_eq!(a.node_speed(1), 0.25);
        let single = Allocation {
            gpus: vec![GpuId { node: 0, idx: 0 }],
        };
        assert_eq!(a.alloc_speed(&single), 1.0);
        let spanning = Allocation {
            gpus: vec![
                GpuId { node: 0, idx: 0 },
                GpuId { node: 1, idx: 0 },
                GpuId { node: 2, idx: 0 },
            ],
        };
        // gang-synchronous: the slowest node paces the whole gang
        assert_eq!(a.alloc_speed(&spanning), 0.25);
        a.set_speed(1, 1.0);
        assert_eq!(a.alloc_speed(&spanning), 1.0);
        // a degraded node stays fully allocatable
        a.set_speed(1, 0.1);
        assert_eq!(a.available_gpus(), 16);
        assert!(a.allocate(16).is_some());
    }

    #[test]
    fn allocate_avoiding_prefers_healthy_then_falls_back() {
        let mut a = Allocator::new(spec4x4());
        let avoid = [true, false, false, false];
        assert_eq!(a.available_gpus_avoiding(&avoid), 12);
        // fits on unflagged nodes: never touches node 0
        for _ in 0..3 {
            let alloc = a.allocate_avoiding(4, &avoid).unwrap();
            assert!(alloc.gpus.iter().all(|g| g.node != 0));
        }
        // only node 0 is left: fall back rather than starve
        assert_eq!(a.available_gpus_avoiding(&avoid), 0);
        let alloc = a.allocate_avoiding(2, &avoid).unwrap();
        assert!(alloc.gpus.iter().all(|g| g.node == 0));
        // but a *down* node is never a fallback
        let mut b = Allocator::new(spec4x4());
        b.set_down(0, true);
        assert!(b
            .allocate_avoiding(16, &[true, false, false, false])
            .is_none());
    }

    #[test]
    fn allocate_avoiding_all_false_matches_allocate_exactly() {
        let mut a = Allocator::new(spec4x4());
        let mut b = Allocator::new(spec4x4());
        let avoid = [false; 4];
        for n in [2usize, 4, 6, 1, 3] {
            let x = a.allocate(n);
            let y = b.allocate_avoiding(n, &avoid);
            assert_eq!(x, y, "n={n}");
        }
    }

    #[test]
    fn default_spec_is_uniform_reference() {
        let s = ClusterSpec::default_128();
        assert_eq!(s.tiers.len(), 1);
        assert!(s.tiers[0].is_reference());
        assert!(s.node_tier.is_empty());
        assert!(s.is_uniform_reference());
        assert_eq!(s.tier_index(0), 0);
        assert_eq!(s.compute_mult(5), 1.0);
        assert_eq!(s.mem_bytes_of(5), s.gpu.mem_bytes);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn hardware_mix_parses_weighted_round_robin() {
        let (tiers, pattern) =
            parse_hardware_mix("a100*3:h100").unwrap();
        assert_eq!(tiers.len(), 2);
        assert_eq!(tiers[0].name, "a100");
        assert!(tiers[0].is_reference());
        assert_eq!(tiers[1].name, "h100");
        assert!(tiers[1].compute_mult > 1.0);
        assert_eq!(pattern, vec![0, 0, 0, 1]);
        // pattern applies cyclically over nodes
        let s = ClusterSpec::with_gpus_mix(128, "a100*3:h100").unwrap();
        assert!(!s.is_uniform_reference());
        assert_eq!(s.tier_of(0).name, "a100");
        assert_eq!(s.tier_of(3).name, "h100");
        assert_eq!(s.tier_of(7).name, "h100");
        assert_eq!(s.tier_of(4).name, "a100");
        assert!(s.validate().is_ok());
        assert_eq!(s.hardware_mix, "a100*3:h100");
    }

    #[test]
    fn hardware_mix_rejects_garbage() {
        assert!(parse_hardware_mix("notagpu").is_err());
        assert!(parse_hardware_mix("a100*0").is_err());
        assert!(parse_hardware_mix("a100*x").is_err());
        assert!(parse_hardware_mix("a100::h100").is_err());
        let mut s = ClusterSpec::with_gpus(16);
        s.tiers.clear();
        assert!(s.validate().is_err());
        let mut s = ClusterSpec::with_gpus(16);
        s.node_tier = vec![3];
        assert!(s.validate().is_err());
        let mut s = ClusterSpec::with_gpus(16);
        s.tiers[0].compute_mult = 0.0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn empty_mix_resets_to_reference() {
        let mut s = ClusterSpec::with_gpus_mix(32, "v100").unwrap();
        assert!(!s.is_uniform_reference());
        s.apply_hardware_mix("").unwrap();
        assert_eq!(s, ClusterSpec::with_gpus(32));
    }

    #[test]
    fn bandwidth_scales_with_slower_endpoint_tier() {
        let mut s = spec4x4();
        // node 1 on a half-bandwidth tier
        s.tiers.push(HardwareTier {
            name: "slowlink".into(),
            compute_mult: 1.0,
            bw_mult: 0.5,
            mem_mult: 1.0,
        });
        s.node_tier = vec![0, 1, 0, 0];
        let a = GpuId { node: 0, idx: 0 };
        let b = GpuId { node: 2, idx: 0 };
        let c = GpuId { node: 1, idx: 0 };
        // reference-pair links keep the base rate bit-for-bit
        assert_eq!(s.bandwidth(a, b), s.ib_bw);
        // any link touching the slow tier runs at its multiplier
        assert_eq!(s.bandwidth(a, c), s.ib_bw * 0.5);
        let d = GpuId { node: 1, idx: 1 };
        assert_eq!(s.bandwidth(c, d), s.nvlink_bw * 0.5);
        // collectives inherit the scaled bottleneck
        assert!(
            s.allreduce_time(&[a, c], 1e8)
                > s.allreduce_time(&[a, b], 1e8)
        );
        assert!(s.p2p_time(a, c, 1e8) > s.p2p_time(a, b, 1e8));
    }

    #[test]
    fn topology_parse_roundtrip_and_defaults() {
        let t = parse_topology("").unwrap();
        assert_eq!(t, TopologySpec::flat());
        assert!(t.is_flat());
        let t = parse_topology("racks=4:rack_bw=0.5").unwrap();
        assert!(!t.is_flat());
        assert_eq!(t.racks, 4);
        assert_eq!(t.regions, 1);
        assert_eq!(t.rack_bw, 0.5);
        assert_eq!(t.region_bw, 1.0);
        assert_eq!(t.spec_str, "racks=4:rack_bw=0.5");
        let t = parse_topology(
            "racks=8:regions=2:region_bw=0.1:rack_lat=1e-5:\
             region_lat=2e-3",
        )
        .unwrap();
        assert_eq!((t.racks, t.regions), (8, 2));
        assert_eq!(t.region_bw, 0.1);
        assert_eq!(t.rack_latency_s, 1e-5);
        assert_eq!(t.region_latency_s, 2e-3);
    }

    #[test]
    fn topology_parse_rejects_garbage() {
        assert!(parse_topology("racks").is_err());
        assert!(parse_topology("racks=x").is_err());
        assert!(parse_topology("racks=0").is_err());
        assert!(parse_topology("rack_bw=0").is_err());
        assert!(parse_topology("rack_bw=-1").is_err());
        assert!(parse_topology("racks=2:regions=4").is_err());
        assert!(parse_topology("turbo=9").is_err());
        assert!(parse_topology("racks=4:").is_err());
        let mut s = ClusterSpec::with_gpus(16);
        s.topology.racks = 0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn empty_topology_resets_to_flat() {
        let mut s = ClusterSpec::with_gpus(32);
        s.apply_topology("racks=4").unwrap();
        assert!(!s.topology.is_flat());
        s.apply_topology("").unwrap();
        assert_eq!(s, ClusterSpec::with_gpus(32));
    }

    #[test]
    fn rack_and_region_blocks_are_contiguous() {
        // 8 nodes, 4 racks, 2 regions: nodes pack 2 per rack, racks
        // 2 per region
        let mut s = ClusterSpec::with_gpus(64);
        s.apply_topology("racks=4:regions=2").unwrap();
        assert_eq!(s.n_nodes, 8);
        let racks: Vec<usize> =
            (0..8).map(|n| s.rack_of(n)).collect();
        assert_eq!(racks, vec![0, 0, 1, 1, 2, 2, 3, 3]);
        let regions: Vec<usize> =
            (0..8).map(|n| s.region_of(n)).collect();
        assert_eq!(regions, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        // flat topology: everything is rack 0 / region 0
        let s = ClusterSpec::with_gpus(64);
        assert!((0..8).all(|n| s.rack_of(n) == 0));
        assert!((0..8).all(|n| s.region_of(n) == 0));
        assert!(s.failure_domains().is_empty());
    }

    #[test]
    fn failure_domains_partition_the_fleet() {
        let mut s = ClusterSpec::with_gpus(64);
        s.apply_topology("racks=4").unwrap();
        let domains = s.failure_domains();
        assert_eq!(domains.len(), 4);
        let mut all: Vec<usize> = vec![];
        for (r, d) in domains.iter().enumerate() {
            assert_eq!(d.name, format!("rack{r}"));
            assert!(!d.nodes.is_empty());
            for &n in &d.nodes {
                assert_eq!(s.rack_of(n), r);
            }
            all.extend_from_slice(&d.nodes);
        }
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
        // more racks than nodes: trailing racks are simply empty
        let mut s = ClusterSpec::with_gpus(16);
        s.n_nodes = 3;
        s.gpus_per_node = 4;
        s.apply_topology("racks=4").unwrap();
        let domains = s.failure_domains();
        assert_eq!(domains.len(), 3);
        assert!(domains.iter().all(|d| d.nodes.len() == 1));
    }

    #[test]
    fn cross_rack_links_price_the_structural_tier() {
        let mut s = spec4x4();
        s.apply_topology("racks=2:rack_bw=0.5:rack_lat=1e-4")
            .unwrap();
        let a = GpuId { node: 0, idx: 0 };
        let b = GpuId { node: 1, idx: 0 }; // same rack
        let c = GpuId { node: 2, idx: 0 }; // other rack
        // same-rack inter-node links keep the base rate bit-for-bit
        assert_eq!(s.bandwidth(a, b), s.ib_bw);
        assert_eq!(s.bandwidth(a, c), s.ib_bw * 0.5);
        // intra-node untouched
        assert_eq!(
            s.bandwidth(a, GpuId { node: 0, idx: 1 }),
            s.nvlink_bw
        );
        // collectives inherit the scaled bottleneck and the rack hop
        // latency
        assert!(
            s.allreduce_time(&[a, c], 1e8)
                > s.allreduce_time(&[a, b], 1e8)
        );
        assert!(s.p2p_time(a, c, 1e8) > s.p2p_time(a, b, 1e8));
        // regions beat racks
        let mut s2 = spec4x4();
        s2.apply_topology(
            "racks=4:regions=2:rack_bw=0.5:region_bw=0.1",
        )
        .unwrap();
        let d = GpuId { node: 3, idx: 0 }; // other region
        assert_eq!(s2.bandwidth(a, b), s2.ib_bw * 0.5);
        assert_eq!(s2.bandwidth(a, d), s2.ib_bw * 0.1);
    }

    #[test]
    fn flat_topology_pricing_is_bit_identical() {
        // the topology hooks early-return on flat trees: every priced
        // quantity must be bit-equal to an untouched spec's
        let flat = spec4x4();
        let mut labeled = spec4x4();
        labeled.apply_topology("").unwrap();
        assert_eq!(flat, labeled);
        let gpus: Vec<GpuId> = (0..4)
            .flat_map(|node| {
                (0..2).map(move |idx| GpuId { node, idx })
            })
            .collect();
        for &a in &gpus {
            for &b in &gpus {
                assert_eq!(
                    flat.bandwidth(a, b).to_bits(),
                    labeled.bandwidth(a, b).to_bits()
                );
                assert_eq!(
                    flat.p2p_time(a, b, 1e8).to_bits(),
                    labeled.p2p_time(a, b, 1e8).to_bits()
                );
            }
        }
        assert_eq!(
            flat.allreduce_time(&gpus, 1e8).to_bits(),
            labeled.allreduce_time(&gpus, 1e8).to_bits()
        );
    }

    #[test]
    fn scored_path_matches_flat_path_on_uniform_flat_cluster() {
        // the differential the byte-freedom contract rests on: with
        // one reference tier in one rack, the scored planner must
        // reproduce the count-based allocation order bit-exactly,
        // through arbitrary churn
        let spec = {
            let mut s = ClusterSpec::with_gpus(32);
            s.n_nodes = 8;
            s.gpus_per_node = 4;
            s
        };
        for seed in 0..16u64 {
            let mut rng = Rng::new(seed ^ 0x70_70);
            let mut a = Allocator::new(spec.clone());
            let mut b = Allocator::new(spec.clone());
            let mut live: Vec<Allocation> = vec![];
            for _ in 0..200 {
                match rng.below(5) {
                    0 | 1 | 2 => {
                        let n = rng.range(1, 12);
                        if n == 0 || a.available_gpus() < n {
                            continue;
                        }
                        let x = a.allocate_flat(n);
                        let y = b.allocate_scored(n);
                        assert_eq!(x, y, "seed {seed}");
                        live.push(x);
                    }
                    3 => {
                        if !live.is_empty() {
                            let i = rng.below(live.len());
                            let x = live.swap_remove(i);
                            a.release(&x);
                            b.release(&x);
                        }
                    }
                    _ => {
                        let node = rng.below(8);
                        let down = rng.bool(0.5);
                        a.set_down(node, down);
                        b.set_down(node, down);
                    }
                }
            }
        }
    }

    #[test]
    fn scored_path_skips_holed_node_for_equally_tight_clean_node() {
        // hole-aware placement, pinned: node 0 carries a failed
        // device (3 free, 1 hole), node 1 is merely occupied (3
        // free, clean). Both offer slack 0 for a 3-GPU gang; the old
        // order (compute_mult tie, first index) took node 0 — packing
        // the fresh gang right next to the hole. The hole tiebreak
        // skips it for the equally tight clean node.
        let mut a = Allocator::new(spec4x4());
        a.set_gpu_down(0, 0, true);
        assert_eq!(a.holed_gpus(0), 1);
        assert_eq!(a.free_on(0), 3);
        // occupy one GPU on node 1 (avoid mask steers the ask there)
        let occ = a
            .allocate_avoiding(1, &[true, false, true, true])
            .unwrap();
        assert_eq!(occ.nodes(), vec![1]);
        let gang = a.allocate_scored(3);
        assert_eq!(gang.nodes(), vec![1], "holed node not skipped");
        // ...but cleanliness is only a tiebreak: a strictly tighter
        // fit on the holed node still wins over looser clean nodes
        let mut b = Allocator::new(spec4x4());
        b.set_gpu_down(0, 0, true);
        let tight = b.allocate_scored(3);
        assert_eq!(tight.nodes(), vec![0], "slack must rank first");
    }

    #[test]
    fn mixed_fleet_gang_lands_on_a_single_tier() {
        // the tier-blind packing bug, pinned: nodes 0-2 are h100,
        // node 3 is v100 (4 GPUs each); nodes 1 and 2 half-occupied.
        // A gang of 8 cannot fit in one node, and the count-based
        // spill (most-free-first: node 0 then node 3) split it across
        // the h100/v100 boundary — gang-synchronous pacing then taxes
        // every step at the slow generation. The scored path sees the
        // h100 tier still holds 8 free GPUs and keeps the gang pure.
        let mut spec = ClusterSpec::with_gpus(16);
        spec.n_nodes = 4;
        spec.gpus_per_node = 4;
        spec.apply_hardware_mix("h100*3:v100").unwrap();
        let mut a = Allocator::new(spec.clone());
        // occupy 2 GPUs each on nodes 1 and 2 (steered via the avoid
        // mask: the flagged nodes are treated as down for the ask)
        let x1 = a
            .allocate_avoiding(2, &[true, false, true, true])
            .unwrap();
        assert_eq!(x1.nodes(), vec![1]);
        let x2 = a
            .allocate_avoiding(2, &[true, true, false, true])
            .unwrap();
        assert_eq!(x2.nodes(), vec![2]);
        // the old count-based order would have taken nodes 0 + 3
        // (both 4 free) — asserted on a flat-path replay so the claim
        // stays pinned to real code, not a comment
        let mut blind = a.clone();
        let split = blind.allocate_flat(8);
        assert_eq!(split.nodes(), vec![0, 3]);
        let tiers: std::collections::HashSet<&str> = split
            .nodes()
            .iter()
            .map(|&n| spec.tier_of(n).name.as_str())
            .collect();
        assert_eq!(tiers.len(), 2, "old path split the tiers");
        // the fixed placer keeps the gang on the h100 tier
        let gang = a.allocate(8).unwrap();
        assert_eq!(gang.n_gpus(), 8);
        assert_eq!(gang.nodes(), vec![0, 1, 2]);
        assert!(gang
            .nodes()
            .iter()
            .all(|&n| spec.tier_of(n).name == "h100"));
        // and the pure gang is strictly faster under gang-synchronous
        // tier pacing: the slowest member's compute multiplier paces
        // the gang (the planner-level step-time comparison is pinned
        // in planner::tests)
        let slowest = |al: &Allocation| -> f64 {
            al.nodes()
                .iter()
                .map(|&n| spec.compute_mult(n))
                .fold(f64::INFINITY, f64::min)
        };
        assert!(slowest(&gang) > slowest(&split));
    }

    #[test]
    fn scored_spill_prefers_fewest_racks() {
        // uniform hardware, 4 racks of 2 nodes, occupancy tuned so
        // free counts deceive: nodes 2 and 4 hold the most free GPUs
        // but sit in different racks, while rack 0 exactly fits the
        // gang. Count-based most-free-first spans two racks; the
        // scored path keeps the gang on one switch.
        let mut spec = ClusterSpec::with_gpus(32);
        spec.n_nodes = 8;
        spec.gpus_per_node = 4;
        spec.apply_topology("racks=4:rack_bw=0.5").unwrap();
        let mut a = Allocator::new(spec.clone());
        // free per node after steered pre-occupation:
        //   rack0: n0=3 n1=3   rack1: n2=4 n3=1
        //   rack2: n4=4 n5=1   rack3: n6=1 n7=1
        for (node, take) in
            [(0usize, 1usize), (1, 1), (3, 3), (5, 3), (6, 3), (7, 3)]
        {
            let avoid: Vec<bool> =
                (0..8).map(|i| i != node).collect();
            let x = a.allocate_avoiding(take, &avoid).unwrap();
            assert_eq!(x.nodes(), vec![node]);
        }
        // the count-based order takes n2 + n4 — two racks (replayed
        // on the flat path so the claim stays pinned to real code)
        let mut blind = a.clone();
        let split = blind.allocate_flat(6);
        assert_eq!(split.nodes(), vec![2, 4]);
        assert_eq!(
            blind.spec().rack_of(2) == blind.spec().rack_of(4),
            false
        );
        // the scored path lands the gang in rack 0 (tightest rack
        // that fits: 6 free, slack 0)
        let gang = a.allocate(6).unwrap();
        assert_eq!(gang.nodes(), vec![0, 1]);
        assert_eq!(
            gang.nodes()
                .iter()
                .map(|&n| spec.rack_of(n))
                .collect::<std::collections::HashSet<_>>()
                .len(),
            1
        );
        // and asks larger than any rack still spill across rack
        // boundaries rather than starving
        a.release(&gang);
        let big = a.allocate(12).unwrap();
        assert_eq!(big.n_gpus(), 12);
    }

    #[test]
    fn allocator_churn_upholds_checked_invariants() {
        // satellite prop test: interleave set_down / degrade /
        // allocate_avoiding / release churn across seeds on a mixed
        // topologized fleet; the checked pops inside allocate must
        // never fire and capacity accounting must stay conserved
        let mut spec = ClusterSpec::with_gpus(32);
        spec.n_nodes = 8;
        spec.gpus_per_node = 4;
        spec.apply_hardware_mix("a100*2:v100*2").unwrap();
        spec.apply_topology("racks=2:rack_bw=0.5").unwrap();
        for seed in 0..16u64 {
            let mut rng = Rng::new(seed ^ 0xC4_42);
            let mut a = Allocator::new(spec.clone());
            let mut live: Vec<Allocation> = vec![];
            for _ in 0..300 {
                match rng.below(8) {
                    0 | 1 | 2 => {
                        let n = rng.range(1, 10);
                        let avoid: Vec<bool> =
                            (0..8).map(|_| rng.bool(0.3)).collect();
                        let before = a.available_gpus();
                        match a.allocate_avoiding(n, &avoid) {
                            Some(x) => {
                                assert_eq!(x.n_gpus(), n);
                                assert!(x
                                    .gpus
                                    .iter()
                                    .all(|g| !a.is_down(g.node)));
                                assert!(
                                    x.gpus.iter().all(|g| !a
                                        .gpu_is_down(g.node, g.idx)),
                                    "holed GPU handed out (seed \
                                     {seed})"
                                );
                                live.push(x);
                            }
                            None => {
                                assert!(
                                    before < n,
                                    "refused {n} with {before} \
                                     available (seed {seed})"
                                );
                            }
                        }
                    }
                    3 | 4 => {
                        if !live.is_empty() {
                            let i = rng.below(live.len());
                            let x = live.swap_remove(i);
                            a.release(&x);
                        }
                    }
                    5 => {
                        let node = rng.below(8);
                        a.set_down(node, rng.bool(0.5));
                    }
                    6 => {
                        // single-GPU hole churn: fail/heal any slot,
                        // free or allocated — strand-but-account must
                        // keep conservation exact either way
                        let node = rng.below(8);
                        let idx = rng.below(4);
                        a.set_gpu_down(node, idx, rng.bool(0.5));
                    }
                    _ => {
                        let node = rng.below(8);
                        a.set_speed(
                            node,
                            rng.range_f64(0.1, 1.0),
                        );
                    }
                }
                // conservation: free + live == capacity (holed GPUs
                // count as free-but-stranded, never lost)
                let held: usize =
                    live.iter().map(|x| x.n_gpus()).sum();
                assert_eq!(a.free_gpus() + held, 32);
            }
        }
    }

    #[test]
    fn allocate_random_skips_down_nodes() {
        let mut a = Allocator::new(spec4x4());
        a.set_down(2, true);
        let mut rng = crate::util::rng::Rng::new(9);
        let alloc = a.allocate_random(10, &mut rng).unwrap();
        assert_eq!(alloc.n_gpus(), 10);
        assert!(alloc.gpus.iter().all(|g| g.node != 2));
        assert!(a.allocate_random(3, &mut rng).is_none());
    }

    #[test]
    fn gpu_hole_excluded_from_allocation_but_accounted() {
        let mut a = Allocator::new(spec4x4());
        a.set_gpu_down(0, 1, true);
        assert!(a.gpu_is_down(0, 1));
        assert_eq!(a.holed_gpus(0), 1);
        // strand-but-account: still counted free, not allocatable
        assert_eq!(a.free_gpus(), 16);
        assert_eq!(a.available_gpus(), 15);
        // a 4-GPU ask can no longer land on the holed node
        let x = a.allocate(4).unwrap();
        assert!(!x.spans_nodes());
        assert_ne!(x.gpus[0].node, 0);
        // the node's survivors remain allocatable
        let y = a.allocate(3).unwrap();
        assert_eq!(y.nodes(), vec![0]);
        assert!(y.gpus.iter().all(|g| g.idx != 1));
        // healing restores the slot
        a.set_gpu_down(0, 1, false);
        assert_eq!(a.holed_gpus(0), 0);
        assert_eq!(a.available_gpus(), 16 - 7);
        let z = a.allocate(1).unwrap();
        a.release(&x);
        a.release(&y);
        a.release(&z);
        assert_eq!(a.free_gpus(), 16);
        assert!(a.allocate(16).is_some());
    }

    #[test]
    fn release_onto_holed_gpu_strands_until_heal() {
        // fail a GPU *while allocated*: the mask is set immediately,
        // the strand happens at release, and the slot stays out of
        // the pool until healed
        let mut a = Allocator::new(spec4x4());
        let x = a.allocate(4).unwrap();
        let g = x.gpus[2];
        a.set_gpu_down(g.node, g.idx, true);
        assert_eq!(a.holed_gpus(g.node), 1);
        a.release(&x);
        assert_eq!(a.free_gpus(), 16); // accounted...
        assert_eq!(a.available_gpus(), 15); // ...but stranded
        let y = a.allocate(4).unwrap();
        assert!(y
            .gpus
            .iter()
            .all(|q| (q.node, q.idx) != (g.node, g.idx)));
        a.set_gpu_down(g.node, g.idx, false);
        a.release(&y);
        assert_eq!(a.available_gpus(), 16);
        // idempotence: double-fail / double-heal never double-moves
        a.set_gpu_down(0, 0, true);
        a.set_gpu_down(0, 0, true);
        a.set_gpu_down(0, 0, false);
        a.set_gpu_down(0, 0, false);
        assert_eq!(a.free_gpus(), 16);
        assert!(a.allocate(16).is_some());
    }

    #[test]
    fn node_recovery_with_live_hole_restores_surviving_gpus() {
        // the double-free regression: a gang releases onto a *down*
        // node that also has an individually-holed GPU; node recovery
        // must restore exactly per_node - holes allocatable GPUs and
        // never resurrect the holed slot into the free list
        let mut a = Allocator::new(spec4x4());
        let x = a.allocate(4).unwrap();
        let node = x.gpus[0].node;
        a.set_gpu_down(node, 2, true); // hole inside the gang
        a.set_down(node, true); // then the whole node fails
        a.release(&x); // eviction returns the gang
        assert_eq!(a.free_gpus(), 16);
        assert_eq!(a.available_gpus(), 12);
        a.set_down(node, false);
        // exactly per_node - holes come back
        assert_eq!(a.available_gpus(), 15);
        assert_eq!(a.holed_gpus(node), 1);
        let y = a.allocate(15).unwrap();
        assert!(y
            .gpus
            .iter()
            .all(|g| (g.node, g.idx) != (node, 2)));
        assert!(a.allocate(1).is_none());
        // heal: the full fleet is whole again, with no duplicate slot
        a.set_gpu_down(node, 2, false);
        let z = a.allocate(1).unwrap();
        assert_eq!((z.gpus[0].node, z.gpus[0].idx), (node, 2));
        a.release(&y);
        a.release(&z);
        assert_eq!(a.free_gpus(), 16);
        let all = a.allocate(16).unwrap();
        let mut slots: Vec<(usize, usize)> =
            all.gpus.iter().map(|g| (g.node, g.idx)).collect();
        slots.sort_unstable();
        slots.dedup();
        assert_eq!(slots.len(), 16, "duplicate slot after recovery");
    }

    #[test]
    fn hole_free_fleet_replays_pre_hole_allocation_order() {
        // the byte-freedom differential: with no holes ever set, the
        // allocator must reproduce the pre-hole count-based order
        // exactly. The expected sequences are the pre-PR algorithm by
        // construction: free lists init (0..per_node).rev() and pop
        // from the back, best-fit single node first, then spill
        // most-free-first.
        let mut a = Allocator::new(spec4x4());
        let ids = |al: &Allocation| -> Vec<(usize, usize)> {
            al.gpus.iter().map(|g| (g.node, g.idx)).collect()
        };
        let x = a.allocate(2).unwrap();
        assert_eq!(ids(&x), vec![(0, 0), (0, 1)]);
        let y = a.allocate(4).unwrap();
        assert_eq!(
            ids(&y),
            vec![(1, 0), (1, 1), (1, 2), (1, 3)]
        );
        a.release(&x); // free[0] is now [3,2,0,1]
        let z = a.allocate(6).unwrap();
        assert_eq!(
            ids(&z),
            vec![(0, 1), (0, 0), (0, 2), (0, 3), (2, 0), (2, 1)]
        );
    }
}
