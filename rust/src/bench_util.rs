//! Benchmark harness (criterion substitute) used by the `cargo bench`
//! targets: warmup, repeated timed runs, mean/std/min reporting, and
//! throughput rows. Deterministic workloads + wall-clock timing.

use std::time::Instant;

/// Timing result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10.3} ms/iter (±{:.3} ms, min {:.3} ms, n={})",
            self.name,
            self.mean_s * 1e3,
            self.std_s * 1e3,
            self.min_s * 1e3,
            self.iters
        )
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn bench<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> T,
) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples
        .iter()
        .map(|s| (s - mean).powi(2))
        .sum::<f64>()
        / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: min,
    }
}

/// Time a single long-running invocation (trace-driven sims).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Standard bench header so `cargo bench` output is navigable.
pub fn section(title: &str) {
    println!("\n########## {title} ##########");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s);
        assert_eq!(r.iters, 5);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn time_once_returns_value() {
        let (v, t) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }
}
