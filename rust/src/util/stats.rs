//! Descriptive statistics: summaries, percentiles, CDFs, histograms.
//!
//! Shared by the simulator's metric collection and the bench harness.

use super::f64_cmp;

/// Summary statistics of a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
            };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| f64_cmp(*a, *b));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| f64_cmp(*a, *b));
    percentile_sorted(&sorted, q)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Mean and 95% confidence half-width of a sample (normal
/// approximation, `1.96 * s / sqrt(n)` with the sample standard
/// deviation). Half-width is 0 for fewer than two observations. The
/// sweep engine reports every aggregated metric as `mean ± ci95`.
///
/// Implemented as a [`Welford`] fold so the legacy collect-then-
/// aggregate report path and the streaming online-accumulator path
/// produce bit-identical results for the same observations in the
/// same order.
pub fn mean_ci95(xs: &[f64]) -> (f64, f64) {
    let mut w = Welford::default();
    for &x in xs {
        w.add(x);
    }
    w.mean_ci95()
}

/// Empirical CDF sampled at `points` evenly-spaced quantiles —
/// the JCT-CDF figures (Figs. 5b, 11–13) plot these series.
#[derive(Debug, Clone)]
pub struct Cdf {
    /// (value, cumulative fraction) pairs, fraction in (0, 1].
    pub points: Vec<(f64, f64)>,
}

impl Cdf {
    pub fn of(xs: &[f64], points: usize) -> Cdf {
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| f64_cmp(*a, *b));
        if sorted.is_empty() || points == 0 {
            return Cdf { points: vec![] };
        }
        let mut out = Vec::with_capacity(points);
        for i in 1..=points {
            let q = i as f64 / points as f64;
            out.push((percentile_sorted(&sorted, q), q));
        }
        Cdf { points: out }
    }

    /// Fraction of samples <= v.
    pub fn at(&self, v: f64) -> f64 {
        let mut frac = 0.0;
        for (x, q) in &self.points {
            if *x <= v {
                frac = *q;
            } else {
                break;
            }
        }
        frac
    }
}

/// Fixed-bin histogram over [lo, hi).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<usize>,
    pub underflow: usize,
    pub overflow: usize,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Histogram {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            bins: vec![0; nbins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let idx = ((x - self.lo) / (self.hi - self.lo)
                * self.bins.len() as f64) as usize;
            let last = self.bins.len() - 1;
            self.bins[idx.min(last)] += 1;
        }
    }

    pub fn total(&self) -> usize {
        self.bins.iter().sum::<usize>() + self.underflow + self.overflow
    }
}

/// Online mean/variance (Welford) — used by hot loops that must not
/// allocate (DESIGN.md §Perf L3).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Unbiased (n-1) sample variance; 0 below two observations.
    pub fn sample_var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// 95% confidence half-width of the mean (normal approximation);
    /// 0 below two observations.
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            1.96 * (self.sample_var() / self.n as f64).sqrt()
        }
    }

    /// `(mean, ci95)` — the pair every sweep-report metric is made of.
    /// Bit-identical to the free [`mean_ci95`] over the same values in
    /// the same order (that function is this fold).
    pub fn mean_ci95(&self) -> (f64, f64) {
        (self.mean, self.ci95())
    }
}

/// Time-weighted average of a step function — GPU-utilization accounting:
/// `add(t, v)` records that the value became `v` at time `t`.
#[derive(Debug, Clone, Default)]
pub struct TimeWeighted {
    last_t: Option<f64>,
    last_v: f64,
    weighted_sum: f64,
    span: f64,
}

impl TimeWeighted {
    pub fn add(&mut self, t: f64, v: f64) {
        if let Some(lt) = self.last_t {
            let dt = (t - lt).max(0.0);
            self.weighted_sum += self.last_v * dt;
            self.span += dt;
        }
        self.last_t = Some(t);
        self.last_v = v;
    }

    /// Close the window at time `t` and return the average.
    pub fn finish(&mut self, t: f64) -> f64 {
        self.add(t, self.last_v);
        if self.span > 0.0 {
            self.weighted_sum / self.span
        } else {
            0.0
        }
    }

    pub fn average(&self) -> f64 {
        if self.span > 0.0 {
            self.weighted_sum / self.span
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    fn mean_ci95_matches_hand_computation() {
        // s = 1 for [1,2,3] sample-std; ci = 1.96 * 1/sqrt(3)
        let (m, ci) = mean_ci95(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((ci - 1.96 / 3.0f64.sqrt()).abs() < 1e-9, "{ci}");
        // degenerate cases collapse to zero width
        assert_eq!(mean_ci95(&[5.0]), (5.0, 0.0));
        assert_eq!(mean_ci95(&[]), (0.0, 0.0));
        // identical observations: zero width
        let (_, ci0) = mean_ci95(&[4.0, 4.0, 4.0, 4.0]);
        assert!(ci0.abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone_and_bounds() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let cdf = Cdf::of(&xs, 20);
        assert_eq!(cdf.points.len(), 20);
        for w in cdf.points.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 > w[0].1);
        }
        assert!((cdf.points.last().unwrap().1 - 1.0).abs() < 1e-12);
        assert!(cdf.at(-1.0) == 0.0);
        assert!((cdf.at(1e9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(11.0);
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.add(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std() - s.std).abs() < 1e-12);
    }

    #[test]
    fn welford_ci95_is_bitwise_the_batch_fold() {
        // The streaming report's online accumulators must reproduce
        // the legacy collect-then-aggregate bytes exactly; that holds
        // because mean_ci95 *is* a Welford fold — pin the identity.
        let xs = [0.125, 3.5, -2.75, 9.0, 9.0, 0.0625, 1e-9, 4.2];
        for k in 0..=xs.len() {
            let mut w = Welford::default();
            for &x in &xs[..k] {
                w.add(x);
            }
            let (bm, bc) = mean_ci95(&xs[..k]);
            let (wm, wc) = w.mean_ci95();
            assert_eq!(bm.to_bits(), wm.to_bits(), "mean k={k}");
            assert_eq!(bc.to_bits(), wc.to_bits(), "ci k={k}");
        }
    }

    #[test]
    fn time_weighted_step_function() {
        let mut tw = TimeWeighted::default();
        tw.add(0.0, 1.0); // value 1 on [0, 10)
        tw.add(10.0, 0.0); // value 0 on [10, 20)
        let avg = tw.finish(20.0);
        assert!((avg - 0.5).abs() < 1e-12, "{avg}");
    }

    #[test]
    fn time_weighted_empty() {
        let mut tw = TimeWeighted::default();
        assert_eq!(tw.finish(5.0), 0.0);
    }
}
