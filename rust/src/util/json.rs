//! Minimal JSON: parser, printer, typed accessors — plus a streaming
//! layer that never builds a tree.
//!
//! Replaces serde_json (unavailable in the offline vendor set). Supports
//! the full JSON grammar the project uses: objects, arrays, strings with
//! escapes, numbers (f64 + exact i64 round-trip), booleans, null.
//! `parse ∘ to_string == id` is property-tested in [`crate::util::prop`]'s
//! test suite and below.
//!
//! The streaming layer (DESIGN.md §Streaming reports):
//! - [`Lexer`] — a pull-based, allocation-free event lexer whose
//!   [`Event`]s borrow slices of the input; [`visit`] is the callback
//!   form.
//! - [`path_f64`] / [`path_str`] — lazy byte-scanning path reads that
//!   skip over everything off-path without materializing it.
//! - [`diff`] — a byte-range differ over two canonical streams,
//!   reporting the first divergent path + byte offsets; the golden and
//!   threads-1-vs-8 determinism tests use it instead of tree equality.
//!
//! Both the tree parser and the lexer enforce [`MAX_DEPTH`] so
//! adversarial depth-bomb inputs fail with a [`JsonError`] instead of
//! overflowing the stack.

use std::collections::BTreeMap;
use std::fmt;

/// Maximum container-nesting depth accepted by [`parse`] and
/// [`Lexer`]. Deeper input returns a [`JsonError`] instead of
/// recursing toward stack overflow. Generous: real reports nest ~5
/// levels.
pub const MAX_DEPTH: usize = 128;

/// A JSON value. Object keys are sorted (BTreeMap) so printing is
/// deterministic — required for artifact-manifest diffing.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Numbers that parse exactly as i64 are kept integral.
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl JsonError {
    /// 1-based (line, column) of `offset` within `text` — config
    /// diagnostics point at the line the user has to fix.
    pub fn line_col(&self, text: &str) -> (usize, usize) {
        let upto = &text.as_bytes()[..self.offset.min(text.len())];
        let line = upto.iter().filter(|&&b| b == b'\n').count() + 1;
        let col =
            upto.iter().rev().take_while(|&&b| b != b'\n').count() + 1;
        (line, col)
    }

    /// Prefix the message with `path: line L, column C` context.
    fn with_context(
        mut self,
        path: &std::path::Path,
        text: &str,
    ) -> JsonError {
        let (line, col) = self.line_col(text);
        self.msg = format!(
            "{}: line {line}, column {col}: {}",
            path.display(),
            self.msg
        );
        self
    }
}

/// Callers that accumulate errors as `String` (the CLI, the runtime
/// manifest loader) keep working with `?` on [`JsonError`] results.
impl From<JsonError> for String {
    fn from(e: JsonError) -> String {
        e.to_string()
    }
}

impl Json {
    // ---------------- constructors ----------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }

    // ---------------- typed accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `a.b.c` path access.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(x) => Some(*x),
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 9e15 => {
                Some(*x as i64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// usize vector from a JSON array of numbers.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|x| x.as_f64()).collect()
    }

    // ---------------- printing ----------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    /// Pretty-print starting at a given indent level, with no
    /// trailing newline — the streaming report writer splices per-row
    /// subtrees into a hand-emitted envelope and must reproduce
    /// [`Json::to_pretty`]'s bytes exactly.
    pub fn to_pretty_at(&self, indent: usize) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, indent);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Int(x) => out.push_str(&x.to_string()),
            Json::Num(x) => write_f64(*x, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = |n: usize, o: &mut String| {
            for _ in 0..n {
                o.push_str("  ");
            }
        };
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    pad(indent + 1, out);
                    v.write_pretty(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(indent, out);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    pad(indent + 1, out);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(indent, out);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_f64(x: f64, out: &mut String) {
    if x.is_finite() {
        // shortest round-trip repr rust gives us
        let s = format!("{x}");
        out.push_str(&s);
        if !s.contains('.') && !s.contains('e') && !s.contains('E') {
            out.push_str(".0");
        }
    } else {
        // JSON has no inf/nan; null is the conventional fallback
        out.push_str("null");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Int(x as i64)
    }
}

impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Int(x as i64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(v: &[T]) -> Json {
        Json::Arr(v.iter().cloned().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

/// Parse a JSON file. Both I/O and syntax failures surface as
/// [`JsonError`]; syntax errors carry `path: line L, column C`
/// context so config mistakes are actionable, and
/// `From<JsonError> for String` keeps string-error call sites on `?`.
pub fn parse_file(path: &std::path::Path) -> Result<Json, JsonError> {
    let text = std::fs::read_to_string(path).map_err(|e| JsonError {
        msg: format!("read {}: {e}", path.display()),
        offset: 0,
    })?;
    parse(&text).map_err(|e| e.with_context(path, &text))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.nested(Parser::object),
            Some(b'[') => self.nested(Parser::array),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    /// Depth guard around container recursion: the parser's stack
    /// usage is bounded by MAX_DEPTH frames, so a depth-bomb input
    /// errors out instead of overflowing.
    fn nested(
        &mut self,
        f: fn(&mut Parser<'a>) -> Result<Json, JsonError>,
    ) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err(&format!(
                "nesting exceeds depth limit ({MAX_DEPTH})"
            )));
        }
        self.depth += 1;
        let v = f(self)?;
        self.depth -= 1;
        Ok(v)
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(
                                || self.err("bad unicode escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one utf-8 char
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ---------------------------------------------------------------------------
// Streaming layer: pull lexer, callback visitor, lazy path reads, differ
// ---------------------------------------------------------------------------

/// One lexical event. String-ish payloads borrow the input *raw*
/// (escapes unprocessed — [`unescape`] decodes); numbers stay as the
/// unparsed text slice. The lexer therefore allocates nothing per
/// event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event<'a> {
    ObjStart,
    ObjEnd,
    ArrStart,
    ArrEnd,
    /// Object key (raw string body, quotes stripped).
    Key(&'a str),
    /// String value (raw body, quotes stripped).
    Str(&'a str),
    /// Number value, unparsed (`"1.5"`, `"-3e2"`, …).
    Num(&'a str),
    Bool(bool),
    Null,
}

/// Per-container lexer state: kind (`b'{'` / `b'['`), whether an
/// element has been emitted (comma handling), and — for objects —
/// whether a key has been consumed and a value is due next.
#[derive(Clone, Copy)]
struct LexFrame {
    kind: u8,
    has_elems: bool,
    awaiting_value: bool,
}

/// Pull-based JSON lexer. Validates the same grammar as [`parse`]
/// while allocating only its container stack (≤ [`MAX_DEPTH`]
/// frames); every event borrows from the input. Drives [`visit`],
/// [`path_f64`]/[`path_str`], and the lockstep byte-range [`diff`].
pub struct Lexer<'a> {
    bytes: &'a [u8],
    pos: usize,
    stack: Vec<LexFrame>,
    started: bool,
}

impl<'a> Lexer<'a> {
    pub fn new(input: &'a str) -> Lexer<'a> {
        Lexer {
            bytes: input.as_bytes(),
            pos: 0,
            stack: Vec::new(),
            started: false,
        }
    }

    /// Byte offset of the lexer cursor (just past the last event).
    pub fn offset(&self) -> usize {
        self.pos
    }

    /// Current container-nesting depth.
    pub fn depth(&self) -> usize {
        self.stack.len()
    }

    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r'))
        {
            self.pos += 1;
        }
    }

    /// Next event, or `None` at clean end-of-input.
    pub fn next_event(
        &mut self,
    ) -> Result<Option<Event<'a>>, JsonError> {
        self.skip_ws();
        let frame = match self.stack.last().copied() {
            Some(f) => f,
            None => {
                // top level: exactly one value, then clean EOF
                if self.started {
                    return if self.pos == self.bytes.len() {
                        Ok(None)
                    } else {
                        Err(self.err("trailing characters"))
                    };
                }
                self.started = true;
                return self.value_event().map(Some);
            }
        };
        if frame.kind == b'{' && !frame.awaiting_value {
            // expecting `}`, or (`,`) `"key":`
            match self.peek() {
                Some(b'}') => {
                    self.pos += 1;
                    self.stack.pop();
                    return Ok(Some(Event::ObjEnd));
                }
                None => return Err(self.err("unterminated object")),
                _ => {}
            }
            if frame.has_elems {
                if self.peek() != Some(b',') {
                    return Err(self.err("expected ',' or '}'"));
                }
                self.pos += 1;
                self.skip_ws();
            }
            let key = self.raw_string()?;
            self.skip_ws();
            if self.peek() != Some(b':') {
                return Err(self.err("expected ':'"));
            }
            self.pos += 1;
            let top = self.stack.last_mut().unwrap();
            top.has_elems = true;
            top.awaiting_value = true;
            return Ok(Some(Event::Key(key)));
        }
        if frame.kind == b'{' {
            // the value after a key
            self.stack.last_mut().unwrap().awaiting_value = false;
            return self.value_event().map(Some);
        }
        // array element, `]`, or `,`
        match self.peek() {
            Some(b']') => {
                self.pos += 1;
                self.stack.pop();
                return Ok(Some(Event::ArrEnd));
            }
            None => return Err(self.err("unterminated array")),
            _ => {}
        }
        if frame.has_elems {
            if self.peek() != Some(b',') {
                return Err(self.err("expected ',' or ']'"));
            }
            self.pos += 1;
            self.skip_ws();
        }
        self.stack.last_mut().unwrap().has_elems = true;
        self.value_event().map(Some)
    }

    fn value_event(&mut self) -> Result<Event<'a>, JsonError> {
        match self.peek() {
            Some(b'{') | Some(b'[') => {
                if self.stack.len() >= MAX_DEPTH {
                    return Err(self.err(&format!(
                        "nesting exceeds depth limit ({MAX_DEPTH})"
                    )));
                }
                let kind = self.peek().unwrap();
                self.pos += 1;
                self.stack.push(LexFrame {
                    kind,
                    has_elems: false,
                    awaiting_value: false,
                });
                Ok(if kind == b'{' {
                    Event::ObjStart
                } else {
                    Event::ArrStart
                })
            }
            Some(b'"') => Ok(Event::Str(self.raw_string()?)),
            Some(b't') => self.lit("true", Event::Bool(true)),
            Some(b'f') => self.lit("false", Event::Bool(false)),
            Some(b'n') => self.lit("null", Event::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                Ok(Event::Num(self.raw_number()?))
            }
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(
        &mut self,
        word: &str,
        ev: Event<'a>,
    ) -> Result<Event<'a>, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(ev)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    /// Scan a string, validating escapes but not decoding them;
    /// returns the raw body (quotes stripped). Never allocates: the
    /// slice borrows the input. Byte-wise scanning is safe because
    /// `"` and `\` cannot occur inside a UTF-8 continuation sequence.
    fn raw_string(&mut self) -> Result<&'a str, JsonError> {
        if self.peek() != Some(b'"') {
            return Err(self.err("expected string"));
        }
        self.pos += 1;
        let start = self.pos;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let raw = std::str::from_utf8(
                        &self.bytes[start..self.pos],
                    )
                    .map_err(|_| self.err("invalid utf-8"))?;
                    self.pos += 1;
                    return Ok(raw);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(
                            b'"' | b'\\' | b'/' | b'b' | b'f' | b'n'
                            | b'r' | b't',
                        ) => self.pos += 1,
                        Some(b'u') => {
                            self.pos += 1;
                            for _ in 0..4 {
                                match self.peek() {
                                    Some(c)
                                        if c.is_ascii_hexdigit() =>
                                    {
                                        self.pos += 1
                                    }
                                    _ => {
                                        return Err(
                                            self.err("bad hex digit")
                                        )
                                    }
                                }
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => self.pos += 1,
            }
        }
    }

    /// Scan a number token; acceptance matches the tree parser (which
    /// defers validity to `str::parse`).
    fn raw_number(&mut self) -> Result<&'a str, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit())
            {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit())
            {
                self.pos += 1;
            }
        }
        let text =
            std::str::from_utf8(&self.bytes[start..self.pos])
                .map_err(|_| self.err("invalid number"))?;
        if text.parse::<f64>().is_err() {
            return Err(self.err("invalid number"));
        }
        Ok(text)
    }
}

/// Callback form of the lexer: feed every event of `input` to `f`
/// without building a tree.
pub fn visit<'a>(
    input: &'a str,
    mut f: impl FnMut(&Event<'a>),
) -> Result<(), JsonError> {
    let mut lx = Lexer::new(input);
    while let Some(ev) = lx.next_event()? {
        f(&ev);
    }
    Ok(())
}

/// Decode a raw string body (as borrowed by [`Event::Key`] /
/// [`Event::Str`]) into its unescaped form — the inverse of the
/// writer's escaping. Delegates to the tree parser's escape logic so
/// the two layers cannot drift.
pub fn unescape(raw: &str) -> Result<String, JsonError> {
    let quoted = format!("\"{raw}\"");
    let mut p = Parser {
        bytes: quoted.as_bytes(),
        pos: 0,
        depth: 0,
    };
    let s = p.string()?;
    if p.pos != quoted.len() {
        return Err(JsonError {
            msg: "unescaped quote in raw string".into(),
            offset: p.pos,
        });
    }
    Ok(s)
}

fn eof_err(lx: &Lexer) -> JsonError {
    JsonError {
        msg: "unexpected end of input".into(),
        offset: lx.offset(),
    }
}

/// Consume the remainder of the value that `ev` opened (no-op for
/// scalars), leaving the lexer positioned after it.
fn skip_value(lx: &mut Lexer, ev: &Event) -> Result<(), JsonError> {
    match ev {
        Event::ObjStart | Event::ArrStart => {
            let target = lx.depth() - 1;
            while lx.depth() > target {
                lx.next_event()?.ok_or_else(|| eof_err(lx))?;
            }
            Ok(())
        }
        _ => Ok(()),
    }
}

/// Lazily scan `input` for the value at `path` (object keys; array
/// segments are decimal indices). Off-path subtrees are skipped
/// byte-wise — nothing is parsed into memory. Returns the opening
/// event of the value (`ObjStart`/`ArrStart` for containers), or
/// `None` if any segment is absent.
pub fn path_value<'a>(
    input: &'a str,
    path: &[&str],
) -> Result<Option<Event<'a>>, JsonError> {
    let mut lx = Lexer::new(input);
    let mut ev = match lx.next_event()? {
        Some(e) => e,
        None => return Ok(None),
    };
    for seg in path {
        match ev {
            Event::ObjStart => {
                let mut found = None;
                loop {
                    match lx.next_event()?.ok_or_else(|| eof_err(&lx))? {
                        Event::Key(k) => {
                            let hit = k == *seg
                                || unescape(k)
                                    .map(|u| u == *seg)
                                    .unwrap_or(false);
                            let v = lx
                                .next_event()?
                                .ok_or_else(|| eof_err(&lx))?;
                            if hit {
                                found = Some(v);
                                break;
                            }
                            skip_value(&mut lx, &v)?;
                        }
                        Event::ObjEnd => break,
                        _ => unreachable!("lexer yields keys in objects"),
                    }
                }
                match found {
                    Some(v) => ev = v,
                    None => return Ok(None),
                }
            }
            Event::ArrStart => {
                let idx: usize = match seg.parse() {
                    Ok(i) => i,
                    Err(_) => return Ok(None),
                };
                let mut i = 0usize;
                loop {
                    match lx.next_event()?.ok_or_else(|| eof_err(&lx))? {
                        Event::ArrEnd => return Ok(None),
                        v => {
                            if i == idx {
                                ev = v;
                                break;
                            }
                            skip_value(&mut lx, &v)?;
                            i += 1;
                        }
                    }
                }
            }
            _ => return Ok(None), // scalar mid-path
        }
    }
    Ok(Some(ev))
}

/// Lazy numeric read at `path` — never builds a tree. `None` when the
/// path is absent or not a number.
pub fn path_f64(
    input: &str,
    path: &[&str],
) -> Result<Option<f64>, JsonError> {
    match path_value(input, path)? {
        Some(Event::Num(s)) => Ok(s.parse().ok()),
        _ => Ok(None),
    }
}

/// Lazy string read at `path` — scans bytes, allocates only the
/// returned (unescaped) string. `None` when absent or not a string.
pub fn path_str(
    input: &str,
    path: &[&str],
) -> Result<Option<String>, JsonError> {
    match path_value(input, path)? {
        Some(Event::Str(s)) => Ok(Some(unescape(s)?)),
        _ => Ok(None),
    }
}

// ---------------------------------------------------------------------------
// Byte-range differ
// ---------------------------------------------------------------------------

/// First divergence between two JSON streams, located by lexing both
/// in lockstep — memory stays bounded by nesting depth no matter how
/// large the documents are.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonDiff {
    /// Dotted/indexed path to the diverging value, e.g.
    /// `$.points[3].label`.
    pub path: String,
    /// Byte offset just past the divergence in the left stream.
    pub offset_a: usize,
    /// Byte offset just past the divergence in the right stream.
    pub offset_b: usize,
    /// Human description of the two sides.
    pub detail: String,
}

impl fmt::Display for JsonDiff {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} (byte {} vs {}): {}",
            self.path, self.offset_a, self.offset_b, self.detail
        )
    }
}

enum DiffFrame {
    Obj(Option<String>),
    Arr(usize),
}

fn render_path(frames: &[DiffFrame]) -> String {
    let mut s = String::from("$");
    for f in frames {
        match f {
            DiffFrame::Obj(Some(k)) => {
                s.push('.');
                s.push_str(k);
            }
            DiffFrame::Obj(None) => s.push_str(".{}"),
            DiffFrame::Arr(i) => s.push_str(&format!("[{i}]")),
        }
    }
    s
}

/// Compare two canonical JSON streams lazily, token-by-token.
/// `None` means lexically identical (for canonical output that is
/// byte-identity up to insignificant whitespace — our writers pin
/// whitespace too, so callers typically pre-check `a == b` and use
/// this to *localize* the divergence). The first mismatching token,
/// structural difference, or lex error is reported with the JSON path
/// and both byte offsets.
pub fn diff(a: &str, b: &str) -> Option<JsonDiff> {
    let mut la = Lexer::new(a);
    let mut lb = Lexer::new(b);
    let mut frames: Vec<DiffFrame> = Vec::new();
    loop {
        let ea = la.next_event();
        let eb = lb.next_event();
        let at = |detail: String, frames: &[DiffFrame]| {
            Some(JsonDiff {
                path: render_path(frames),
                offset_a: la.offset(),
                offset_b: lb.offset(),
                detail,
            })
        };
        let (ea, eb) = match (ea, eb) {
            (Err(e), _) => {
                return at(
                    format!("left stream invalid: {}", e.msg),
                    &frames,
                )
            }
            (_, Err(e)) => {
                return at(
                    format!("right stream invalid: {}", e.msg),
                    &frames,
                )
            }
            (Ok(None), Ok(None)) => return None,
            (Ok(Some(_)), Ok(None)) => {
                return at("left has extra trailing data".into(), &frames)
            }
            (Ok(None), Ok(Some(_))) => {
                return at(
                    "right has extra trailing data".into(),
                    &frames,
                )
            }
            (Ok(Some(x)), Ok(Some(y))) => (x, y),
        };
        if ea != eb {
            return at(format!("{ea:?} != {eb:?}"), &frames);
        }
        // streams agree on this event — thread it through the path
        match ea {
            Event::ObjStart => frames.push(DiffFrame::Obj(None)),
            Event::ArrStart => frames.push(DiffFrame::Arr(0)),
            Event::ObjEnd | Event::ArrEnd => {
                frames.pop();
                if let Some(DiffFrame::Arr(i)) = frames.last_mut() {
                    *i += 1;
                }
            }
            Event::Key(k) => {
                if let Some(DiffFrame::Obj(slot)) = frames.last_mut() {
                    *slot = Some(
                        unescape(k).unwrap_or_else(|_| k.to_string()),
                    );
                }
            }
            _ => {
                if let Some(DiffFrame::Arr(i)) = frames.last_mut() {
                    *i += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Int(42));
        assert_eq!(parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse("1.5").unwrap(), Json::Num(1.5));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.path("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escape() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrip_examples() {
        for text in [
            "null",
            "[1,2,3]",
            r#"{"a":1,"b":[true,false,null],"c":{"d":"e"}}"#,
            r#"{"x":1.25,"y":-3,"z":"\" \\ \n"}"#,
        ] {
            let v = parse(text).unwrap();
            let v2 = parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn pretty_parses_back() {
        let v = parse(r#"{"a":[1,{"b":2}],"c":[]}"#).unwrap();
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"n": 3, "f": 2.5, "s": "x", "b": true,
                          "arr": [1,2], "o": {"k": 9}}"#)
            .unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("f").unwrap().as_f64().unwrap(), 2.5);
        assert_eq!(v.get("n").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(v.path("o.k").unwrap().as_i64().unwrap(), 9);
        assert_eq!(v.get("arr").unwrap().as_usize_vec().unwrap(), vec![1, 2]);
        assert!(v.get("missing").is_none());
        assert!(v.path("o.missing").is_none());
    }

    #[test]
    fn builder() {
        let v = Json::obj().set("a", 1i64).set("b", "x").set(
            "c",
            Json::Arr(vec![Json::Int(1), Json::Bool(false)]),
        );
        assert_eq!(v.to_string(), r#"{"a":1,"b":"x","c":[1,false]}"#);
    }

    #[test]
    fn float_roundtrip_keeps_value() {
        for x in [0.1, 1e-9, 123456.789, -2.5e10] {
            let v = parse(&Json::Num(x).to_string()).unwrap();
            assert_eq!(v.as_f64().unwrap(), x);
        }
    }

    // ---------------- depth limit ----------------

    #[test]
    fn depth_bomb_rejected_not_overflowed() {
        // regression: 10k-deep input used to overflow the parser's
        // recursion; now both layers error at MAX_DEPTH
        let bomb = "[".repeat(10_000) + &"]".repeat(10_000);
        let err = parse(&bomb).unwrap_err();
        assert!(err.msg.contains("depth limit"), "{}", err.msg);
        let obomb = "{\"k\":".repeat(10_000) + "0"
            + &"}".repeat(10_000);
        assert!(parse(&obomb)
            .unwrap_err()
            .msg
            .contains("depth limit"));
        // the lexer enforces the same bound
        let mut lx = Lexer::new(&bomb);
        let res = loop {
            match lx.next_event() {
                Ok(Some(_)) => continue,
                other => break other,
            }
        };
        assert!(res.unwrap_err().msg.contains("depth limit"));
    }

    #[test]
    fn deep_but_legal_nesting_parses() {
        let n = MAX_DEPTH - 1;
        let ok = "[".repeat(n) + "7" + &"]".repeat(n);
        assert!(parse(&ok).is_ok());
        let mut events = 0usize;
        visit(&ok, |_| events += 1).unwrap();
        assert_eq!(events, 2 * n + 1);
    }

    // ---------------- parse_file / line:column ----------------

    #[test]
    fn line_col_maps_offsets() {
        let text = "{\n  \"a\": 1,\n  \"b\": nope\n}";
        let err = parse(text).unwrap_err();
        let (line, col) = err.line_col(text);
        assert_eq!(line, 3);
        assert!(col >= 8, "column {col}");
    }

    #[test]
    fn parse_file_errors_are_json_errors_with_context() {
        let dir = std::env::temp_dir();
        let path = dir.join("tlora_json_parse_file_test.json");
        std::fs::write(&path, "{\n  \"a\": [1, 2,]\n}").unwrap();
        let err = parse_file(&path).unwrap_err();
        assert!(err.msg.contains("line 2"), "{}", err.msg);
        assert!(
            err.msg.contains("tlora_json_parse_file_test.json"),
            "{}",
            err.msg
        );
        // the String conversion used by `?` call sites keeps context
        let s: String = err.into();
        assert!(s.contains("line 2"), "{s}");
        let _ = std::fs::remove_file(&path);
        let missing = parse_file(&dir.join("tlora_definitely_absent"));
        assert!(missing.unwrap_err().msg.starts_with("read "));
    }

    // ---------------- lexer ----------------

    #[test]
    fn lexer_event_sequence() {
        let text = r#"{"a": [1, "x\n", true], "b": null}"#;
        let mut got = Vec::new();
        visit(text, |ev| got.push(format!("{ev:?}"))).unwrap();
        assert_eq!(
            got,
            vec![
                "ObjStart",
                "Key(\"a\")",
                "ArrStart",
                "Num(\"1\")",
                "Str(\"x\\\\n\")", // raw body: escapes undecoded
                "Bool(true)",
                "ArrEnd",
                "Key(\"b\")",
                "Null",
                "ObjEnd",
            ]
        );
    }

    #[test]
    fn lexer_rejects_what_parser_rejects() {
        for bad in
            ["{", "[1,]", "1 2", "{\"a\" 1}", "nul", "[1 2]", "{,}"]
        {
            let mut lx = Lexer::new(bad);
            let res = loop {
                match lx.next_event() {
                    Ok(Some(_)) => continue,
                    other => break other,
                }
            };
            assert!(res.is_err(), "lexer accepted {bad:?}");
            assert!(parse(bad).is_err(), "parser accepted {bad:?}");
        }
    }

    #[test]
    fn unescape_decodes_raw_bodies() {
        assert_eq!(unescape("x\\ny").unwrap(), "x\ny");
        assert_eq!(unescape("\\u00e9").unwrap(), "é");
        assert_eq!(unescape("plain").unwrap(), "plain");
        assert!(unescape("broken\\").is_err());
    }

    // ---------------- lazy path reads ----------------

    #[test]
    fn path_reads_scan_without_parsing() {
        let text = r#"{"cells": [{"key": "a", "v": [1.5, 0.25]},
                                  {"key": "b", "v": [2.5, 0.5]}],
                       "n_points": 4, "label": "run \"x\""}"#;
        assert_eq!(
            path_f64(text, &["n_points"]).unwrap(),
            Some(4.0)
        );
        assert_eq!(
            path_f64(text, &["cells", "1", "v", "0"]).unwrap(),
            Some(2.5)
        );
        assert_eq!(
            path_str(text, &["cells", "0", "key"]).unwrap(),
            Some("a".into())
        );
        assert_eq!(
            path_str(text, &["label"]).unwrap(),
            Some("run \"x\"".into())
        );
        // absent / type-mismatched paths are None, not errors
        assert_eq!(path_f64(text, &["absent"]).unwrap(), None);
        assert_eq!(path_f64(text, &["cells", "9"]).unwrap(), None);
        assert_eq!(path_str(text, &["n_points"]).unwrap(), None);
        assert_eq!(
            path_f64(text, &["label", "deeper"]).unwrap(),
            None
        );
        // malformed input is an error even off-path
        assert!(path_f64("{\"a\": [1,]}", &["b"]).is_err());
    }

    // ---------------- differ ----------------

    #[test]
    fn diff_identical_is_none() {
        let v = Json::obj()
            .set("a", 1i64)
            .set("b", Json::Arr(vec![Json::Num(1.5), Json::Null]));
        assert_eq!(diff(&v.to_pretty(), &v.to_pretty()), None);
        // insignificant whitespace is invisible to the differ
        assert_eq!(diff("[1, 2]", "[1,2]"), None);
    }

    #[test]
    fn diff_localizes_first_divergence() {
        let a = r#"{"points": [{"x": 1}, {"x": 2}]}"#;
        let b = r#"{"points": [{"x": 1}, {"x": 3}]}"#;
        let d = diff(a, b).unwrap();
        assert_eq!(d.path, "$.points[1].x");
        assert!(d.detail.contains('2') && d.detail.contains('3'));
        assert!(d.offset_a > 0 && d.offset_b > 0);
        let shown = d.to_string();
        assert!(shown.contains("$.points[1].x"), "{shown}");
    }

    #[test]
    fn diff_reports_structural_and_length_mismatches() {
        let d = diff(r#"{"a": 1}"#, r#"{"a": [1]}"#).unwrap();
        assert_eq!(d.path, "$.a");
        let d = diff("[1, 2]", "[1, 2, 3]").unwrap();
        assert_eq!(d.path, "$[2]");
        let d = diff(r#"{"a": 1}"#, r#"{"b": 1}"#).unwrap();
        assert_eq!(d.path, "$.{}");
        assert!(diff("[]", "[]").is_none());
        let d = diff("[]", "[] 1").unwrap();
        assert!(d.detail.contains("invalid"), "{}", d.detail);
    }

    // ---------------- property tests ----------------

    fn rand_json(r: &mut crate::util::rng::Rng, depth: usize) -> Json {
        let pick = if depth == 0 {
            r.range(0, 4) // scalars only at the leaves
        } else {
            r.range(0, 6)
        };
        match pick {
            0 => Json::Null,
            1 => Json::Bool(r.bool(0.5)),
            // bounded so f64 round-trips exactly and stays Int-typed
            2 => Json::Int(
                r.range(0, 1 << 50) as i64
                    - if r.bool(0.5) { 1 << 49 } else { 0 },
            ),
            3 => Json::Num(r.range_f64(-1e6, 1e6)),
            4 => {
                let n = r.range(0, 8);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            *r.choice(&[
                                'a', 'é', '"', '\\', '\n', '\t',
                                '😀', ' ',
                            ])
                        })
                        .collect(),
                )
            }
            _ => {
                let n = r.range(0, 4);
                if r.bool(0.5) {
                    Json::Arr(
                        (0..n)
                            .map(|_| rand_json(r, depth - 1))
                            .collect(),
                    )
                } else {
                    let mut m = BTreeMap::new();
                    for i in 0..n {
                        m.insert(
                            format!("k{i}"),
                            rand_json(r, depth - 1),
                        );
                    }
                    Json::Obj(m)
                }
            }
        }
    }

    #[test]
    fn prop_parse_write_roundtrips() {
        let gen = crate::util::prop::Gen::new(
            |r| rand_json(r, 3),
            |_| vec![],
        );
        crate::util::prop::prop_check(200, &gen, |v| {
            parse(&v.to_string()).ok().as_ref() == Some(v)
                && parse(&v.to_pretty()).ok().as_ref() == Some(v)
        });
    }

    /// Replay a lexer stream back into a tree so the two layers can be
    /// compared semantically (strings unescaped, numbers parsed).
    fn tree_from_events(lx: &mut Lexer) -> Result<Json, JsonError> {
        let ev = lx.next_event()?.expect("value expected");
        tree_from(lx, ev)
    }

    fn tree_from(
        lx: &mut Lexer,
        ev: Event,
    ) -> Result<Json, JsonError> {
        Ok(match ev {
            Event::Null => Json::Null,
            Event::Bool(b) => Json::Bool(b),
            Event::Num(s) => {
                // same int-vs-float decision as the tree parser
                if !s.contains(&['.', 'e', 'E'][..]) {
                    if let Ok(i) = s.parse::<i64>() {
                        return Ok(Json::Int(i));
                    }
                }
                Json::Num(s.parse().unwrap())
            }
            Event::Str(s) => Json::Str(unescape(s)?),
            Event::ArrStart => {
                let mut a = Vec::new();
                loop {
                    match lx.next_event()?.expect("in array") {
                        Event::ArrEnd => break,
                        v => a.push(tree_from(lx, v)?),
                    }
                }
                Json::Arr(a)
            }
            Event::ObjStart => {
                let mut m = BTreeMap::new();
                loop {
                    match lx.next_event()?.expect("in object") {
                        Event::ObjEnd => break,
                        Event::Key(k) => {
                            let v = lx
                                .next_event()?
                                .expect("value after key");
                            m.insert(
                                unescape(k)?,
                                tree_from(lx, v)?,
                            );
                        }
                        other => {
                            panic!("unexpected in object: {other:?}")
                        }
                    }
                }
                Json::Obj(m)
            }
            Event::Key(_) | Event::ObjEnd | Event::ArrEnd => {
                panic!("not a value event: {ev:?}")
            }
        })
    }

    #[test]
    fn prop_lexer_equivalent_to_tree_parser() {
        let gen = crate::util::prop::Gen::new(
            |r| rand_json(r, 3),
            |_| vec![],
        );
        crate::util::prop::prop_check(200, &gen, |v| {
            for text in [v.to_string(), v.to_pretty()] {
                let mut lx = Lexer::new(&text);
                let rebuilt = tree_from_events(&mut lx).unwrap();
                if lx.next_event().unwrap().is_some() {
                    return false; // trailing events
                }
                if &rebuilt != v {
                    return false;
                }
            }
            true
        });
    }
}
