//! Minimal JSON: parser, printer, and typed accessors.
//!
//! Replaces serde_json (unavailable in the offline vendor set). Supports
//! the full JSON grammar the project uses: objects, arrays, strings with
//! escapes, numbers (f64 + exact i64 round-trip), booleans, null.
//! `parse ∘ to_string == id` is property-tested in [`crate::util::prop`]'s
//! test suite and below.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are sorted (BTreeMap) so printing is
/// deterministic — required for artifact-manifest diffing.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Numbers that parse exactly as i64 are kept integral.
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- constructors ----------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }

    // ---------------- typed accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `a.b.c` path access.
    pub fn path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(x) => Some(*x),
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 9e15 => {
                Some(*x as i64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// usize vector from a JSON array of numbers.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|x| x.as_f64()).collect()
    }

    // ---------------- printing ----------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Int(x) => out.push_str(&x.to_string()),
            Json::Num(x) => write_f64(*x, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = |n: usize, o: &mut String| {
            for _ in 0..n {
                o.push_str("  ");
            }
        };
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    pad(indent + 1, out);
                    v.write_pretty(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(indent, out);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    pad(indent + 1, out);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                pad(indent, out);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_f64(x: f64, out: &mut String) {
    if x.is_finite() {
        // shortest round-trip repr rust gives us
        let s = format!("{x}");
        out.push_str(&s);
        if !s.contains('.') && !s.contains('e') && !s.contains('E') {
            out.push_str(".0");
        }
    } else {
        // JSON has no inf/nan; null is the conventional fallback
        out.push_str("null");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i64)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Int(x as i64)
    }
}

impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Int(x as i64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(v: &[T]) -> Json {
        Json::Arr(v.iter().cloned().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(
                                || self.err("bad unicode escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one utf-8 char
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Int(42));
        assert_eq!(parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse("1.5").unwrap(), Json::Num(1.5));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.path("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escape() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrip_examples() {
        for text in [
            "null",
            "[1,2,3]",
            r#"{"a":1,"b":[true,false,null],"c":{"d":"e"}}"#,
            r#"{"x":1.25,"y":-3,"z":"\" \\ \n"}"#,
        ] {
            let v = parse(text).unwrap();
            let v2 = parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn pretty_parses_back() {
        let v = parse(r#"{"a":[1,{"b":2}],"c":[]}"#).unwrap();
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"n": 3, "f": 2.5, "s": "x", "b": true,
                          "arr": [1,2], "o": {"k": 9}}"#)
            .unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get("f").unwrap().as_f64().unwrap(), 2.5);
        assert_eq!(v.get("n").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(v.path("o.k").unwrap().as_i64().unwrap(), 9);
        assert_eq!(v.get("arr").unwrap().as_usize_vec().unwrap(), vec![1, 2]);
        assert!(v.get("missing").is_none());
        assert!(v.path("o.missing").is_none());
    }

    #[test]
    fn builder() {
        let v = Json::obj().set("a", 1i64).set("b", "x").set(
            "c",
            Json::Arr(vec![Json::Int(1), Json::Bool(false)]),
        );
        assert_eq!(v.to_string(), r#"{"a":1,"b":"x","c":[1,false]}"#);
    }

    #[test]
    fn float_roundtrip_keeps_value() {
        for x in [0.1, 1e-9, 123456.789, -2.5e10] {
            let v = parse(&Json::Num(x).to_string()).unwrap();
            assert_eq!(v.as_f64().unwrap(), x);
        }
    }
}
