//! Deterministic PRNG + distributions (rand crate substitute).
//!
//! xoshiro256** seeded via SplitMix64, plus the distributions the trace
//! generator and simulator need: uniform, exponential (Poisson arrivals),
//! lognormal (service durations), Zipf (token corpora), and weighted
//! choice. Everything is seed-deterministic so simulations and benches
//! reproduce bit-for-bit.

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Independent stream derived from this one (for sub-components).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough for sim use
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi].
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick uniformly from a slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Weighted choice: returns an index with probability w_i / Σw.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate λ (mean 1/λ) — Poisson inter-arrival gaps.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Lognormal with underlying N(mu, sigma²) — job service durations
    /// (the heavy-tailed shape cluster traces like ACMETrace exhibit).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf sample in [0, n) with exponent s (synthetic token corpus).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF on the harmonic weights; O(log n) by binary search
        // over a precomputable CDF is overkill here — rejection sampling
        // per Devroye is fine for the corpus generator.
        debug_assert!(n > 0);
        if n == 1 {
            return 0;
        }
        loop {
            let u = self.f64();
            let v = self.f64();
            let x = ((n as f64).powf(1.0 - s).mul_add(u, 1.0 - u))
                .powf(1.0 / (1.0 - s));
            let k = x.floor() as usize;
            if k >= 1 && k <= n {
                let ratio = (k as f64 / x).powf(s);
                if v * ratio <= 1.0 {
                    return k - 1;
                }
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>()
            / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn lognormal_positive_and_heavy() {
        let mut r = Rng::new(19);
        let xs: Vec<f64> = (0..10_000).map(|_| r.lognormal(0.0, 1.0))
            .collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        // E[lognormal(0,1)] = e^{1/2} ≈ 1.6487
        assert!((mean - 1.6487).abs() < 0.15, "{mean}");
    }

    #[test]
    fn zipf_skewed_to_small() {
        let mut r = Rng::new(23);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[r.zipf(100, 1.1)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[50] * 5);
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(29);
        let mut c = [0usize; 3];
        for _ in 0..30_000 {
            c[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(c[2] > c[1] && c[1] > c[0]);
        let frac = c[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03, "{frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(31);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(37);
        for _ in 0..1000 {
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
        }
        // degenerate range
        assert_eq!(r.range(4, 4), 4);
    }
}
