//! Leveled stderr logger (log-crate substitute, zero deps).
//!
//! Level selected via `TLORA_LOG` (error|warn|info|debug|trace) or
//! programmatically; defaults to `info`. The macros are cheap when the
//! level is off (atomic load only).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("TLORA_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 255 {
        cur = init_from_env();
    }
    (level as u8) <= cur
}

pub fn log(level: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {args}");
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn macros_compile() {
        set_level(Level::Error);
        log_info!("hidden {}", 1);
        log_error!("shown {}", 2);
        set_level(Level::Info);
    }
}
