//! Property-testing mini-framework (proptest substitute).
//!
//! The offline vendor set has no proptest, so scheduler/planner/JSON
//! invariants are checked with this seeded generator + shrinking harness:
//!
//! ```ignore
//! prop_check(100, gen_vec(gen_usize(0, 100), 0, 50), |v| {
//!     let mut s = v.clone();
//!     s.sort();
//!     s.windows(2).all(|w| w[0] <= w[1])
//! });
//! ```
//!
//! On failure the input is greedily shrunk (halving / element-dropping)
//! and the minimal counterexample is reported in the panic message.

use super::rng::Rng;
use std::fmt::Debug;

/// A generator produces a value and its shrink candidates.
pub struct Gen<T> {
    #[allow(clippy::type_complexity)]
    produce: Box<dyn Fn(&mut Rng) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        produce: impl Fn(&mut Rng) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Gen<T> {
        Gen {
            produce: Box::new(produce),
            shrink: Box::new(shrink),
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.produce)(rng)
    }

    pub fn shrinks(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }

    /// Map the generated value (shrinking maps through best-effort by
    /// re-shrinking in the source domain is not possible here, so mapped
    /// generators do not shrink).
    pub fn map<U: Clone + 'static>(
        self,
        f: impl Fn(T) -> U + 'static,
    ) -> Gen<U> {
        Gen::new(move |r| f(self.sample(r)), |_| vec![])
    }
}

/// usize in [lo, hi] with shrinking toward lo.
pub fn gen_usize(lo: usize, hi: usize) -> Gen<usize> {
    Gen::new(
        move |r| r.range(lo, hi),
        move |&v| {
            let mut out = vec![];
            if v > lo {
                out.push(lo);
                out.push(lo + (v - lo) / 2);
                out.push(v - 1);
            }
            out.dedup();
            out
        },
    )
}

/// f64 in [lo, hi] with shrinking toward lo.
pub fn gen_f64(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(
        move |r| r.range_f64(lo, hi),
        move |&v| {
            if v > lo + 1e-12 {
                vec![lo, lo + (v - lo) / 2.0]
            } else {
                vec![]
            }
        },
    )
}

pub fn gen_bool() -> Gen<bool> {
    Gen::new(|r| r.bool(0.5), |&v| if v { vec![false] } else { vec![] })
}

/// Vec of T with length in [min_len, max_len]; shrinks by halving the
/// vector, dropping single elements, then shrinking elements pointwise.
pub fn gen_vec<T: Clone + 'static>(
    elem: Gen<T>,
    min_len: usize,
    max_len: usize,
) -> Gen<Vec<T>> {
    let elem = std::rc::Rc::new(elem);
    let e1 = elem.clone();
    Gen::new(
        move |r| {
            let n = r.range(min_len, max_len);
            (0..n).map(|_| e1.sample(r)).collect()
        },
        move |v: &Vec<T>| {
            let mut out: Vec<Vec<T>> = vec![];
            // halve
            if v.len() > min_len {
                let half = v[..v.len() / 2.max(min_len).max(1)].to_vec();
                if half.len() >= min_len && half.len() < v.len() {
                    out.push(half);
                }
                // drop one element (first few positions)
                for i in 0..v.len().min(4) {
                    if v.len() - 1 >= min_len {
                        let mut w = v.clone();
                        w.remove(i);
                        out.push(w);
                    }
                }
            }
            // shrink each element (first few positions)
            for i in 0..v.len().min(4) {
                for cand in elem.shrinks(&v[i]) {
                    let mut w = v.clone();
                    w[i] = cand;
                    out.push(w);
                }
            }
            out
        },
    )
}

/// Pair generator.
pub fn gen_pair<A: Clone + 'static, B: Clone + 'static>(
    ga: Gen<A>,
    gb: Gen<B>,
) -> Gen<(A, B)> {
    let ga = std::rc::Rc::new(ga);
    let gb = std::rc::Rc::new(gb);
    let (ga2, gb2) = (ga.clone(), gb.clone());
    Gen::new(
        move |r| (ga.sample(r), gb.sample(r)),
        move |(a, b)| {
            let mut out = vec![];
            for ca in ga2.shrinks(a) {
                out.push((ca, b.clone()));
            }
            for cb in gb2.shrinks(b) {
                out.push((a.clone(), cb));
            }
            out
        },
    )
}

/// Run `cases` random cases of `property` against `gen`; on failure,
/// shrink to a minimal counterexample and panic with it. Deterministic
/// given `seed` (env `TLORA_PROP_SEED` overrides for repro).
pub fn prop_check_seeded<T: Clone + Debug + 'static>(
    seed: u64,
    cases: usize,
    gen: &Gen<T>,
    property: impl Fn(&T) -> bool,
) {
    let seed = std::env::var("TLORA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(seed);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if !property(&input) {
            let minimal = shrink_loop(gen, input, &property);
            panic!(
                "property failed (seed={seed}, case={case}).\n\
                 minimal counterexample: {minimal:#?}"
            );
        }
    }
}

/// `prop_check` with a default seed derived from the case count.
pub fn prop_check<T: Clone + Debug + 'static>(
    cases: usize,
    gen: &Gen<T>,
    property: impl Fn(&T) -> bool,
) {
    prop_check_seeded(0xC0FFEE ^ cases as u64, cases, gen, property)
}

fn shrink_loop<T: Clone + Debug + 'static>(
    gen: &Gen<T>,
    mut current: T,
    property: &impl Fn(&T) -> bool,
) -> T {
    // greedy: take the first shrink candidate that still fails; stop when
    // no candidate fails (local minimum). Bounded to avoid pathological
    // shrink graphs.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in gen.shrinks(&current) {
            if !property(&cand) {
                current = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    current
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        prop_check(200, &gen_usize(0, 100), |&x| x <= 100);
    }

    #[test]
    fn vec_property() {
        let g = gen_vec(gen_usize(0, 50), 0, 30);
        prop_check(100, &g, |v| {
            let mut s = v.clone();
            s.sort();
            s.windows(2).all(|w| w[0] <= w[1])
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn fails_and_reports() {
        prop_check(500, &gen_usize(0, 1000), |&x| x < 900);
    }

    #[test]
    fn shrinks_to_boundary() {
        // capture the panic and check the counterexample is minimal-ish
        let result = std::panic::catch_unwind(|| {
            prop_check(500, &gen_usize(0, 1000), |&x| x < 500);
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        // greedy shrink should land close to the 500 boundary
        assert!(msg.contains("counterexample"), "{msg}");
    }

    #[test]
    fn pair_gen() {
        let g = gen_pair(gen_usize(1, 10), gen_f64(0.0, 1.0));
        prop_check(100, &g, |(a, b)| *a >= 1 && *b < 1.0 + 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = gen_usize(0, 1_000_000);
        let mut r1 = Rng::new(99);
        let mut r2 = Rng::new(99);
        for _ in 0..50 {
            assert_eq!(g.sample(&mut r1), g.sample(&mut r2));
        }
    }
}
