//! Zero-dependency substrates.
//!
//! The offline vendor set has no serde/rand/proptest/criterion, so the
//! pieces a production framework would pull from crates.io are built here
//! (DESIGN.md §3.6): a JSON parser/printer, a counter-based PRNG with the
//! distributions the trace generator needs, descriptive statistics, a
//! small property-testing framework, and a leveled logger.

pub mod json;
pub mod rng;
pub mod stats;
pub mod prop;
pub mod logging;

/// Monotonically-increasing id allocator (jobs, groups, events).
#[derive(Debug, Default)]
pub struct IdGen {
    next: u64,
}

impl IdGen {
    pub fn new() -> Self {
        Self { next: 0 }
    }

    pub fn next(&mut self) -> u64 {
        let id = self.next;
        self.next += 1;
        id
    }

    /// Start above ids already consumed elsewhere (trace replay).
    pub fn starting_at(next: u64) -> Self {
        Self { next }
    }
}

/// f64 ordering helper: total order treating NaN as largest.
pub fn f64_cmp(a: f64, b: f64) -> std::cmp::Ordering {
    a.partial_cmp(&b).unwrap_or_else(|| {
        if a.is_nan() && b.is_nan() {
            std::cmp::Ordering::Equal
        } else if a.is_nan() {
            std::cmp::Ordering::Greater
        } else {
            std::cmp::Ordering::Less
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idgen_monotone() {
        let mut g = IdGen::new();
        assert_eq!(g.next(), 0);
        assert_eq!(g.next(), 1);
        let mut g2 = IdGen::starting_at(10);
        assert_eq!(g2.next(), 10);
    }

    #[test]
    fn f64_cmp_total() {
        use std::cmp::Ordering::*;
        assert_eq!(f64_cmp(1.0, 2.0), Less);
        assert_eq!(f64_cmp(2.0, 1.0), Greater);
        assert_eq!(f64_cmp(1.0, 1.0), Equal);
        assert_eq!(f64_cmp(f64::NAN, 1.0), Greater);
        assert_eq!(f64_cmp(f64::NAN, f64::NAN), Equal);
    }
}
