//! Kernel Fuser runtime model (§3.3): fused-kernel execution time,
//! nano-batch partitioning, the AIMD controller, and the Eq.-1
//! computation/communication overlap engine.
//!
//! The *numerics* of the fused kernel live in Pallas
//! (`python/compile/kernels/fused_lora.py`, validated against `ref.py`);
//! this module is the performance model the simulator and scheduler use
//! to predict how a fused group executes on the modeled GPUs — the same
//! role the paper's profiling pass plays for its Triton kernel.

pub mod tile;
pub mod nano;
pub mod aimd;
pub mod overlap;

pub use aimd::AimdController;
pub use nano::{nano_sizes, NanoLayout};
pub use overlap::iter_time;
pub use tile::{adapter_exec_time, AdapterLoad};
