//! Nano-batch partitioning (§3.3).
//!
//! A nano-batch splits the current fused batch along the batch dimension
//! into N execution units; samples in a nano-batch are processed together
//! by the fused kernel before the next nano-batch starts, exposing
//! fine-grained comm/comp overlap. The coordinator lays sequences out
//! round-robin across jobs so each nano-batch has the same per-job
//! composition (which is what keeps nano-batched gradients identical to
//! the full-batch step — see `train_step_nano` in model.py).

/// Balanced split of `total` samples into `n` nano-batches:
/// sizes differ by at most one and sum exactly to `total`.
pub fn nano_sizes(total: usize, n: usize) -> Vec<usize> {
    let n = n.clamp(1, total.max(1));
    let base = total / n;
    let rem = total % n;
    (0..n)
        .map(|i| base + usize::from(i < rem))
        .collect()
}

/// Round-robin assignment of each job's sequences to nano-batches.
#[derive(Debug, Clone, PartialEq)]
pub struct NanoLayout {
    /// per nano-batch: list of (job index, sequence count)
    pub slices: Vec<Vec<(usize, usize)>>,
}

impl NanoLayout {
    /// Distribute `batch_sizes[j]` sequences of each job j across `n`
    /// nano-batches as evenly as possible.
    pub fn round_robin(batch_sizes: &[usize], n: usize) -> NanoLayout {
        let total: usize = batch_sizes.iter().sum();
        let n = n.clamp(1, total.max(1));
        let mut slices = vec![vec![]; n];
        for (j, &b) in batch_sizes.iter().enumerate() {
            for (i, slice) in slices.iter_mut().enumerate() {
                let cnt = b / n + usize::from(i < b % n);
                if cnt > 0 {
                    slice.push((j, cnt));
                }
            }
        }
        NanoLayout { slices }
    }

    pub fn n(&self) -> usize {
        self.slices.len()
    }

    /// Total sequences in nano-batch `i`.
    pub fn slice_size(&self, i: usize) -> usize {
        self.slices[i].iter().map(|&(_, c)| c).sum()
    }

    /// Check conservation: every job's sequences appear exactly once.
    pub fn validate(&self, batch_sizes: &[usize]) -> Result<(), String> {
        let mut per_job = vec![0usize; batch_sizes.len()];
        for slice in &self.slices {
            for &(j, c) in slice {
                if j >= batch_sizes.len() {
                    return Err(format!("slice references job {j}"));
                }
                per_job[j] += c;
            }
        }
        for (j, (&got, &want)) in
            per_job.iter().zip(batch_sizes).enumerate()
        {
            if got != want {
                return Err(format!("job {j}: {got} sequences, want {want}"));
            }
        }
        Ok(())
    }

    /// Max/min slice-size imbalance (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let sizes: Vec<usize> =
            (0..self.n()).map(|i| self.slice_size(i)).collect();
        let mx = *sizes.iter().max().unwrap_or(&1) as f64;
        let mn = *sizes.iter().min().unwrap_or(&1) as f64;
        if mn > 0.0 {
            mx / mn
        } else {
            f64::INFINITY
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_sum_and_balance() {
        for total in [1usize, 7, 16, 33] {
            for n in [1usize, 2, 3, 8, 64] {
                let s = nano_sizes(total, n);
                assert_eq!(s.iter().sum::<usize>(), total);
                let mx = *s.iter().max().unwrap();
                let mn = *s.iter().min().unwrap();
                assert!(mx - mn <= 1, "total={total} n={n} {s:?}");
            }
        }
    }

    #[test]
    fn n_clamped_to_total() {
        assert_eq!(nano_sizes(3, 10).len(), 3);
        assert_eq!(nano_sizes(5, 0).len(), 1);
    }

    #[test]
    fn round_robin_conserves_sequences() {
        let batches = [1usize, 2, 4, 8];
        for n in [1usize, 2, 3, 5] {
            let l = NanoLayout::round_robin(&batches, n);
            l.validate(&batches).unwrap();
        }
    }

    #[test]
    fn round_robin_balanced_when_divisible() {
        let l = NanoLayout::round_robin(&[2, 2, 2], 2);
        assert_eq!(l.n(), 2);
        assert_eq!(l.slice_size(0), 3);
        assert_eq!(l.slice_size(1), 3);
        assert_eq!(l.imbalance(), 1.0);
        // each slice holds one sequence of every job
        for i in 0..2 {
            assert_eq!(l.slices[i].len(), 3);
        }
    }

    #[test]
    fn imbalance_bounded() {
        let l = NanoLayout::round_robin(&[1, 2, 4, 8], 4);
        l.validate(&[1, 2, 4, 8]).unwrap();
        assert!(l.imbalance() <= 2.0, "{}", l.imbalance());
    }
}
