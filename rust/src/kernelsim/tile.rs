//! Fused vs. unfused LoRA kernel execution-time model.
//!
//! §3.3: "a naïve design that processes each adapter independently
//! launches one kernel per adapter, … incurring excessive overhead, poor
//! occupancy". The model captures exactly those effects:
//!
//! * **fused** — three launches per layer invocation (fwd, dx, dA/dB),
//!   one pass over the token stream, rank-aware tiles. Efficiency is the
//!   low-rank-GEMM cap discounted by rank-padding waste (mirrors
//!   `mxu_utilization_estimate` in the Pallas kernel).
//! * **unfused** — per-adapter GEMM pairs (6 launches per adapter per
//!   layer), per-adapter efficiency degraded for small token counts, and
//!   extra HBM traffic from materialized `(t_i, r)` / `(t_i, d)`
//!   temporaries.

use crate::cluster::GpuSpec;

/// One adapter's load on a layer: its rank and the tokens it owns.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdapterLoad {
    pub rank: usize,
    pub tokens: f64,
}

/// Low-rank GEMMs cannot reach the dense-GEMM MFU cap: the rank-r inner
/// dimension starves the MMA pipelines.
const LOW_RANK_MFU: f64 = 0.30;

/// Token count below which a lone per-adapter kernel underutilizes SMs.
const SMALL_KERNEL_TOKENS: f64 = 512.0;

/// FLOPs of one adapter's LoRA branches on one layer, fwd+bwd
/// (q and v targets; wgrad + dgrad on the backward).
fn adapter_flops(d: f64, load: &AdapterLoad) -> f64 {
    let fwd = 2.0 * (2.0 * load.tokens * d * load.rank as f64) * 2.0;
    fwd * 3.0 // fwd + dgrad + wgrad
}

/// Execution time of all adapter branches of ONE fused layer invocation
/// on one GPU (the planner divides by the tensor-parallel degree).
pub fn adapter_exec_time(
    gpu: &GpuSpec,
    d_model: usize,
    adapters: &[AdapterLoad],
    fused: bool,
) -> f64 {
    if adapters.is_empty() {
        return 0.0;
    }
    let d = d_model as f64;
    let total_tokens: f64 = adapters.iter().map(|a| a.tokens).sum();
    if fused {
        // one fused pass: fwd kernel + dx kernel + dA/dB kernel
        let launches = 3.0;
        let flops: f64 =
            adapters.iter().map(|a| adapter_flops(d, a)).sum();
        // rank-padding waste: tiles padded to r_max (the static-shape
        // trick that makes heterogeneous ranks share one kernel)
        let r_max = adapters.iter().map(|a| a.rank).max().unwrap() as f64;
        let useful: f64 = adapters
            .iter()
            .map(|a| a.tokens * a.rank as f64)
            .sum::<f64>();
        let padded: f64 = total_tokens * r_max;
        let pad_eff = (useful / padded).clamp(0.05, 1.0);
        let eff = LOW_RANK_MFU * (0.5 + 0.5 * pad_eff);
        // memory: x read + output accumulate per kernel pass; compact
        // (t, r) intermediates stay in shared memory / VMEM
        let bytes = 3.0 * (2.0 * total_tokens * d * 2.0);
        let compute = flops / (gpu.peak_flops * eff);
        let memory = bytes / gpu.hbm_bw;
        launches * gpu.launch_overhead_s + compute.max(memory)
    } else {
        // per-adapter unfused path: gather + 2 GEMMs fwd, 4 GEMMs bwd
        let mut t = 0.0;
        for a in adapters {
            let launches = 6.0 * 2.0; // per target (q, v)
            let flops = adapter_flops(d, a);
            let occupancy =
                (a.tokens / SMALL_KERNEL_TOKENS).clamp(0.05, 1.0);
            let eff = LOW_RANK_MFU * occupancy;
            // materialized temporaries round-trip HBM: gathered x,
            // (t, r) intermediate, (t, d) output, read back for bwd
            let bytes = 3.0
                * (2.0 * a.tokens * d * 2.0
                    + 2.0 * a.tokens * a.rank as f64 * 4.0)
                + 2.0 * a.tokens * d * 4.0;
            let compute = flops / (gpu.peak_flops * eff);
            let memory = bytes / gpu.hbm_bw;
            t += launches * gpu.launch_overhead_s + compute.max(memory);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuSpec;

    fn gpu() -> GpuSpec {
        GpuSpec::a100_80g()
    }

    fn loads(n: usize, rank: usize, tokens: f64) -> Vec<AdapterLoad> {
        (0..n).map(|_| AdapterLoad { rank, tokens }).collect()
    }

    #[test]
    fn empty_is_free() {
        assert_eq!(adapter_exec_time(&gpu(), 4096, &[], true), 0.0);
    }

    #[test]
    fn fused_beats_unfused_for_many_small_adapters() {
        // the Fig. 7 effect: 8 small adapters, launch overhead dominates
        // the unfused path
        let a = loads(8, 8, 128.0);
        let fused = adapter_exec_time(&gpu(), 4096, &a, true);
        let unfused = adapter_exec_time(&gpu(), 4096, &a, false);
        assert!(
            unfused > 2.0 * fused,
            "unfused {unfused:.2e} fused {fused:.2e}"
        );
    }

    #[test]
    fn fused_advantage_grows_with_adapter_count() {
        let gain = |k: usize| {
            let a = loads(k, 8, 256.0);
            adapter_exec_time(&gpu(), 4096, &a, false)
                / adapter_exec_time(&gpu(), 4096, &a, true)
        };
        assert!(gain(16) > gain(4));
        assert!(gain(4) > gain(1) * 0.99);
    }

    #[test]
    fn time_scales_with_tokens() {
        let small = loads(2, 8, 1024.0);
        let big = loads(2, 8, 64.0 * 1024.0);
        assert!(
            adapter_exec_time(&gpu(), 4096, &big, true)
                > adapter_exec_time(&gpu(), 4096, &small, true) * 4.0
        );
    }

    #[test]
    fn low_rank_kernel_is_memory_bound_so_padding_is_free() {
        // arithmetic intensity of the LoRA kernel is ~2r flops/byte,
        // far below an A100's ~47: the fused kernel is memory-bound at
        // realistic ranks, so zero-padding heterogeneous ranks to r_max
        // costs nothing — the property that makes the static-shape
        // trick cheap (§3.3 / DESIGN.md §Hardware-Adaptation)
        let homo = vec![
            AdapterLoad { rank: 8, tokens: 4096.0 },
            AdapterLoad { rank: 8, tokens: 4096.0 },
        ];
        let hetero = vec![
            AdapterLoad { rank: 2, tokens: 4096.0 },
            AdapterLoad { rank: 16, tokens: 4096.0 },
        ];
        let t_homo = adapter_exec_time(&gpu(), 4096, &homo, true);
        let t_het = adapter_exec_time(&gpu(), 4096, &hetero, true);
        assert!((t_homo - t_het).abs() / t_homo < 0.05,
                "{t_homo:.3e} vs {t_het:.3e}");
    }

    #[test]
    fn rank_padding_penalizes_when_compute_bound() {
        // with memory bandwidth and launch overhead taken out of the
        // picture, the rank-padding waste shows up as lost efficiency
        let mut g = gpu();
        g.hbm_bw = 1e18;
        g.launch_overhead_s = 0.0;
        let homo = vec![
            AdapterLoad { rank: 8, tokens: 4096.0 },
            AdapterLoad { rank: 8, tokens: 4096.0 },
        ];
        let hetero = vec![
            AdapterLoad { rank: 2, tokens: 4096.0 },
            AdapterLoad { rank: 16, tokens: 4096.0 },
        ];
        let f = |ls: &[AdapterLoad]| -> f64 {
            ls.iter().map(|a| super::adapter_flops(4096.0, a)).sum()
        };
        let eff_homo = f(&homo) / adapter_exec_time(&g, 4096, &homo, true);
        let eff_het =
            f(&hetero) / adapter_exec_time(&g, 4096, &hetero, true);
        assert!(eff_homo > eff_het, "{eff_homo:.3e} vs {eff_het:.3e}");
    }

    #[test]
    fn unfused_linear_in_adapters() {
        let t4 = adapter_exec_time(&gpu(), 4096, &loads(4, 8, 256.0), false);
        let t8 = adapter_exec_time(&gpu(), 4096, &loads(8, 8, 256.0), false);
        assert!((t8 / t4 - 2.0).abs() < 0.05);
    }
}
