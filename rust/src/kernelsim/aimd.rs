//! AIMD nano-batch controller (§3.3, Eq. 2).
//!
//! ```text
//! N_{t+1} = N_t + α            if T_t <= T_{t-1} - τ
//!         = max(1, ⌊β N_t⌋)    otherwise
//! ```
//!
//! α = 4, β = 1/2 by default; τ filters measurement noise. The
//! controller only consumes end-to-end step times, so it adapts to
//! whatever the real bottleneck is (accelerator, interconnect,
//! contention) without a cost model — and each probe step still makes
//! training progress.

use crate::config::AimdConfig;

#[derive(Debug, Clone)]
pub struct AimdController {
    cfg: AimdConfig,
    n: usize,
    prev_t: Option<f64>,
    /// best (time, n) seen — used for reporting and for re-anchoring
    /// after backoff
    best: Option<(f64, usize)>,
    adjustments: u64,
    /// consecutive observations with |T_t - T_{t-1}| <= τ (plateau)
    plateau: u32,
}

/// Plateau length that triggers an exploratory +α probe. Eq. 2 as
/// written assumes noisy T_t; on a quiet system T_t == T_{t-1} forever
/// and the controller would park (worst case at N=1). Periodic probing
/// restores the classic AIMD sawtooth around the optimum; probing every
/// 8th plateau step keeps the exploration tax under a few percent
/// (§Perf log in EXPERIMENTS.md).
const PROBE_AFTER_PLATEAU: u32 = 8;

impl AimdController {
    pub fn new(cfg: AimdConfig) -> AimdController {
        let n = cfg.n0.max(1);
        AimdController {
            cfg,
            n,
            prev_t: None,
            best: None,
            adjustments: 0,
            plateau: 0,
        }
    }

    /// Current nano-batch count to use for the next step.
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn adjustments(&self) -> u64 {
        self.adjustments
    }

    pub fn best(&self) -> Option<(f64, usize)> {
        self.best
    }

    /// Feed the observed end-to-end time of the step that ran with the
    /// current `n`; returns the `n` for the next step.
    pub fn observe(&mut self, t: f64) -> usize {
        if self
            .best
            .map_or(true, |(bt, _)| t < bt)
        {
            self.best = Some((t, self.n));
        }
        let next = match self.prev_t {
            None => self.n + self.cfg.alpha, // first probe: explore up
            Some(prev) => {
                let tau = self.cfg.tau_frac * prev;
                if t <= prev - tau {
                    // improvement: additive increase
                    self.plateau = 0;
                    self.n + self.cfg.alpha
                } else if t >= prev + tau {
                    // regression: multiplicative decrease, re-anchored
                    // to the best N seen when that lies below us — a
                    // failed probe returns directly to the optimum
                    // instead of paying the sawtooth ramp again
                    self.plateau = 0;
                    let backoff = ((self.n as f64 * self.cfg.beta)
                        .floor() as usize)
                        .max(1);
                    match self.best {
                        Some((_, bn)) if bn >= backoff && bn < self.n => {
                            bn
                        }
                        _ => backoff,
                    }
                } else {
                    // within the noise margin: hold, then probe — see
                    // PROBE_AFTER_PLATEAU
                    self.plateau += 1;
                    if self.plateau >= PROBE_AFTER_PLATEAU {
                        self.plateau = 0;
                        self.n + self.cfg.alpha
                    } else {
                        self.n
                    }
                }
            }
        };
        self.prev_t = Some(t);
        self.n = next.clamp(1, self.cfg.n_max);
        self.adjustments += 1;
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelsim::overlap::iter_time;

    fn cfg() -> AimdConfig {
        AimdConfig::default()
    }

    /// Synthetic step-time curve with a clear interior optimum.
    fn t_of(n: usize) -> f64 {
        iter_time(1.0, 0.8, n, 0.01, 0.002)
    }

    #[test]
    fn additive_increase_on_improvement() {
        let mut c = AimdController::new(cfg());
        let n0 = c.n();
        let n1 = c.observe(1.0); // first probe explores upward
        assert_eq!(n1, n0 + 4);
        let n2 = c.observe(0.8); // improved: increase again
        assert_eq!(n2, n1 + 4);
    }

    #[test]
    fn multiplicative_decrease_on_regression() {
        let mut c = AimdController::new(cfg());
        c.observe(1.0);
        c.observe(0.5);
        let n = c.n();
        let best_n = c.best().unwrap().1;
        let n_after = c.observe(0.9); // worse: back off
        // Eq. 2 backoff, re-anchored to the best-seen N when that lies
        // in [βN, N)
        let backoff = (n / 2).max(1);
        let expect = if best_n >= backoff && best_n < n {
            best_n
        } else {
            backoff
        };
        assert_eq!(n_after, expect);
        assert!(n_after < n);
    }

    #[test]
    fn never_below_one_or_above_max(){
        let mut c = AimdController::new(cfg());
        for i in 0..200 {
            // alternate improving/worsening wildly
            let t = if i % 2 == 0 { 0.1 } else { 10.0 };
            let n = c.observe(t);
            assert!(n >= 1 && n <= AimdConfig::default().n_max);
        }
    }

    #[test]
    fn converges_near_optimum_of_synthetic_curve() {
        // run the controller against the Eq.-1 overlap curve and check
        // it spends late steps near the best fixed N
        let (best_n, _) = (1..=64)
            .map(|n| (n, t_of(n)))
            .min_by(|a, b| crate::util::f64_cmp(a.1, b.1))
            .unwrap();
        let mut c = AimdController::new(cfg());
        let mut visits = vec![];
        for _ in 0..300 {
            let n = c.n();
            visits.push(n);
            c.observe(t_of(n));
        }
        // average N over the last half should bracket the optimum
        let tail = &visits[150..];
        let mean_n =
            tail.iter().sum::<usize>() as f64 / tail.len() as f64;
        assert!(
            (mean_n - best_n as f64).abs() <= best_n as f64,
            "mean {mean_n} vs best {best_n}"
        );
        // and the best time seen should be within 15% of the true best
        let best_seen = c.best().unwrap().0;
        assert!(best_seen <= t_of(best_n) * 1.15);
    }

    #[test]
    fn converges_to_fixed_point_under_stable_load() {
        // Under a stationary step-time curve the controller must settle
        // into a bounded cycle anchored at one N (the AIMD sawtooth:
        // anchor, +α probe, back to anchor) instead of wandering: after
        // a transient, every visited N lies within α of a single anchor
        // value, and the anchor is revisited for the majority of steps.
        let mut c = AimdController::new(cfg());
        let mut visits = vec![];
        for _ in 0..300 {
            let n = c.n();
            visits.push(n);
            c.observe(t_of(n));
        }
        let tail = &visits[200..];
        let anchor = *tail.iter().min().unwrap();
        let span = *tail.iter().max().unwrap() - anchor;
        assert!(
            span <= AimdConfig::default().alpha,
            "no fixed point: visited N spans {span} around {anchor} \
             ({tail:?})"
        );
        let at_anchor =
            tail.iter().filter(|&&n| n == anchor).count();
        assert!(
            at_anchor * 3 >= tail.len(),
            "anchor {anchor} held only {at_anchor}/{} steps",
            tail.len()
        );
        // the same load curve must reproduce the same fixed point
        let mut c2 = AimdController::new(cfg());
        let mut visits2 = vec![];
        for _ in 0..300 {
            let n = c2.n();
            visits2.push(n);
            c2.observe(t_of(n));
        }
        assert_eq!(visits, visits2, "controller is not deterministic");
    }

    #[test]
    fn backoff_is_logarithmic() {
        // from n_max, consecutive regressions reach 1 in O(log N) steps
        let mut c = AimdController::new(AimdConfig {
            n0: 64,
            ..cfg()
        });
        c.observe(1.0);
        let mut steps = 0;
        let mut t = 100.0;
        while c.n() > 1 {
            t *= 1.1; // clearly worse each step (beyond the τ margin)
            c.observe(t);
            steps += 1;
            assert!(steps < 20, "backoff too slow");
        }
        assert!(steps <= 8, "{steps} steps to reach 1 from 64+");
    }

    #[test]
    fn noise_within_tau_holds_instead_of_oscillating() {
        let mut c = AimdController::new(cfg());
        c.observe(1.0);
        // change below tau: neither increase nor multiplicative backoff
        let n_before = c.n();
        let n_after = c.observe(0.9999);
        assert_eq!(n_after, n_before);
    }

    #[test]
    fn plateau_triggers_probe() {
        // a perfectly quiet system must not park forever: after a few
        // same-time observations the controller probes upward
        let mut c = AimdController::new(cfg());
        c.observe(1.0);
        c.observe(5.0); // force a backoff toward small N
        c.observe(5.0);
        let parked = c.n();
        let mut n = parked;
        for _ in 0..2 * super::PROBE_AFTER_PLATEAU {
            n = c.observe(3.0); // constant plateau at the new level
            if n > parked {
                break;
            }
        }
        assert!(n > parked, "controller never probed out of plateau");
    }

    #[test]
    fn tracks_bandwidth_change() {
        // optimum shifts when comm grows; the controller must follow
        let mut c = AimdController::new(cfg());
        for _ in 0..100 {
            let t = iter_time(1.0, 0.3, c.n(), 0.004, 0.001);
            c.observe(t);
        }
        let (best1, _) = (1..=64)
            .map(|n| (n, iter_time(1.0, 0.3, n, 0.004, 0.001)))
            .min_by(|a, b| crate::util::f64_cmp(a.1, b.1))
            .unwrap();
        let t_now = iter_time(1.0, 0.3, c.n(), 0.004, 0.001);
        let t_best = iter_time(1.0, 0.3, best1, 0.004, 0.001);
        assert!(t_now <= t_best * 1.25, "{t_now} vs {t_best}");
        // congestion: comm jumps 4x
        for _ in 0..100 {
            let t = iter_time(1.0, 1.2, c.n(), 0.004, 0.001);
            c.observe(t);
        }
        let (best2, _) = (1..=64)
            .map(|n| (n, iter_time(1.0, 1.2, n, 0.004, 0.001)))
            .min_by(|a, b| crate::util::f64_cmp(a.1, b.1))
            .unwrap();
        let t_now = iter_time(1.0, 1.2, c.n(), 0.004, 0.001);
        let t_best = iter_time(1.0, 1.2, best2, 0.004, 0.001);
        assert!(t_now <= t_best * 1.25, "{t_now} vs {t_best}");
    }
}
