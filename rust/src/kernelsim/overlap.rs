//! Eq.-1 computation/communication overlap engine (§3.3).
//!
//! With the batch split into N nano-batches, communication for nano-batch
//! i can start as soon as its compute finishes, so
//!
//! ```text
//! T_iter(N) = comp/N + oh                       (first nano's compute)
//!           + max( (N-1)/N·comp + (N-1)·oh ,    (remaining compute)
//!                  comm + N·lat )               (all communication)
//! ```
//!
//! which reduces to the paper's `max(ΣT_comp, ΣT_comm)` ideal when the
//! per-nano overheads (kernel launch `oh`, per-message latency `lat`)
//! vanish. Too few nano-batches delay communication behind long compute
//! phases; too many pay `N·(oh + lat)` — exactly the trade-off the AIMD
//! controller searches.

/// End-to-end iteration time for compute `comp` seconds and
/// communication `comm` seconds split into `n` nano-batches, with
/// per-nano kernel-launch overhead `oh` and per-message latency `lat`.
pub fn iter_time(comp: f64, comm: f64, n: usize, oh: f64, lat: f64) -> f64 {
    let n = n.max(1) as f64;
    let first = comp / n + oh;
    let rest_comp = comp * (n - 1.0) / n + oh * (n - 1.0);
    let all_comm = comm + lat * n;
    first + rest_comp.max(all_comm)
}

/// The no-overlap execution (what a policy without the Kernel Fuser
/// pays): strictly serial compute then communicate.
pub fn serial_time(comp: f64, comm: f64, oh: f64, lat: f64) -> f64 {
    iter_time(comp, comm, 1, oh, lat)
}

/// Best fixed nano-batch count by exhaustive scan (oracle for Fig. 8a
/// and for tests; the online system uses AIMD instead).
pub fn best_fixed_n(
    comp: f64,
    comm: f64,
    n_max: usize,
    oh: f64,
    lat: f64,
) -> (usize, f64) {
    (1..=n_max.max(1))
        .map(|n| (n, iter_time(comp, comm, n, oh, lat)))
        .min_by(|a, b| crate::util::f64_cmp(a.1, b.1))
        .unwrap()
}

/// Lower bound: perfect overlap with zero overheads (paper Eq. 1).
pub fn ideal_time(comp: f64, comm: f64) -> f64 {
    comp.max(comm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n1_is_serial() {
        let t = iter_time(2.0, 1.0, 1, 0.0, 0.0);
        assert!((t - 3.0).abs() < 1e-12);
    }

    #[test]
    fn overlap_improves_over_serial() {
        let serial = serial_time(1.0, 1.0, 0.001, 0.0001);
        let (best_n, best_t) = best_fixed_n(1.0, 1.0, 64, 0.001, 0.0001);
        assert!(best_t < serial, "{best_t} vs {serial}");
        assert!(best_n > 1);
    }

    #[test]
    fn approaches_ideal_with_zero_overheads() {
        let (_, t) = best_fixed_n(1.0, 0.9, 4096, 0.0, 0.0);
        assert!(t < ideal_time(1.0, 0.9) * 1.01, "{t}");
        assert!(t >= ideal_time(1.0, 0.9) - 1e-9);
    }

    #[test]
    fn never_beats_ideal() {
        for &(comp, comm) in
            &[(1.0, 0.5), (0.5, 1.0), (2.0, 2.0), (0.1, 3.0)]
        {
            for n in 1..64 {
                assert!(
                    iter_time(comp, comm, n, 0.001, 0.0001)
                        >= ideal_time(comp, comm) - 1e-12
                );
            }
        }
    }

    #[test]
    fn overlap_never_exceeds_100_percent() {
        // The time nano-batching saves over serial execution is exactly
        // the communication (or compute) it hides; hiding more than
        // min(comp, comm) would be >100% overlap. With zero per-nano
        // overheads the bound is exact; with overheads the saving only
        // shrinks on the iter side while serial pays oh + lat once, so
        // the bound loosens by at most that one-shot oh + lat.
        for &(comp, comm) in &[
            (1.0, 0.5),
            (0.5, 1.0),
            (2.0, 2.0),
            (0.1, 3.0),
            (3.0, 0.1),
        ] {
            for n in 1..=128usize {
                let saved_ideal = serial_time(comp, comm, 0.0, 0.0)
                    - iter_time(comp, comm, n, 0.0, 0.0);
                let frac = saved_ideal / comp.min(comm);
                assert!(
                    frac <= 1.0 + 1e-12,
                    "{frac} overlap at comp={comp} comm={comm} n={n}"
                );
                assert!(saved_ideal >= -1e-12);
                for &(oh, lat) in &[(0.01, 0.002), (0.0005, 0.0001)] {
                    let saved = serial_time(comp, comm, oh, lat)
                        - iter_time(comp, comm, n, oh, lat);
                    assert!(
                        saved <= comp.min(comm) + oh + lat + 1e-12,
                        "saved {saved} > min(comp, comm) at \
                         comp={comp} comm={comm} n={n} oh={oh}"
                    );
                }
            }
        }
    }

    #[test]
    fn large_n_penalized_by_overheads() {
        let t8 = iter_time(1.0, 0.8, 8, 0.01, 0.002);
        let t512 = iter_time(1.0, 0.8, 512, 0.01, 0.002);
        assert!(t512 > t8);
    }

    #[test]
    fn interior_optimum_exists() {
        let (n, _) = best_fixed_n(1.0, 0.8, 256, 0.01, 0.002);
        assert!(n > 1 && n < 256, "optimum at boundary: {n}");
    }

    #[test]
    fn compute_bound_prefers_small_n() {
        // with negligible comm there is nothing to overlap: larger N
        // only adds launch overhead
        let (n, _) = best_fixed_n(1.0, 0.001, 64, 0.01, 0.002);
        assert_eq!(n, 1);
    }

    #[test]
    fn optimum_depends_on_bandwidth() {
        // §3.3: "the optimal nano-batch size … vary depending on the
        // inter-GPU connection bandwidth" — slower network (bigger comm)
        // shifts the optimum
        let (n_fast, _) = best_fixed_n(1.0, 0.2, 128, 0.005, 0.001);
        let (n_slow, _) = best_fixed_n(1.0, 0.9, 128, 0.005, 0.001);
        assert_ne!(n_fast, n_slow);
    }
}
