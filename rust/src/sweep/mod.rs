//! Scenario-sweep engine: declarative grids over the evaluation axes,
//! fanned out across worker threads, aggregated with confidence
//! intervals, and emitted as tables / CSV / JSON.
//!
//! The paper's headline results (Figs. 5–10) are all grids over
//! policy × cluster size × arrival rate × trace month; related systems
//! (mLoRA, PLoRA) are evaluated the same way. This subsystem makes that
//! shape first-class so every figure bench — and any future
//! evaluation — is a thin driver instead of a bespoke loop:
//!
//! * [`grid`] — [`SweepGrid`] (the declarative cartesian product) and
//!   [`SweepPoint`] (one cell, in a fixed enumeration order);
//! * [`runner`] — the `std::thread` + channel executor. Simulations are
//!   pure functions of their config; [`run_streaming`] delivers results
//!   in strict grid-index order through a bounded reorder buffer, so
//!   output is bit-identical across thread counts and runs;
//! * [`report`] — per-scenario aggregation across seed replicas
//!   (`mean ± 95% CI` via [`crate::util::stats::mean_ci95`]) and
//!   table/CSV/JSON emission through [`crate::metrics`] and
//!   [`crate::util::json`]. This is the legacy collect-then-emit path,
//!   kept as the differential reference for —
//! * [`stream`] — the O(1)-memory emit-as-you-aggregate report writer
//!   ([`StreamReport`]), byte-identical to [`report`] on every output
//!   form and the default CLI path (DESIGN.md §Streaming reports).
//!
//! CLI: `tlora sweep --policies tlora,mlora --gpus 32,64,128
//! --rate-scales 0.5,1,2 --seeds 41,42,43 --threads 8 --out-json s.json
//! --out-csv s.csv` (see `main.rs` / DESIGN.md §Sweep).

pub mod grid;
pub mod runner;
pub mod report;
pub mod stream;

pub use grid::{month_profile, SweepGrid, SweepPoint};
pub use report::{
    aggregate, sweep_table, to_csv, to_json, to_json_canonical,
    CellSummary,
};
pub use runner::{default_threads, reorder_capacity, run, run_parallel,
                 run_streaming, PointResult, StreamStats, SweepRun};
pub use stream::{run_streaming_report, Spool, StreamReport};
