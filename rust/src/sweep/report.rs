//! Sweep aggregation and emission.
//!
//! Per-scenario aggregation pools the seed replicas of each grid cell
//! and reports every metric as `mean ± 95% CI` via
//! [`crate::util::stats::mean_ci95`]. Emission goes through the shared
//! reporting substrates: aligned tables / CSV via [`crate::metrics`]
//! and JSON via [`crate::util::json`].
//!
//! The per-row builders ([`point_json`], [`cell_json`],
//! [`csv_headers`], [`csv_point_row`]) are shared with the streaming
//! writer in [`super::stream`]: both paths emit through the same
//! functions, so their bytes cannot drift. This full-tree module
//! survives as the differential reference (`--legacy-report` in the
//! CLI) that the streaming path is pinned byte-identical against.

use super::runner::{PointResult, SweepRun};
use crate::metrics::Table;
use crate::util::json::Json;
use crate::util::stats::mean_ci95;

/// One scenario (grid cell modulo seed) aggregated across its replicas.
#[derive(Debug, Clone)]
pub struct CellSummary {
    /// scenario key, e.g. `tlora/j200/g128/r1x/m1`
    pub key: String,
    /// representative point of the cell (its first replica)
    pub point: super::grid::SweepPoint,
    pub n_seeds: usize,
    /// (mean, 95% CI half-width) pairs
    pub throughput: (f64, f64),
    pub mean_jct: (f64, f64),
    pub p99_jct: (f64, f64),
    pub gpu_util: (f64, f64),
    pub makespan: (f64, f64),
    pub mean_slowdown: (f64, f64),
    /// useful samples/s (rolled-back work excluded) — the churn metric
    pub goodput: (f64, f64),
    /// fraction of jobs meeting their SLO deadline
    pub slo_attainment: (f64, f64),
    /// time-weighted severity of degraded node-time (1.0 = no
    /// stragglers)
    pub straggler_slowdown: (f64, f64),
    /// total evictions across the cell's replicas
    pub restarts: u64,
    /// total node-failure events across the cell's replicas
    pub node_failures: u64,
    /// total single-GPU failure events across the cell's replicas —
    /// the GPU-fault columns are gated on the cell's `gpu_mtbf_s` so
    /// fault-free reports stay byte-identical to pre-GPU-fault builds
    pub gpu_failures: u64,
    /// total simulated seconds individual GPUs spent holed out of
    /// otherwise-healthy nodes, pooled as (mean, ci95) over replicas
    pub holed_gpu_time_s: (f64, f64),
    /// total straggler degrade episodes across the cell's replicas
    pub node_degrades: u64,
    /// total voluntary straggler migrations across the cell's replicas
    pub migrations: u64,
    /// total planner evaluations (shape-cache misses) across the
    /// cell's replicas — the scheduler-cost column the scaling bench
    /// gates on (previously only totalled run-wide, invisible per cell)
    pub probes: u64,
    /// total predictor queries the caches absorbed across the cell's
    /// replicas
    pub plan_cache_hits: u64,
    /// total jobs that never completed across the cell's replicas —
    /// nonzero means the scenario silently truncated work and its
    /// JCT/throughput numbers are not comparable
    pub incomplete: usize,
    /// per-hardware-tier time-averaged utilization pooled across the
    /// cell's replicas, in tier order (`(tier name, (mean, ci95))`);
    /// empty for homogeneous cells — the tier columns are gated on
    /// this so single-tier reports stay byte-identical to pre-tier
    /// builds
    pub tier_util: Vec<(String, (f64, f64))>,
    /// mean racks spanned per scheduled gang, pooled across replicas;
    /// (0, 0) for flat cells where the tracker never runs — the
    /// topology columns are gated on the cell's topology string so
    /// flat reports stay byte-identical to pre-topology builds
    pub rack_span_mean: (f64, f64),
    /// worst racks-spanned by any gang across the cell's replicas
    pub rack_span_max: u64,
    /// total shrink-in-place events across the cell's replicas — the
    /// shrink columns are gated on the cell's `shrink` flag so
    /// evict-semantics reports stay byte-identical to pre-shrink
    /// builds
    pub shrinks: u64,
    /// total regrow-to-full-width events across the cell's replicas
    pub regrows: u64,
    /// job-seconds spent training at shrunken width, pooled as
    /// (mean, ci95) over replicas
    pub degraded_rate_time_s: (f64, f64),
}

impl CellSummary {
    /// Fraction of the cell's predictor queries served from cache.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.probes + self.plan_cache_hits;
        if total == 0 {
            0.0
        } else {
            self.plan_cache_hits as f64 / total as f64
        }
    }
}

/// Aggregate a run's points into per-scenario summaries, preserving the
/// grid's enumeration order of first appearance.
pub fn aggregate(run: &SweepRun) -> Vec<CellSummary> {
    // first-appearance order preserved; HashMap index keeps the
    // grouping O(points) for paper-scale sweeps (thousands of cells)
    let mut order: Vec<String> = vec![];
    let mut buckets: Vec<Vec<&PointResult>> = vec![];
    let mut index: std::collections::HashMap<String, usize> =
        std::collections::HashMap::new();
    for p in &run.points {
        let key = p.point.cell_key();
        match index.get(&key) {
            Some(&i) => buckets[i].push(p),
            None => {
                index.insert(key.clone(), order.len());
                order.push(key);
                buckets.push(vec![p]);
            }
        }
    }
    order
        .into_iter()
        .zip(buckets)
        .map(|(key, pts)| {
            let col = |f: &dyn Fn(&PointResult) -> f64| -> (f64, f64) {
                let xs: Vec<f64> = pts.iter().map(|p| f(*p)).collect();
                mean_ci95(&xs)
            };
            CellSummary {
                key,
                point: pts[0].point.clone(),
                n_seeds: pts.len(),
                throughput: col(&|p| p.result.avg_throughput),
                mean_jct: col(&|p| p.result.mean_jct),
                p99_jct: col(&|p| p.result.p99_jct),
                gpu_util: col(&|p| p.result.avg_gpu_util),
                makespan: col(&|p| p.result.makespan),
                mean_slowdown: col(&|p| p.result.mean_slowdown),
                goodput: col(&|p| p.result.goodput),
                slo_attainment: col(&|p| p.result.slo_attainment),
                straggler_slowdown: col(&|p| {
                    p.result.straggler_slowdown
                }),
                restarts: pts
                    .iter()
                    .map(|p| p.result.restarts)
                    .sum(),
                node_failures: pts
                    .iter()
                    .map(|p| p.result.node_failures)
                    .sum(),
                gpu_failures: pts
                    .iter()
                    .map(|p| p.result.gpu_failures)
                    .sum(),
                holed_gpu_time_s: col(&|p| {
                    p.result.holed_gpu_time_s
                }),
                node_degrades: pts
                    .iter()
                    .map(|p| p.result.node_degrades)
                    .sum(),
                migrations: pts
                    .iter()
                    .map(|p| p.result.migrations)
                    .sum(),
                probes: pts
                    .iter()
                    .map(|p| p.result.scheduler_probes)
                    .sum(),
                plan_cache_hits: pts
                    .iter()
                    .map(|p| p.result.plan_cache_hits)
                    .sum(),
                incomplete: pts
                    .iter()
                    .map(|p| p.result.incomplete_jobs.len())
                    .sum(),
                tier_util: pts[0]
                    .result
                    .tier_util
                    .iter()
                    .enumerate()
                    .map(|(i, (name, _))| {
                        let xs: Vec<f64> = pts
                            .iter()
                            .map(|p| {
                                p.result
                                    .tier_util
                                    .get(i)
                                    .map_or(0.0, |&(_, u)| u)
                            })
                            .collect();
                        (name.clone(), mean_ci95(&xs))
                    })
                    .collect(),
                rack_span_mean: col(&|p| p.result.rack_span_mean),
                rack_span_max: pts
                    .iter()
                    .map(|p| p.result.rack_span_max)
                    .max()
                    .unwrap_or(0),
                shrinks: pts
                    .iter()
                    .map(|p| p.result.shrinks)
                    .sum(),
                regrows: pts
                    .iter()
                    .map(|p| p.result.regrows)
                    .sum(),
                degraded_rate_time_s: col(&|p| {
                    p.result.degraded_rate_time_s
                }),
            }
        })
        .collect()
}

/// Clamp a metric to finite before emission. A cell whose every job
/// was cut off has no completed-JCT sample, so its mean/p99 come out
/// NaN; emitted verbatim that poisoned the report — NaN has no JSON
/// encoding (the writer falls back to `null`, breaking the numeric
/// schema and any canonical byte-diff) and rendered literally in the
/// table/CSV. 0.0 next to the `incomplete` warning column is the
/// honest encoding; finite values pass through bit-unchanged.
pub(crate) fn fin(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

fn pm(v: (f64, f64), digits: usize) -> String {
    let (m, c) = (fin(v.0), fin(v.1));
    if c > 0.0 {
        format!("{m:.d$} ±{c:.d$}", d = digits)
    } else {
        format!("{m:.d$}", d = digits)
    }
}

/// Render the aggregated scenarios as an aligned table. The `tier
/// util` column appears only when some cell is heterogeneous, so
/// homogeneous sweeps render byte-identically to pre-tier builds.
pub fn sweep_table(title: &str, cells: &[CellSummary]) -> Table {
    let het = cells.iter().any(|c| !c.tier_util.is_empty());
    let topo =
        cells.iter().any(|c| !c.point.topology.is_empty());
    let gpufaults =
        cells.iter().any(|c| c.point.gpu_mtbf_s > 0.0);
    let shrink = cells.iter().any(|c| c.point.shrink);
    let mut headers =
        vec!["scenario", "seeds", "thr (samples/s)", "goodput",
          "mean JCT (s)", "p99 JCT (s)", "GPU util", "slowdown",
          "SLO", "restarts", "migr", "probes", "hit%", "incomplete"];
    if gpufaults {
        headers.push("gpu fails");
    }
    if het {
        headers.push("tier util");
    }
    if topo {
        headers.push("rack span");
    }
    if shrink {
        headers.push("shrinks");
    }
    let mut t = Table::new(title, &headers);
    for c in cells {
        let mut row = vec![
            c.key.clone(),
            c.n_seeds.to_string(),
            pm(c.throughput, 2),
            pm(c.goodput, 2),
            pm(c.mean_jct, 0),
            pm(c.p99_jct, 0),
            format!(
                "{:.1}%{}",
                c.gpu_util.0 * 100.0,
                if c.gpu_util.1 > 0.0 {
                    format!(" ±{:.1}", c.gpu_util.1 * 100.0)
                } else {
                    String::new()
                }
            ),
            pm(c.mean_slowdown, 3),
            format!(
                "{:.1}%{}",
                c.slo_attainment.0 * 100.0,
                if c.slo_attainment.1 > 0.0 {
                    format!(" ±{:.1}", c.slo_attainment.1 * 100.0)
                } else {
                    String::new()
                }
            ),
            c.restarts.to_string(),
            c.migrations.to_string(),
            c.probes.to_string(),
            format!("{:.1}%", c.cache_hit_rate() * 100.0),
            // warning column: jobs cut off before completion make the
            // cell's other metrics incomparable
            if c.incomplete == 0 {
                "-".into()
            } else {
                format!("{} UNFINISHED", c.incomplete)
            },
        ];
        if gpufaults {
            row.push(if c.point.gpu_mtbf_s > 0.0 {
                format!(
                    "{} ({:.0}s holed)",
                    c.gpu_failures,
                    fin(c.holed_gpu_time_s.0)
                )
            } else {
                "-".into()
            });
        }
        if het {
            row.push(if c.tier_util.is_empty() {
                "-".into()
            } else {
                c.tier_util
                    .iter()
                    .map(|(n, v)| {
                        format!("{n}:{:.1}%", fin(v.0) * 100.0)
                    })
                    .collect::<Vec<_>>()
                    .join(" ")
            });
        }
        if topo {
            row.push(if c.point.topology.is_empty() {
                "-".into()
            } else {
                format!(
                    "{:.2} max {}",
                    fin(c.rack_span_mean.0),
                    c.rack_span_max
                )
            });
        }
        if shrink {
            row.push(if c.point.shrink {
                format!(
                    "{} ({} regrown, {:.0}s degraded)",
                    c.shrinks,
                    c.regrows,
                    fin(c.degraded_rate_time_s.0)
                )
            } else {
                "-".into()
            });
        }
        t.row(&row);
    }
    t
}

/// CSV column names; `gpufaults` appends the GPU-fault-gated columns,
/// `het` the heterogeneity-gated ones, `topo` the topology-gated
/// ones and `shrink` the shrink-in-place-gated ones. Shared by the
/// legacy and streaming CSV paths.
pub(crate) fn csv_headers(
    het: bool,
    topo: bool,
    gpufaults: bool,
    shrink: bool,
) -> Vec<&'static str> {
    let mut headers =
        vec!["index", "policy", "n_jobs", "gpus", "rate_scale", "month",
          "mtbf_s", "straggler_mtbs_s", "seed", "throughput",
          "goodput", "mean_jct", "p99_jct", "gpu_util", "makespan",
          "mean_slowdown", "slo_attainment", "node_failures",
          "preemptions", "restarts", "lost_step_time_s",
          "restore_delay_s", "node_degrades", "degraded_time_s",
          "straggler_slowdown", "migrations", "sched_rounds",
          "events", "events_stale", "probes", "plan_cache_hits",
          "completed", "incomplete"];
    if gpufaults {
        headers.push("gpu_mtbf_s");
        headers.push("gpu_failures");
        headers.push("holed_gpu_time_s");
    }
    if het {
        headers.push("hardware_mix");
        headers.push("tier_util");
    }
    if topo {
        headers.push("topology");
        headers.push("rack_span_mean");
        headers.push("rack_span_max");
    }
    if shrink {
        headers.push("shrink");
        headers.push("shrinks");
        headers.push("regrows");
        headers.push("degraded_rate_time_s");
    }
    headers
}

/// One point's CSV cells, in [`csv_headers`] order. Shared by the
/// legacy and streaming CSV paths.
pub(crate) fn csv_point_row(
    p: &PointResult,
    het: bool,
    topo: bool,
    gpufaults: bool,
    shrink: bool,
) -> Vec<String> {
    let mut row = vec![
        p.point.index.to_string(),
        p.point.policy.slug().to_string(),
        p.point.n_jobs.to_string(),
        p.point.gpus.to_string(),
        p.point.rate_scale.to_string(),
        p.point.month.to_string(),
        p.point.mtbf_s.to_string(),
        p.point.straggler_mtbs_s.to_string(),
        p.point.seed.to_string(),
        format!("{:.6}", fin(p.result.avg_throughput)),
        format!("{:.6}", fin(p.result.goodput)),
        format!("{:.6}", fin(p.result.mean_jct)),
        format!("{:.6}", fin(p.result.p99_jct)),
        format!("{:.6}", fin(p.result.avg_gpu_util)),
        format!("{:.6}", fin(p.result.makespan)),
        format!("{:.6}", fin(p.result.mean_slowdown)),
        format!("{:.6}", fin(p.result.slo_attainment)),
        p.result.node_failures.to_string(),
        p.result.preemptions.to_string(),
        p.result.restarts.to_string(),
        format!("{:.6}", fin(p.result.lost_step_time_s)),
        format!("{:.6}", fin(p.result.restore_delay_s)),
        p.result.node_degrades.to_string(),
        format!("{:.6}", fin(p.result.degraded_node_time_s)),
        format!("{:.6}", fin(p.result.straggler_slowdown)),
        p.result.migrations.to_string(),
        p.result.sched_rounds.to_string(),
        p.result.events.to_string(),
        p.result.events_stale.to_string(),
        p.result.scheduler_probes.to_string(),
        p.result.plan_cache_hits.to_string(),
        p.result.jct.len().to_string(),
        p.result.incomplete_jobs.len().to_string(),
    ];
    if gpufaults {
        row.push(p.point.gpu_mtbf_s.to_string());
        row.push(p.result.gpu_failures.to_string());
        row.push(format!("{:.6}", fin(p.result.holed_gpu_time_s)));
    }
    if het {
        row.push(p.point.hardware_mix.clone());
        row.push(
            p.result
                .tier_util
                .iter()
                .map(|(n, u)| format!("{n}:{:.6}", fin(*u)))
                .collect::<Vec<_>>()
                .join(";"),
        );
    }
    if topo {
        row.push(p.point.topology.clone());
        row.push(format!("{:.6}", fin(p.result.rack_span_mean)));
        row.push(p.result.rack_span_max.to_string());
    }
    if shrink {
        row.push(p.point.shrink.to_string());
        row.push(p.result.shrinks.to_string());
        row.push(p.result.regrows.to_string());
        row.push(format!(
            "{:.6}",
            fin(p.result.degraded_rate_time_s)
        ));
    }
    row
}

/// Per-point CSV (one row per simulated cell) through the shared
/// [`Table`] CSV path. The `hardware_mix` / `tier_util` columns
/// appear only when some point is heterogeneous, keeping homogeneous
/// CSV output byte-identical to pre-tier builds.
pub fn to_csv(run: &SweepRun) -> String {
    let het = run
        .points
        .iter()
        .any(|p| !p.point.hardware_mix.is_empty());
    let topo = run
        .points
        .iter()
        .any(|p| !p.point.topology.is_empty());
    let gpufaults = run
        .points
        .iter()
        .any(|p| p.point.gpu_mtbf_s > 0.0);
    let shrink = run.points.iter().any(|p| p.point.shrink);
    let mut t = Table::new(
        "sweep",
        &csv_headers(het, topo, gpufaults, shrink),
    );
    for p in &run.points {
        t.row(&csv_point_row(p, het, topo, gpufaults, shrink));
    }
    t.to_csv()
}

/// Full machine-readable report: run metadata, per-point metrics, and
/// per-scenario aggregates. Includes wall-clock timing and the thread
/// count — see [`to_json_canonical`] for the determinism-comparable
/// form.
pub fn to_json(run: &SweepRun) -> Json {
    to_json_with(run, true)
}

/// [`to_json`] minus every execution-dependent field (`wall_s` per
/// point and total, `n_threads`): two runs of the same grid must
/// produce *byte-identical* canonical JSON whatever the thread count —
/// this is the form the golden-trace fixture and CI's `--threads 1`
/// vs `--threads 8` diff pin down.
pub fn to_json_canonical(run: &SweepRun) -> Json {
    to_json_with(run, false)
}

/// One point's JSON object — the subtree under `points[i]`. Shared by
/// the legacy full-tree writer and the streaming writer (which builds
/// this small transient tree per row and frees it after emission, so
/// report memory stays O(1) in point count).
pub(crate) fn point_json(p: &PointResult, include_timing: bool) -> Json {
    let mut j = Json::obj()
        .set("index", p.point.index)
        .set("label", p.point.label())
        .set("policy", p.point.policy.slug())
        .set("n_jobs", p.point.n_jobs)
        .set("gpus", p.point.gpus)
        .set("rate_scale", p.point.rate_scale)
        .set("month", p.point.month)
        .set("mtbf_s", p.point.mtbf_s)
        .set("straggler_mtbs_s", p.point.straggler_mtbs_s)
        .set("seed", p.point.seed)
        .set("throughput", fin(p.result.avg_throughput))
        .set("goodput", fin(p.result.goodput))
        .set("mean_jct", fin(p.result.mean_jct))
        .set("p99_jct", fin(p.result.p99_jct))
        .set("gpu_util", fin(p.result.avg_gpu_util))
        .set("makespan", fin(p.result.makespan))
        .set("mean_slowdown", fin(p.result.mean_slowdown))
        .set("slo_attainment", fin(p.result.slo_attainment))
        .set("node_failures", p.result.node_failures)
        .set("preemptions", p.result.preemptions)
        .set("restarts", p.result.restarts)
        .set("lost_step_time_s", fin(p.result.lost_step_time_s))
        .set("restore_delay_s", fin(p.result.restore_delay_s))
        .set("node_degrades", p.result.node_degrades)
        .set(
            "degraded_time_s",
            fin(p.result.degraded_node_time_s),
        )
        .set(
            "straggler_slowdown",
            fin(p.result.straggler_slowdown),
        )
        .set("migrations", p.result.migrations)
        .set("sched_rounds", p.result.sched_rounds)
        .set("events", p.result.events)
        .set("events_stale", p.result.events_stale)
        .set("scheduler_probes", p.result.scheduler_probes)
        .set("plan_cache_hits", p.result.plan_cache_hits)
        .set("completed", p.result.jct.len())
        .set("incomplete", p.result.incomplete_jobs.len());
    // gated on the point's GPU-MTBF axis: fault-free points carry no
    // GPU-fault fields, so their JSON is byte-identical to
    // pre-GPU-fault builds
    if p.point.gpu_mtbf_s > 0.0 {
        j = j
            .set("gpu_mtbf_s", p.point.gpu_mtbf_s)
            .set("gpu_failures", p.result.gpu_failures)
            .set(
                "holed_gpu_time_s",
                fin(p.result.holed_gpu_time_s),
            );
    }
    // gated on heterogeneity: homogeneous points carry no hardware
    // fields, so their JSON is byte-identical to pre-tier builds
    if !p.point.hardware_mix.is_empty() {
        j = j
            .set("hardware_mix", p.point.hardware_mix.as_str())
            .set(
                "tier_util",
                Json::Arr(
                    p.result
                        .tier_util
                        .iter()
                        .map(|(n, u)| {
                            Json::obj()
                                .set("tier", n.as_str())
                                .set("util", fin(*u))
                        })
                        .collect(),
                ),
            );
    }
    // gated on topology: flat points carry no topology fields, so
    // their JSON is byte-identical to pre-topology builds
    if !p.point.topology.is_empty() {
        j = j
            .set("topology", p.point.topology.as_str())
            .set("rack_span_mean", fin(p.result.rack_span_mean))
            .set("rack_span_max", p.result.rack_span_max);
    }
    // gated on the shrink axis: evict-semantics points carry no
    // shrink fields, so their JSON is byte-identical to pre-shrink
    // builds
    if p.point.shrink {
        j = j
            .set("shrink", true)
            .set("shrinks", p.result.shrinks)
            .set("regrows", p.result.regrows)
            .set(
                "degraded_rate_time_s",
                fin(p.result.degraded_rate_time_s),
            );
    }
    if include_timing {
        j = j.set("wall_s", p.wall_s);
    }
    j
}

/// One aggregated cell's JSON object — the subtree under `cells[i]`.
/// Shared by the legacy and streaming writers.
pub(crate) fn cell_json(c: &CellSummary) -> Json {
    let ci = |v: (f64, f64)| {
        Json::Arr(vec![Json::Num(fin(v.0)), Json::Num(fin(v.1))])
    };
    let mut j = Json::obj()
        .set("key", c.key.clone())
        .set("n_seeds", c.n_seeds)
        .set("throughput", ci(c.throughput))
        .set("goodput", ci(c.goodput))
        .set("mean_jct", ci(c.mean_jct))
        .set("p99_jct", ci(c.p99_jct))
        .set("gpu_util", ci(c.gpu_util))
        .set("makespan", ci(c.makespan))
        .set("mean_slowdown", ci(c.mean_slowdown))
        .set("slo_attainment", ci(c.slo_attainment))
        .set("straggler_slowdown", ci(c.straggler_slowdown))
        .set("restarts", c.restarts)
        .set("node_failures", c.node_failures)
        .set("node_degrades", c.node_degrades)
        .set("migrations", c.migrations)
        .set("scheduler_probes", c.probes)
        .set("plan_cache_hits", c.plan_cache_hits)
        .set("plan_cache_rate", c.cache_hit_rate())
        .set("incomplete", c.incomplete);
    if c.point.gpu_mtbf_s > 0.0 {
        j = j
            .set("gpu_mtbf_s", c.point.gpu_mtbf_s)
            .set("gpu_failures", c.gpu_failures)
            .set("holed_gpu_time_s", ci(c.holed_gpu_time_s));
    }
    if !c.point.hardware_mix.is_empty() {
        j = j
            .set("hardware_mix", c.point.hardware_mix.as_str())
            .set(
                "tier_util",
                Json::Arr(
                    c.tier_util
                        .iter()
                        .map(|(n, v)| {
                            Json::obj()
                                .set("tier", n.as_str())
                                .set("util", ci(*v))
                        })
                        .collect(),
                ),
            );
    }
    if !c.point.topology.is_empty() {
        j = j
            .set("topology", c.point.topology.as_str())
            .set("rack_span_mean", ci(c.rack_span_mean))
            .set("rack_span_max", c.rack_span_max);
    }
    if c.point.shrink {
        j = j
            .set("shrink", true)
            .set("shrinks", c.shrinks)
            .set("regrows", c.regrows)
            .set(
                "degraded_rate_time_s",
                ci(c.degraded_rate_time_s),
            );
    }
    j
}

fn to_json_with(run: &SweepRun, include_timing: bool) -> Json {
    let points: Vec<Json> = run
        .points
        .iter()
        .map(|p| point_json(p, include_timing))
        .collect();
    let cells: Vec<Json> =
        aggregate(run).iter().map(cell_json).collect();
    let total_probes: u64 = run
        .points
        .iter()
        .map(|p| p.result.scheduler_probes)
        .sum();
    let total_hits: u64 = run
        .points
        .iter()
        .map(|p| p.result.plan_cache_hits)
        .sum();
    let mut j = Json::obj()
        .set("n_points", run.points.len())
        .set("scheduler_probes", total_probes)
        .set("plan_cache_hits", total_hits)
        .set("points", Json::Arr(points))
        .set("cells", Json::Arr(cells));
    if include_timing {
        j = j.set("n_threads", run.n_threads).set("wall_s", run.wall_s);
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;
    use crate::sweep::grid::SweepGrid;
    use crate::sweep::runner;
    use crate::util::json;

    fn run_small() -> SweepRun {
        let mut g = SweepGrid::default();
        g.policies = vec![Policy::TLora];
        g.n_jobs = vec![8];
        g.gpus = vec![16];
        g.rate_scales = vec![2.0];
        g.months = vec![1];
        g.seeds = vec![3, 4];
        runner::run(&g, 2).unwrap()
    }

    #[test]
    fn aggregate_pools_seeds() {
        let run = run_small();
        let cells = aggregate(&run);
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].n_seeds, 2);
        assert!(cells[0].throughput.0 > 0.0);
        assert!(cells[0].throughput.1 >= 0.0);
        assert_eq!(cells[0].incomplete, 0);
        // the pooled mean sits between the two replicas
        let a = run.points[0].result.avg_throughput;
        let b = run.points[1].result.avg_throughput;
        let m = cells[0].throughput.0;
        assert!(m >= a.min(b) - 1e-12 && m <= a.max(b) + 1e-12);
    }

    #[test]
    fn csv_has_one_row_per_point() {
        let run = run_small();
        let csv = to_csv(&run);
        assert_eq!(csv.lines().count(), run.points.len() + 1);
        assert!(csv.starts_with("index,policy,"));
    }

    #[test]
    fn json_roundtrips_through_parser() {
        let run = run_small();
        let j = to_json(&run);
        let back = json::parse(&j.to_string()).unwrap();
        assert_eq!(
            back.get("n_points").unwrap().as_usize().unwrap(),
            run.points.len()
        );
        assert_eq!(
            back.get("points").unwrap().as_arr().unwrap().len(),
            run.points.len()
        );
        assert_eq!(back.get("cells").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn table_renders_scenarios() {
        let run = run_small();
        let t = sweep_table("demo", &aggregate(&run));
        let s = t.render();
        assert!(s.contains("tlora/j8/g16/r2x/m1/f0/d0"), "{s}");
    }

    #[test]
    fn canonical_json_carries_no_timing_fields() {
        let run = run_small();
        let full =
            json::parse(&to_json(&run).to_string()).unwrap();
        assert!(full.get("wall_s").is_some());
        assert!(full.get("n_threads").is_some());
        let canon =
            json::parse(&to_json_canonical(&run).to_string()).unwrap();
        assert!(canon.get("wall_s").is_none());
        assert!(canon.get("n_threads").is_none());
        for p in canon.get("points").unwrap().as_arr().unwrap() {
            assert!(p.get("wall_s").is_none());
            assert!(p.get("goodput").is_some());
            assert!(p.get("slo_attainment").is_some());
            assert!(p.get("mtbf_s").is_some());
            assert!(p.get("straggler_mtbs_s").is_some());
            assert!(p.get("straggler_slowdown").is_some());
            assert!(p.get("migrations").is_some());
            assert!(p.get("scheduler_probes").is_some());
            assert!(p.get("plan_cache_hits").is_some());
            assert!(p.get("events_stale").is_some());
        }
        // canonical output is reproducible byte-for-byte
        let again = to_json_canonical(&runner::run(
            &{
                let mut g = SweepGrid::default();
                g.policies = vec![Policy::TLora];
                g.n_jobs = vec![8];
                g.gpus = vec![16];
                g.rate_scales = vec![2.0];
                g.months = vec![1];
                g.seeds = vec![3, 4];
                g
            },
            1,
        )
        .unwrap());
        assert_eq!(
            to_json_canonical(&run).to_pretty(),
            again.to_pretty()
        );
    }

    #[test]
    fn fault_free_cells_report_zero_churn_columns() {
        let run = run_small();
        let cells = aggregate(&run);
        assert_eq!(cells[0].restarts, 0);
        assert_eq!(cells[0].node_failures, 0);
        assert_eq!(cells[0].node_degrades, 0);
        assert_eq!(cells[0].migrations, 0);
        assert_eq!(cells[0].straggler_slowdown.0, 1.0);
        assert!(cells[0].goodput.0 > 0.0);
        assert!(
            (0.0..=1.0).contains(&cells[0].slo_attainment.0),
            "{}",
            cells[0].slo_attainment.0
        );
        let csv = to_csv(&run);
        let header = csv.lines().next().unwrap();
        for col in [
            "mtbf_s",
            "goodput",
            "slo_attainment",
            "restarts",
            "straggler_mtbs_s",
            "node_degrades",
            "degraded_time_s",
            "straggler_slowdown",
            "migrations",
            "events_stale",
            "plan_cache_hits",
        ] {
            assert!(header.contains(col), "{header}");
        }
    }

    #[test]
    fn cells_carry_probe_and_cache_columns() {
        // satellite fix: scheduler_probes was totalled run-wide but
        // missing from the per-cell aggregates — cells now carry
        // probes, cache hits, and the derived hit rate in table, JSON
        // and accessor form
        let run = run_small();
        let cells = aggregate(&run);
        let per_point: u64 = run
            .points
            .iter()
            .map(|p| p.result.scheduler_probes)
            .sum();
        assert_eq!(cells[0].probes, per_point);
        assert!(cells[0].probes > 0, "no planner evaluations at all");
        assert!(
            cells[0].plan_cache_hits > 0,
            "a real simulation must hit the predictor caches"
        );
        let rate = cells[0].cache_hit_rate();
        assert!((0.0..=1.0).contains(&rate), "{rate}");
        let j = crate::util::json::parse(&to_json(&run).to_string())
            .unwrap();
        let cell = &j.get("cells").unwrap().as_arr().unwrap()[0];
        assert_eq!(
            cell.get("scheduler_probes")
                .unwrap()
                .as_i64()
                .unwrap() as u64,
            per_point
        );
        assert!(cell.get("plan_cache_rate").is_some());
        let t = sweep_table("demo", &cells).render();
        assert!(t.contains("probes"), "{t}");
        assert!(t.contains("hit%"), "{t}");
    }

    #[test]
    fn all_incomplete_cell_emits_finite_numbers() {
        // satellite fix: a cell whose every job was cut off has no
        // completed-JCT sample, so its mean/p99 aggregate to NaN —
        // which leaked into the canonical JSON (as `null`, breaking
        // the numeric schema) and rendered literally in table/CSV
        let mut run = run_small();
        for p in &mut run.points {
            p.result.jct.clear();
            p.result.incomplete_jobs = vec![1, 2, 3];
            p.result.mean_jct = f64::NAN;
            p.result.p99_jct = f64::NAN;
            p.result.mean_slowdown = f64::INFINITY;
        }
        let s = to_json_canonical(&run).to_pretty();
        assert!(!s.contains("NaN"), "{s}");
        assert!(!s.contains("null"), "{s}");
        let back = json::parse(&s).unwrap();
        let pt = &back.get("points").unwrap().as_arr().unwrap()[0];
        assert_eq!(pt.get("mean_jct").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(
            pt.get("mean_slowdown").unwrap().as_f64().unwrap(),
            0.0
        );
        let cell = &back.get("cells").unwrap().as_arr().unwrap()[0];
        let mj = cell.get("mean_jct").unwrap().as_arr().unwrap();
        assert_eq!(mj[0].as_f64().unwrap(), 0.0);
        let csv = to_csv(&run);
        assert!(!csv.contains("NaN") && !csv.contains("inf"), "{csv}");
        let cells = aggregate(&run);
        let t = sweep_table("demo", &cells).render();
        assert!(!t.contains("NaN"), "{t}");
        assert!(t.contains("UNFINISHED"), "{t}");
    }

    fn run_mixed() -> SweepRun {
        let mut g = SweepGrid::default();
        g.policies = vec![Policy::TLora];
        g.n_jobs = vec![6];
        g.gpus = vec![16];
        g.rate_scales = vec![2.0];
        g.months = vec![1];
        g.hardware_mixes = vec!["a100:v100".into()];
        g.seeds = vec![3];
        runner::run(&g, 1).unwrap()
    }

    #[test]
    fn tier_columns_appear_only_for_mixed_cells() {
        // homogeneous sweeps keep the pre-tier schema byte-for-byte
        let homo = run_small();
        let header =
            to_csv(&homo).lines().next().unwrap().to_string();
        assert!(!header.contains("hardware_mix"), "{header}");
        assert!(!header.contains("tier_util"), "{header}");
        let j = json::parse(&to_json_canonical(&homo).to_string())
            .unwrap();
        let pt = &j.get("points").unwrap().as_arr().unwrap()[0];
        assert!(pt.get("hardware_mix").is_none());
        assert!(pt.get("tier_util").is_none());
        assert!(aggregate(&homo)[0].tier_util.is_empty());

        // mixed sweeps carry the gated columns end to end
        let mixed = run_mixed();
        let csv = to_csv(&mixed);
        let header = csv.lines().next().unwrap();
        assert!(
            header.contains("hardware_mix")
                && header.contains("tier_util"),
            "{header}"
        );
        assert!(csv.contains("a100:v100"), "{csv}");
        let j = json::parse(&to_json_canonical(&mixed).to_string())
            .unwrap();
        let pt = &j.get("points").unwrap().as_arr().unwrap()[0];
        assert_eq!(
            pt.get("hardware_mix").unwrap().as_str().unwrap(),
            "a100:v100"
        );
        let tu = pt.get("tier_util").unwrap().as_arr().unwrap();
        assert_eq!(tu.len(), 2);
        assert_eq!(
            tu[0].get("tier").unwrap().as_str().unwrap(),
            "a100"
        );
        assert_eq!(
            tu[1].get("tier").unwrap().as_str().unwrap(),
            "v100"
        );
        let cells = aggregate(&mixed);
        assert_eq!(cells[0].tier_util.len(), 2);
        assert!(
            cells[0].key.ends_with("/ha100:v100"),
            "{}",
            cells[0].key
        );
        for (name, (m, _)) in &cells[0].tier_util {
            assert!(
                (0.0..=1.0).contains(m),
                "{name} utilization {m} out of [0,1]"
            );
        }
        let t = sweep_table("demo", &cells).render();
        assert!(t.contains("tier util"), "{t}");
        assert!(t.contains("a100:"), "{t}");
    }

    fn run_gpufaults() -> SweepRun {
        let mut g = SweepGrid::default();
        g.policies = vec![Policy::TLora];
        g.n_jobs = vec![8];
        g.gpus = vec![16];
        g.rate_scales = vec![2.0];
        g.months = vec![1];
        g.gpu_mtbfs = vec![20_000.0];
        g.seeds = vec![3];
        runner::run(&g, 1).unwrap()
    }

    #[test]
    fn gpu_fault_columns_appear_only_for_fault_cells() {
        // fault-free sweeps keep the pre-GPU-fault schema byte-for-byte
        let clean = run_small();
        let header =
            to_csv(&clean).lines().next().unwrap().to_string();
        assert!(!header.contains("gpu_mtbf_s"), "{header}");
        assert!(!header.contains("gpu_failures"), "{header}");
        assert!(!header.contains("holed_gpu_time_s"), "{header}");
        let j = json::parse(&to_json_canonical(&clean).to_string())
            .unwrap();
        let pt = &j.get("points").unwrap().as_arr().unwrap()[0];
        assert!(pt.get("gpu_mtbf_s").is_none());
        assert!(pt.get("gpu_failures").is_none());
        let cell = &j.get("cells").unwrap().as_arr().unwrap()[0];
        assert!(cell.get("gpu_failures").is_none());
        assert_eq!(aggregate(&clean)[0].gpu_failures, 0);

        // GPU-fault sweeps carry the gated columns end to end
        let faulty = run_gpufaults();
        let csv = to_csv(&faulty);
        let header = csv.lines().next().unwrap();
        assert!(
            header.contains("gpu_mtbf_s")
                && header.contains("gpu_failures")
                && header.contains("holed_gpu_time_s"),
            "{header}"
        );
        let j = json::parse(&to_json_canonical(&faulty).to_string())
            .unwrap();
        let pt = &j.get("points").unwrap().as_arr().unwrap()[0];
        assert_eq!(
            pt.get("gpu_mtbf_s").unwrap().as_f64().unwrap(),
            20_000.0
        );
        assert!(pt.get("gpu_failures").is_some());
        assert!(pt.get("holed_gpu_time_s").is_some());
        let cells = aggregate(&faulty);
        assert!(
            cells[0].key.contains("/G20000"),
            "{}",
            cells[0].key
        );
        let cell = &j.get("cells").unwrap().as_arr().unwrap()[0];
        assert!(cell.get("gpu_failures").is_some());
        let t = sweep_table("demo", &cells).render();
        assert!(t.contains("gpu fails"), "{t}");
    }

    fn run_shrink() -> SweepRun {
        let mut g = SweepGrid::default();
        g.policies = vec![Policy::TLora];
        g.n_jobs = vec![8];
        g.gpus = vec![16];
        g.rate_scales = vec![2.0];
        g.months = vec![1];
        g.gpu_mtbfs = vec![20_000.0];
        g.shrinks = vec![true];
        g.seeds = vec![3];
        runner::run(&g, 1).unwrap()
    }

    #[test]
    fn shrink_columns_appear_only_for_shrink_cells() {
        // evict-semantics sweeps keep the pre-shrink schema
        // byte-for-byte
        let off = run_small();
        let header =
            to_csv(&off).lines().next().unwrap().to_string();
        assert!(!header.contains("shrink"), "{header}");
        assert!(!header.contains("regrows"), "{header}");
        let j = json::parse(&to_json_canonical(&off).to_string())
            .unwrap();
        let pt = &j.get("points").unwrap().as_arr().unwrap()[0];
        assert!(pt.get("shrink").is_none());
        assert!(pt.get("shrinks").is_none());
        let cell = &j.get("cells").unwrap().as_arr().unwrap()[0];
        assert!(cell.get("shrinks").is_none());
        assert_eq!(aggregate(&off)[0].shrinks, 0);

        // shrink sweeps carry the gated columns end to end
        let on = run_shrink();
        let csv = to_csv(&on);
        let header = csv.lines().next().unwrap();
        assert!(
            header.contains("shrink")
                && header.contains("shrinks")
                && header.contains("regrows")
                && header.contains("degraded_rate_time_s"),
            "{header}"
        );
        let j = json::parse(&to_json_canonical(&on).to_string())
            .unwrap();
        let pt = &j.get("points").unwrap().as_arr().unwrap()[0];
        assert!(pt.get("shrink").unwrap().as_bool().unwrap());
        assert!(pt.get("shrinks").is_some());
        assert!(pt.get("regrows").is_some());
        assert!(pt.get("degraded_rate_time_s").is_some());
        let cells = aggregate(&on);
        assert!(cells[0].key.ends_with("/S1"), "{}", cells[0].key);
        let cell = &j.get("cells").unwrap().as_arr().unwrap()[0];
        assert!(cell.get("shrinks").is_some());
        let t = sweep_table("demo", &cells).render();
        assert!(t.contains("shrinks"), "{t}");
        assert!(t.contains("regrown"), "{t}");
    }

    fn run_topo() -> SweepRun {
        let mut g = SweepGrid::default();
        g.policies = vec![Policy::TLora];
        g.n_jobs = vec![6];
        g.gpus = vec![32];
        g.rate_scales = vec![2.0];
        g.months = vec![1];
        g.topologies = vec!["racks=4:rack_bw=0.5".into()];
        g.seeds = vec![3];
        runner::run(&g, 1).unwrap()
    }

    #[test]
    fn topology_columns_appear_only_for_topo_cells() {
        // flat sweeps keep the pre-topology schema byte-for-byte
        let flat = run_small();
        let header =
            to_csv(&flat).lines().next().unwrap().to_string();
        assert!(!header.contains("topology"), "{header}");
        assert!(!header.contains("rack_span"), "{header}");
        let j = json::parse(&to_json_canonical(&flat).to_string())
            .unwrap();
        let pt = &j.get("points").unwrap().as_arr().unwrap()[0];
        assert!(pt.get("topology").is_none());
        assert!(pt.get("rack_span_mean").is_none());
        let cell = &j.get("cells").unwrap().as_arr().unwrap()[0];
        assert!(cell.get("topology").is_none());

        // topology sweeps carry the gated columns end to end
        let topo = run_topo();
        let csv = to_csv(&topo);
        let header = csv.lines().next().unwrap();
        assert!(
            header.contains("topology")
                && header.contains("rack_span_mean")
                && header.contains("rack_span_max"),
            "{header}"
        );
        assert!(csv.contains("racks=4:rack_bw=0.5"), "{csv}");
        let j = json::parse(&to_json_canonical(&topo).to_string())
            .unwrap();
        let pt = &j.get("points").unwrap().as_arr().unwrap()[0];
        assert_eq!(
            pt.get("topology").unwrap().as_str().unwrap(),
            "racks=4:rack_bw=0.5"
        );
        let span =
            pt.get("rack_span_mean").unwrap().as_f64().unwrap();
        assert!(span >= 1.0, "no gang ever observed: {span}");
        let cells = aggregate(&topo);
        assert!(
            cells[0].key.ends_with("/tracks=4:rack_bw=0.5"),
            "{}",
            cells[0].key
        );
        assert!(cells[0].rack_span_max >= 1);
        assert!(cells[0].rack_span_mean.0 >= 1.0);
        let t = sweep_table("demo", &cells).render();
        assert!(t.contains("rack span"), "{t}");
    }
}
