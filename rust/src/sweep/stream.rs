//! Streaming sweep reports: emit-as-you-aggregate in O(1) memory per
//! point (DESIGN.md §Streaming reports).
//!
//! The legacy path ([`super::report`]) materializes every point, then
//! a full `Json` tree — O(points) memory twice over, which caps sweep
//! scale far below the million-arrival north star. This module emits
//! each row the moment [`super::runner::run_streaming`] delivers it
//! (in strict grid-index order — the reorder buffer makes that
//! deterministic at any thread count) and aggregates cells online
//! with [`Welford`] accumulators.
//!
//! **Byte contract:** every form this module writes — pretty JSON
//! (canonical and timing), CSV, and the aligned table — is
//! byte-identical to the legacy full-tree writer. That holds by
//! construction, not by luck:
//! - per-row subtrees come from the *same* builders
//!   ([`super::report::point_json`] / [`cell_json`] /
//!   [`csv_point_row`]) and are spliced into a hand-emitted envelope
//!   that reproduces `Json::to_pretty`'s exact whitespace;
//! - cell statistics use [`Welford`] accumulators, and the legacy
//!   `mean_ci95` *is* a Welford fold over the same values in the same
//!   order — bitwise-equal results;
//! - cells accumulate in a first-appearance-ordered vector with a
//!   key→index map, so replicas fold in global arrival order even
//!   when duplicated axis values split a cell across non-adjacent
//!   runs — exactly the order `aggregate`'s buckets see, hence
//!   bitwise-equal statistics and the same first-appearance emission
//!   order.
//!
//! Sorted-key JSON puts `cells` before `points`, but a cell only
//! finalizes once no later replica can still arrive — at `finish`.
//! Cells therefore hold O(cells) accumulator state (which the table
//! form needs anyway) while points stream to a [`Spool`] (a temp file
//! for the CLI/bench, memory for tests) that is spliced — via a fixed
//! 64 KiB buffer — after the cells section at `finish`. Peak memory
//! is O(cells + threads), independent of point count; the
//! `report_scaling` bench gates this with a counting allocator.

use std::collections::HashMap;
use std::io::{self, Read, Seek, Write};
use std::path::PathBuf;

use super::grid::SweepGrid;
use super::report::{
    cell_json, csv_headers, csv_point_row, point_json, CellSummary,
};
use super::runner::{run_streaming, PointResult, StreamStats};
use crate::metrics::csv_row;
use crate::util::json::Json;
use crate::util::stats::Welford;

fn bad_data(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Where the streaming JSON writer parks the `points` section until
/// the `cells` section (which sorts first) has fully streamed.
pub enum Spool {
    /// In-memory buffer — tests and callers that want the bytes back.
    Memory(Vec<u8>),
    /// On-disk temp file — the O(1)-memory path for CLI and benches.
    /// Removed after splicing.
    File {
        w: io::BufWriter<std::fs::File>,
        path: PathBuf,
    },
}

impl Spool {
    pub fn memory() -> Spool {
        Spool::Memory(Vec::new())
    }

    /// Create (truncating) a read+write temp file at `path`.
    pub fn file(path: impl Into<PathBuf>) -> io::Result<Spool> {
        let path = path.into();
        let f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)?;
        Ok(Spool::File {
            w: io::BufWriter::new(f),
            path,
        })
    }

    fn write_all(&mut self, bytes: &[u8]) -> io::Result<()> {
        match self {
            Spool::Memory(buf) => {
                buf.extend_from_slice(bytes);
                Ok(())
            }
            Spool::File { w, .. } => w.write_all(bytes),
        }
    }

    /// Copy the spooled bytes into `out` through a fixed-size buffer
    /// and release the backing storage.
    fn splice_into(self, out: &mut dyn Write) -> io::Result<()> {
        match self {
            Spool::Memory(buf) => out.write_all(&buf),
            Spool::File { w, path } => {
                let mut f =
                    w.into_inner().map_err(|e| e.into_error())?;
                f.rewind()?;
                let mut buf = [0u8; 64 * 1024];
                loop {
                    let n = f.read(&mut buf)?;
                    if n == 0 {
                        break;
                    }
                    out.write_all(&buf[..n])?;
                }
                drop(f);
                let _ = std::fs::remove_file(&path);
                Ok(())
            }
        }
    }
}

/// Run-wide totals the JSON envelope needs at `finish`.
struct StreamTotals {
    n_points: usize,
    scheduler_probes: u64,
    plan_cache_hits: u64,
    /// `Some` only for the timing (non-canonical) form.
    n_threads: Option<usize>,
    wall_s: Option<f64>,
}

/// Streams the pretty-JSON report envelope: cells directly to `out`,
/// points to the spool, totals stitched in at [`finish`]. The bytes
/// match `to_json{_canonical}(run).to_pretty()` exactly (sorted top
/// keys: `cells`, `n_points`, [`n_threads`], `plan_cache_hits`,
/// `points`, `scheduler_probes`, [`wall_s`]).
///
/// [`finish`]: StreamJsonWriter::finish
pub struct StreamJsonWriter<'a> {
    out: &'a mut dyn Write,
    spool: Spool,
    n_cells: usize,
    n_points: usize,
}

impl<'a> StreamJsonWriter<'a> {
    pub fn new(out: &'a mut dyn Write, spool: Spool) -> Self {
        StreamJsonWriter {
            out,
            spool,
            n_cells: 0,
            n_points: 0,
        }
    }

    fn cell(&mut self, j: &Json) -> io::Result<()> {
        if self.n_cells == 0 {
            self.out.write_all(b"{\n  \"cells\": [\n    ")?;
        } else {
            self.out.write_all(b",\n    ")?;
        }
        self.out.write_all(j.to_pretty_at(2).as_bytes())?;
        self.n_cells += 1;
        Ok(())
    }

    fn point(&mut self, j: &Json) -> io::Result<()> {
        if self.n_points == 0 {
            self.spool.write_all(b"    ")?;
        } else {
            self.spool.write_all(b",\n    ")?;
        }
        self.spool.write_all(j.to_pretty_at(2).as_bytes())?;
        self.n_points += 1;
        Ok(())
    }

    fn finish(self, totals: &StreamTotals) -> io::Result<()> {
        let StreamJsonWriter {
            out,
            spool,
            n_cells,
            n_points,
        } = self;
        if n_cells == 0 {
            out.write_all(b"{\n  \"cells\": [],\n")?;
        } else {
            out.write_all(b"\n  ],\n")?;
        }
        out.write_all(
            format!("  \"n_points\": {},\n", totals.n_points)
                .as_bytes(),
        )?;
        if let Some(t) = totals.n_threads {
            out.write_all(
                format!("  \"n_threads\": {t},\n").as_bytes(),
            )?;
        }
        out.write_all(
            format!(
                "  \"plan_cache_hits\": {},\n",
                totals.plan_cache_hits
            )
            .as_bytes(),
        )?;
        if n_points == 0 {
            out.write_all(b"  \"points\": [],\n")?;
        } else {
            out.write_all(b"  \"points\": [\n")?;
            spool.splice_into(out)?;
            out.write_all(b"\n  ],\n")?;
        }
        out.write_all(
            format!(
                "  \"scheduler_probes\": {}",
                totals.scheduler_probes
            )
            .as_bytes(),
        )?;
        if let Some(w) = totals.wall_s {
            // route through the Json writer so float bytes match
            out.write_all(
                format!(
                    ",\n  \"wall_s\": {}\n",
                    Json::Num(w).to_string()
                )
                .as_bytes(),
            )?;
        } else {
            out.write_all(b"\n")?;
        }
        out.write_all(b"}\n")?;
        out.flush()
    }
}

/// Online per-cell aggregation: one [`Welford`] per CI-pair metric,
/// plain sums for counters — the streaming equivalent of
/// [`super::report::aggregate`]'s per-bucket computation, fed in the
/// same (grid-index) order so the results are bitwise equal.
struct CellAcc {
    key: String,
    point: super::grid::SweepPoint,
    n_seeds: usize,
    throughput: Welford,
    mean_jct: Welford,
    p99_jct: Welford,
    gpu_util: Welford,
    makespan: Welford,
    mean_slowdown: Welford,
    goodput: Welford,
    slo_attainment: Welford,
    straggler_slowdown: Welford,
    restarts: u64,
    node_failures: u64,
    gpu_failures: u64,
    holed_gpu_time_s: Welford,
    node_degrades: u64,
    migrations: u64,
    probes: u64,
    plan_cache_hits: u64,
    incomplete: usize,
    /// tier names fixed by the cell's first replica (legacy rule)
    tier_names: Vec<String>,
    tier_utils: Vec<Welford>,
    rack_span_mean: Welford,
    rack_span_max: u64,
    shrinks: u64,
    regrows: u64,
    degraded_rate_time_s: Welford,
}

impl CellAcc {
    fn new(key: String, p: &PointResult) -> CellAcc {
        let tier_names: Vec<String> = p
            .result
            .tier_util
            .iter()
            .map(|(n, _)| n.clone())
            .collect();
        let tier_utils =
            vec![Welford::default(); tier_names.len()];
        let mut acc = CellAcc {
            key,
            point: p.point.clone(),
            n_seeds: 0,
            throughput: Welford::default(),
            mean_jct: Welford::default(),
            p99_jct: Welford::default(),
            gpu_util: Welford::default(),
            makespan: Welford::default(),
            mean_slowdown: Welford::default(),
            goodput: Welford::default(),
            slo_attainment: Welford::default(),
            straggler_slowdown: Welford::default(),
            restarts: 0,
            node_failures: 0,
            gpu_failures: 0,
            holed_gpu_time_s: Welford::default(),
            node_degrades: 0,
            migrations: 0,
            probes: 0,
            plan_cache_hits: 0,
            incomplete: 0,
            tier_names,
            tier_utils,
            rack_span_mean: Welford::default(),
            rack_span_max: 0,
            shrinks: 0,
            regrows: 0,
            degraded_rate_time_s: Welford::default(),
        };
        acc.push(p);
        acc
    }

    fn push(&mut self, p: &PointResult) {
        // raw (un-clamped) values, exactly like the legacy column
        // closures — fin() stays an emission-time concern
        self.n_seeds += 1;
        self.throughput.add(p.result.avg_throughput);
        self.mean_jct.add(p.result.mean_jct);
        self.p99_jct.add(p.result.p99_jct);
        self.gpu_util.add(p.result.avg_gpu_util);
        self.makespan.add(p.result.makespan);
        self.mean_slowdown.add(p.result.mean_slowdown);
        self.goodput.add(p.result.goodput);
        self.slo_attainment.add(p.result.slo_attainment);
        self.straggler_slowdown.add(p.result.straggler_slowdown);
        self.restarts += p.result.restarts;
        self.node_failures += p.result.node_failures;
        self.gpu_failures += p.result.gpu_failures;
        self.holed_gpu_time_s.add(p.result.holed_gpu_time_s);
        self.node_degrades += p.result.node_degrades;
        self.migrations += p.result.migrations;
        self.probes += p.result.scheduler_probes;
        self.plan_cache_hits += p.result.plan_cache_hits;
        self.incomplete += p.result.incomplete_jobs.len();
        for (i, w) in self.tier_utils.iter_mut().enumerate() {
            w.add(
                p.result
                    .tier_util
                    .get(i)
                    .map_or(0.0, |&(_, u)| u),
            );
        }
        self.rack_span_mean.add(p.result.rack_span_mean);
        self.rack_span_max =
            self.rack_span_max.max(p.result.rack_span_max);
        self.shrinks += p.result.shrinks;
        self.regrows += p.result.regrows;
        self.degraded_rate_time_s
            .add(p.result.degraded_rate_time_s);
    }

    fn finalize(self) -> CellSummary {
        CellSummary {
            key: self.key,
            point: self.point,
            n_seeds: self.n_seeds,
            throughput: self.throughput.mean_ci95(),
            mean_jct: self.mean_jct.mean_ci95(),
            p99_jct: self.p99_jct.mean_ci95(),
            gpu_util: self.gpu_util.mean_ci95(),
            makespan: self.makespan.mean_ci95(),
            mean_slowdown: self.mean_slowdown.mean_ci95(),
            goodput: self.goodput.mean_ci95(),
            slo_attainment: self.slo_attainment.mean_ci95(),
            straggler_slowdown: self
                .straggler_slowdown
                .mean_ci95(),
            restarts: self.restarts,
            node_failures: self.node_failures,
            gpu_failures: self.gpu_failures,
            holed_gpu_time_s: self.holed_gpu_time_s.mean_ci95(),
            node_degrades: self.node_degrades,
            migrations: self.migrations,
            probes: self.probes,
            plan_cache_hits: self.plan_cache_hits,
            incomplete: self.incomplete,
            tier_util: self
                .tier_names
                .into_iter()
                .zip(
                    self.tier_utils
                        .into_iter()
                        .map(|w| w.mean_ci95()),
                )
                .collect(),
            rack_span_mean: self.rack_span_mean.mean_ci95(),
            rack_span_max: self.rack_span_max,
            shrinks: self.shrinks,
            regrows: self.regrows,
            degraded_rate_time_s: self
                .degraded_rate_time_s
                .mean_ci95(),
        }
    }
}

/// The emit-as-you-aggregate report core. Feed it [`PointResult`]s in
/// strict grid-index order (what [`run_streaming`] delivers); each
/// point is written to the attached sinks immediately and folded into
/// its cell accumulator (looked up by key, so duplicated axis values
/// that revisit a cell non-adjacently simply merge), then freed.
/// `finish` finalizes the cells in first-appearance order, closes the
/// JSON envelope and returns the aggregated cells (O(cells) — the
/// only thing the table form needs to buffer, since an aligned table
/// requires global column widths).
pub struct StreamReport<'a> {
    het: bool,
    topo: bool,
    gpufaults: bool,
    shrink: bool,
    include_timing: bool,
    json: Option<StreamJsonWriter<'a>>,
    csv: Option<&'a mut dyn Write>,
    csv_header_written: bool,
    accs: Vec<CellAcc>,
    key_index: HashMap<String, usize>,
    total_probes: u64,
    total_hits: u64,
    n_points: usize,
}

impl<'a> StreamReport<'a> {
    /// `include_timing` selects the timing JSON form (per-point and
    /// total `wall_s`, `n_threads`) vs the canonical form; it has no
    /// effect on CSV/table output.
    pub fn new(grid: &SweepGrid, include_timing: bool) -> Self {
        StreamReport {
            het: grid.is_heterogeneous(),
            topo: grid.has_topology(),
            gpufaults: grid.has_gpu_faults(),
            shrink: grid.has_shrink(),
            include_timing,
            json: None,
            csv: None,
            csv_header_written: false,
            accs: Vec::new(),
            key_index: HashMap::new(),
            total_probes: 0,
            total_hits: 0,
            n_points: 0,
        }
    }

    /// Attach a JSON sink; `spool` buffers the `points` section (use
    /// [`Spool::file`] for O(1) memory, [`Spool::memory`] in tests).
    pub fn with_json(
        mut self,
        out: &'a mut dyn Write,
        spool: Spool,
    ) -> Self {
        self.json = Some(StreamJsonWriter::new(out, spool));
        self
    }

    /// Attach a CSV sink (header written with the first row).
    pub fn with_csv(mut self, out: &'a mut dyn Write) -> Self {
        self.csv = Some(out);
        self
    }

    fn ensure_csv_header(&mut self) -> io::Result<()> {
        if self.csv_header_written {
            return Ok(());
        }
        if let Some(out) = self.csv.as_mut() {
            let headers: Vec<String> = csv_headers(
                self.het,
                self.topo,
                self.gpufaults,
                self.shrink,
            )
            .iter()
            .map(|h| h.to_string())
            .collect();
            out.write_all(csv_row(&headers).as_bytes())?;
            out.write_all(b"\n")?;
        }
        self.csv_header_written = true;
        Ok(())
    }

    /// Ingest the next point (must arrive in strict index order).
    pub fn point(&mut self, p: &PointResult) -> io::Result<()> {
        if p.point.index != self.n_points {
            return Err(bad_data(format!(
                "streaming report fed out of order: got index {}, \
                 expected {} — results must arrive in grid order",
                p.point.index, self.n_points
            )));
        }
        self.n_points += 1;
        self.total_probes += p.result.scheduler_probes;
        self.total_hits += p.result.plan_cache_hits;

        // online aggregation: replicas fold into their cell's
        // accumulator in global arrival order — the same order the
        // legacy `aggregate` buckets see, whether or not the cell's
        // replicas are contiguous (duplicated axis values aren't)
        let key = p.point.cell_key();
        match self.key_index.get(&key) {
            Some(&i) => self.accs[i].push(p),
            None => {
                self.key_index.insert(key.clone(), self.accs.len());
                self.accs.push(CellAcc::new(key, p));
            }
        }

        if let Some(json) = self.json.as_mut() {
            json.point(&point_json(p, self.include_timing))?;
        }
        if self.csv.is_some() {
            self.ensure_csv_header()?;
            let row = csv_point_row(
                p,
                self.het,
                self.topo,
                self.gpufaults,
                self.shrink,
            );
            let out = self.csv.as_mut().unwrap();
            out.write_all(csv_row(&row).as_bytes())?;
            out.write_all(b"\n")?;
        }
        Ok(())
    }

    /// Finalize every cell in first-appearance order, close the JSON
    /// envelope, flush CSV, and return the aggregated cells in
    /// emission order (identical to [`super::report::aggregate`] on
    /// the collected run).
    pub fn finish(
        mut self,
        n_threads: usize,
        wall_s: f64,
    ) -> io::Result<Vec<CellSummary>> {
        let mut cells = Vec::with_capacity(self.accs.len());
        for acc in std::mem::take(&mut self.accs) {
            let c = acc.finalize();
            if let Some(json) = self.json.as_mut() {
                json.cell(&cell_json(&c))?;
            }
            cells.push(c);
        }
        if let Some(json) = self.json.take() {
            let totals = StreamTotals {
                n_points: self.n_points,
                scheduler_probes: self.total_probes,
                plan_cache_hits: self.total_hits,
                n_threads: self
                    .include_timing
                    .then_some(n_threads),
                wall_s: self.include_timing.then_some(wall_s),
            };
            json.finish(&totals)?;
        }
        if self.csv.is_some() {
            self.ensure_csv_header()?; // header even for empty grids
            self.csv.as_mut().unwrap().flush()?;
        }
        Ok(cells)
    }
}

/// CLI/bench convenience: run `grid` with the streaming executor,
/// writing the requested report files as points complete. Returns the
/// aggregated cells (for the table) and the run stats. `json`
/// carries `(path, canonical)`; the points spool lives next to the
/// JSON file as `<path>.points.tmp` and is removed after splicing.
/// On error a partially-written file may remain (the legacy path
/// writes nothing until the end — that is exactly the O(points)
/// buffering this module exists to avoid).
pub fn run_streaming_report(
    grid: &SweepGrid,
    n_threads: usize,
    json: Option<(&std::path::Path, bool)>,
    csv: Option<&std::path::Path>,
) -> Result<(Vec<CellSummary>, StreamStats), String> {
    let include_timing =
        json.is_some_and(|(_, canonical)| !canonical);
    let mut jfile = match json {
        Some((p, _)) => Some(io::BufWriter::new(
            std::fs::File::create(p).map_err(|e| {
                format!("write {}: {e}", p.display())
            })?,
        )),
        None => None,
    };
    let mut spool = match json {
        Some((p, _)) => {
            let mut os = p.as_os_str().to_owned();
            os.push(".points.tmp");
            Some(Spool::file(PathBuf::from(os)).map_err(|e| {
                format!("spool for {}: {e}", p.display())
            })?)
        }
        None => None,
    };
    let mut cfile = match csv {
        Some(p) => Some(io::BufWriter::new(
            std::fs::File::create(p).map_err(|e| {
                format!("write {}: {e}", p.display())
            })?,
        )),
        None => None,
    };

    let mut report = StreamReport::new(grid, include_timing);
    if let Some(f) = jfile.as_mut() {
        report = report.with_json(f, spool.take().unwrap());
    }
    if let Some(f) = cfile.as_mut() {
        report = report.with_csv(f);
    }
    let stats = run_streaming(grid, n_threads, &mut |pr| {
        report
            .point(&pr)
            .map_err(|e| format!("report emission: {e}"))
    })?;
    let cells = report
        .finish(stats.n_threads, stats.wall_s)
        .map_err(|e| format!("report finish: {e}"))?;
    if let Some(mut f) = jfile {
        f.flush().map_err(|e| format!("flush json report: {e}"))?;
    }
    if let Some(mut f) = cfile {
        f.flush().map_err(|e| format!("flush csv report: {e}"))?;
    }
    Ok((cells, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;
    use crate::sweep::report::{
        aggregate, sweep_table, to_csv, to_json, to_json_canonical,
    };
    use crate::sweep::runner;
    use crate::sweep::SweepGrid;

    fn small_grid() -> SweepGrid {
        let mut g = SweepGrid::default();
        g.policies = vec![Policy::TLora, Policy::Megatron];
        g.n_jobs = vec![8];
        g.gpus = vec![16];
        g.rate_scales = vec![2.0];
        g.months = vec![1];
        g.seeds = vec![3, 4];
        g
    }

    /// Feed a collected run through the streaming writer with memory
    /// sinks; returns (json, csv, cells).
    fn stream_all(
        g: &SweepGrid,
        run: &runner::SweepRun,
        include_timing: bool,
    ) -> (String, String, Vec<CellSummary>) {
        let mut jbuf: Vec<u8> = Vec::new();
        let mut cbuf: Vec<u8> = Vec::new();
        let cells = {
            let mut rep = StreamReport::new(g, include_timing)
                .with_json(&mut jbuf, Spool::memory())
                .with_csv(&mut cbuf);
            for p in &run.points {
                rep.point(p).unwrap();
            }
            rep.finish(run.n_threads, run.wall_s).unwrap()
        };
        (
            String::from_utf8(jbuf).unwrap(),
            String::from_utf8(cbuf).unwrap(),
            cells,
        )
    }

    #[test]
    fn streaming_json_matches_legacy_bytes() {
        let g = small_grid();
        let run = runner::run(&g, 2).unwrap();
        let (canon, csv, cells) = stream_all(&g, &run, false);
        assert_eq!(canon, to_json_canonical(&run).to_pretty());
        assert_eq!(csv, to_csv(&run));
        let legacy = aggregate(&run);
        assert_eq!(
            sweep_table("t", &cells).render(),
            sweep_table("t", &legacy).render()
        );
        // timing form too (same PointResults → same wall_s bytes)
        let (timed, _, _) = stream_all(&g, &run, true);
        assert_eq!(timed, to_json(&run).to_pretty());
    }

    #[test]
    fn streaming_matches_legacy_on_heterogeneous_grid() {
        let mut g = small_grid();
        g.hardware_mixes = vec!["a100:v100".into()];
        g.seeds = vec![3];
        let run = runner::run(&g, 1).unwrap();
        let (canon, csv, cells) = stream_all(&g, &run, false);
        assert_eq!(canon, to_json_canonical(&run).to_pretty());
        assert_eq!(csv, to_csv(&run));
        assert!(csv.lines().next().unwrap().contains("tier_util"));
        assert_eq!(
            sweep_table("t", &cells).render(),
            sweep_table("t", &aggregate(&run)).render()
        );
    }

    #[test]
    fn file_spool_splices_identically() {
        let g = small_grid();
        let run = runner::run(&g, 1).unwrap();
        let tmp = std::env::temp_dir()
            .join("tlora_stream_spool_test.points.tmp");
        let mut jbuf: Vec<u8> = Vec::new();
        {
            let mut rep = StreamReport::new(&g, false)
                .with_json(&mut jbuf, Spool::file(&tmp).unwrap());
            for p in &run.points {
                rep.point(p).unwrap();
            }
            rep.finish(run.n_threads, run.wall_s).unwrap();
        }
        assert_eq!(
            String::from_utf8(jbuf).unwrap(),
            to_json_canonical(&run).to_pretty()
        );
        assert!(!tmp.exists(), "spool temp file not cleaned up");
    }

    #[test]
    fn out_of_order_rejected_but_revisited_cells_merge() {
        let g = small_grid();
        let run = runner::run(&g, 1).unwrap();
        // out of order still hard-errors: the reorder buffer is the
        // only thing that makes multi-threaded streaming deterministic
        let mut rep = StreamReport::new(&g, false);
        let err =
            rep.point(&run.points[1]).unwrap_err().to_string();
        assert!(err.contains("out of order"), "{err}");
        // a cell key reappearing non-adjacently (replay point 0 after
        // point 2 opened a new cell) used to hard-error; it now folds
        // into the original accumulator
        let mut rep = StreamReport::new(&g, false);
        rep.point(&run.points[0]).unwrap();
        rep.point(&run.points[1]).unwrap();
        rep.point(&run.points[2]).unwrap();
        let mut replay = run.points[0].clone();
        replay.point.index = 3;
        rep.point(&replay).unwrap();
        let cells = rep.finish(1, 0.0).unwrap();
        assert_eq!(cells.len(), 2, "revisit must not open a new cell");
        assert_eq!(cells[0].n_seeds, 3);
        assert_eq!(cells[1].n_seeds, 1);
    }

    #[test]
    fn duplicate_axis_grid_streams_byte_identical_to_legacy() {
        // regression (satellite): a grid whose gpus axis repeats a
        // value splits each repeated cell across non-adjacent index
        // runs; the streaming path used to reject this — it must now
        // aggregate the revisited cells and still match the legacy
        // report byte for byte
        let mut g = small_grid();
        g.gpus = vec![16, 32, 16];
        let run = runner::run(&g, 2).unwrap();
        let (canon, csv, cells) = stream_all(&g, &run, false);
        assert_eq!(canon, to_json_canonical(&run).to_pretty());
        assert_eq!(csv, to_csv(&run));
        let legacy = aggregate(&run);
        assert_eq!(cells.len(), legacy.len());
        // the duplicated cell pools all four replicas (2 seeds × 2
        // appearances), like the legacy bucket fold
        assert_eq!(cells[0].n_seeds, 4);
        assert_eq!(
            sweep_table("t", &cells).render(),
            sweep_table("t", &legacy).render()
        );
    }

    #[test]
    fn streaming_matches_legacy_on_topology_grid() {
        let mut g = small_grid();
        g.topologies = vec!["racks=4:rack_bw=0.5".into()];
        g.gpus = vec![32];
        g.seeds = vec![3];
        let run = runner::run(&g, 1).unwrap();
        let (canon, csv, cells) = stream_all(&g, &run, false);
        assert_eq!(canon, to_json_canonical(&run).to_pretty());
        assert_eq!(csv, to_csv(&run));
        let header = csv.lines().next().unwrap();
        assert!(
            header.contains("topology")
                && header.contains("rack_span_mean"),
            "{header}"
        );
        assert_eq!(
            sweep_table("t", &cells).render(),
            sweep_table("t", &aggregate(&run)).render()
        );
    }

    #[test]
    fn streaming_matches_legacy_on_gpu_fault_grid() {
        // the grid-derived has_gpu_faults() gate must agree with the
        // legacy writers' any-point check, and the gated columns must
        // stream byte-identically
        let mut g = small_grid();
        g.gpu_mtbfs = vec![0.0, 20_000.0];
        g.seeds = vec![3];
        let run = runner::run(&g, 1).unwrap();
        let (canon, csv, cells) = stream_all(&g, &run, false);
        assert_eq!(canon, to_json_canonical(&run).to_pretty());
        assert_eq!(csv, to_csv(&run));
        let header = csv.lines().next().unwrap();
        assert!(
            header.contains("gpu_mtbf_s")
                && header.contains("gpu_failures")
                && header.contains("holed_gpu_time_s"),
            "{header}"
        );
        assert_eq!(
            sweep_table("t", &cells).render(),
            sweep_table("t", &aggregate(&run)).render()
        );
    }

    #[test]
    fn streaming_matches_legacy_on_shrink_grid() {
        // the grid-derived has_shrink() gate must agree with the
        // legacy writers' any-point check, and the gated shrink
        // columns must stream byte-identically
        let mut g = small_grid();
        g.gpu_mtbfs = vec![20_000.0];
        g.shrinks = vec![false, true];
        g.seeds = vec![3];
        let run = runner::run(&g, 1).unwrap();
        let (canon, csv, cells) = stream_all(&g, &run, false);
        assert_eq!(canon, to_json_canonical(&run).to_pretty());
        assert_eq!(csv, to_csv(&run));
        let header = csv.lines().next().unwrap();
        assert!(
            header.contains("shrink")
                && header.contains("shrinks")
                && header.contains("regrows")
                && header.contains("degraded_rate_time_s"),
            "{header}"
        );
        assert_eq!(
            sweep_table("t", &cells).render(),
            sweep_table("t", &aggregate(&run)).render()
        );
    }

    #[test]
    fn end_to_end_files_match_legacy() {
        let g = small_grid();
        let dir = std::env::temp_dir();
        let jpath = dir.join("tlora_stream_e2e.json");
        let cpath = dir.join("tlora_stream_e2e.csv");
        let (cells, stats) = run_streaming_report(
            &g,
            4,
            Some((jpath.as_path(), true)),
            Some(cpath.as_path()),
        )
        .unwrap();
        assert_eq!(stats.n_points, g.len());
        let run = runner::run(&g, 1).unwrap();
        assert_eq!(
            std::fs::read_to_string(&jpath).unwrap(),
            to_json_canonical(&run).to_pretty()
        );
        assert_eq!(
            std::fs::read_to_string(&cpath).unwrap(),
            to_csv(&run)
        );
        assert_eq!(cells.len(), aggregate(&run).len());
        let _ = std::fs::remove_file(&jpath);
        let _ = std::fs::remove_file(&cpath);
    }
}
