//! Parallel sweep executor.
//!
//! A fixed pool of `std::thread` workers pulls grid cells off a shared
//! atomic cursor, simulates each cell, and streams `(index, result)`
//! pairs back over an mpsc channel. Each simulation is a pure function
//! of its [`crate::config::ExperimentConfig`] (seed-deterministic RNG,
//! no global state), and results are re-sorted by cell index before the
//! run is returned — so a sweep's output is **bit-identical** on 1
//! thread and on N threads, and across repeated runs. The cross-layer
//! determinism tests in `tests/integration_sweep.rs` pin this down.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use super::grid::{SweepGrid, SweepPoint};
use crate::sim::{simulate, SimResult};

/// Outcome of one grid cell.
#[derive(Debug, Clone)]
pub struct PointResult {
    pub point: SweepPoint,
    pub result: SimResult,
    /// wall-clock seconds this cell's simulation took (diagnostic only;
    /// excluded from determinism guarantees)
    pub wall_s: f64,
}

/// A completed sweep: per-cell results in grid-enumeration order.
#[derive(Debug, Clone)]
pub struct SweepRun {
    pub points: Vec<PointResult>,
    pub n_threads: usize,
    pub wall_s: f64,
}

impl SweepRun {
    /// Results matching a predicate on the scenario, in grid order.
    pub fn select(
        &self,
        pred: impl Fn(&SweepPoint) -> bool,
    ) -> Vec<&PointResult> {
        self.points.iter().filter(|p| pred(&p.point)).collect()
    }

    /// The single result matching a predicate (panics on 0 or >1 — the
    /// benches use this to pull exact scenarios out of a grid).
    pub fn expect_one(
        &self,
        pred: impl Fn(&SweepPoint) -> bool,
    ) -> &PointResult {
        let hits = self.select(pred);
        assert_eq!(
            hits.len(),
            1,
            "expected exactly one matching sweep point, got {}",
            hits.len()
        );
        hits[0]
    }
}

/// Worker-thread count to use when the caller does not care: the
/// machine's available parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run every cell of `grid` across `n_threads` workers.
pub fn run(grid: &SweepGrid, n_threads: usize) -> Result<SweepRun, String> {
    grid.validate()?;
    let points = grid.points();
    let n_threads = n_threads.max(1).min(points.len().max(1));
    let t0 = Instant::now();

    let (tx, rx) = mpsc::channel::<PointResult>();
    let cursor = AtomicUsize::new(0);
    {
        let points = &points;
        let cursor = &cursor;
        let base = &grid.base;
        std::thread::scope(|scope| {
            for _ in 0..n_threads {
                let tx = tx.clone();
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= points.len() {
                        break;
                    }
                    let point = points[i].clone();
                    let cfg = point.config(base);
                    let cell_t0 = Instant::now();
                    let result = simulate(&cfg);
                    let wall_s = cell_t0.elapsed().as_secs_f64();
                    if tx
                        .send(PointResult {
                            point,
                            result,
                            wall_s,
                        })
                        .is_err()
                    {
                        break;
                    }
                });
            }
        });
    }
    drop(tx); // workers joined; close the channel so collection ends

    let mut out: Vec<PointResult> = rx.iter().collect();
    if out.len() != points.len() {
        return Err(format!(
            "sweep lost results: {} of {} cells reported",
            out.len(),
            points.len()
        ));
    }
    out.sort_by_key(|p| p.point.index);
    Ok(SweepRun {
        points: out,
        n_threads,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// [`run`] with [`default_threads`] workers.
pub fn run_parallel(grid: &SweepGrid) -> Result<SweepRun, String> {
    run(grid, default_threads())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;

    fn tiny_grid() -> SweepGrid {
        let mut g = SweepGrid::default();
        g.policies = vec![Policy::TLora, Policy::Megatron];
        g.n_jobs = vec![8];
        g.gpus = vec![16];
        g.rate_scales = vec![2.0];
        g.months = vec![1];
        g.seeds = vec![5];
        g
    }

    #[test]
    fn runs_every_cell_in_order() {
        let g = tiny_grid();
        let run = run(&g, 2).unwrap();
        assert_eq!(run.points.len(), g.len());
        for (i, p) in run.points.iter().enumerate() {
            assert_eq!(p.point.index, i);
            assert_eq!(p.result.jct.len(), 8, "{}", p.point.label());
        }
    }

    #[test]
    fn select_and_expect_one() {
        let g = tiny_grid();
        let run = run(&g, 1).unwrap();
        assert_eq!(run.select(|p| p.gpus == 16).len(), 2);
        let one = run.expect_one(|p| p.policy == Policy::Megatron);
        assert_eq!(one.point.policy, Policy::Megatron);
    }

    #[test]
    fn thread_count_clamped_to_grid() {
        let g = tiny_grid();
        let r = run(&g, 64).unwrap();
        assert!(r.n_threads <= g.len());
    }

    #[test]
    fn invalid_grid_rejected() {
        let mut g = tiny_grid();
        g.gpus = vec![];
        assert!(run(&g, 2).is_err());
    }
}
