//! Parallel sweep executor.
//!
//! A fixed pool of `std::thread` workers pulls grid cells off a shared
//! atomic cursor, simulates each cell, and streams `(index, result)`
//! pairs back over an mpsc channel. Each simulation is a pure function
//! of its [`crate::config::ExperimentConfig`] (seed-deterministic RNG,
//! no global state), so a sweep's output is **bit-identical** on 1
//! thread and on N threads, and across repeated runs. The cross-layer
//! determinism tests in `tests/integration_sweep.rs` pin this down.
//!
//! Two execution modes share the same core:
//! - [`run_streaming`] delivers each [`PointResult`] to a sink
//!   callback *in strict grid-index order* while workers race ahead,
//!   via a bounded reorder buffer: a worker may only start cell `i`
//!   once `i < emitted_floor + capacity`, so at most
//!   `reorder_capacity(n_threads)` results are ever alive. This is
//!   what makes O(1)-memory streaming reports deterministic at any
//!   thread count (DESIGN.md §Streaming reports).
//! - [`run`] is the collect-everything form, expressed as a
//!   streaming sink that pushes into a `Vec` — the two cannot drift.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex};
use std::time::Instant;

use super::grid::{SweepGrid, SweepPoint};
use crate::sim::{simulate, SimResult};

/// Outcome of one grid cell.
#[derive(Debug, Clone)]
pub struct PointResult {
    pub point: SweepPoint,
    pub result: SimResult,
    /// wall-clock seconds this cell's simulation took (diagnostic only;
    /// excluded from determinism guarantees)
    pub wall_s: f64,
}

/// A completed sweep: per-cell results in grid-enumeration order.
#[derive(Debug, Clone)]
pub struct SweepRun {
    pub points: Vec<PointResult>,
    pub n_threads: usize,
    pub wall_s: f64,
}

impl SweepRun {
    /// Results matching a predicate on the scenario, in grid order.
    pub fn select(
        &self,
        pred: impl Fn(&SweepPoint) -> bool,
    ) -> Vec<&PointResult> {
        self.points.iter().filter(|p| pred(&p.point)).collect()
    }

    /// The single result matching a predicate (panics on 0 or >1 — the
    /// benches use this to pull exact scenarios out of a grid).
    pub fn expect_one(
        &self,
        pred: impl Fn(&SweepPoint) -> bool,
    ) -> &PointResult {
        let hits = self.select(pred);
        assert_eq!(
            hits.len(),
            1,
            "expected exactly one matching sweep point, got {}",
            hits.len()
        );
        hits[0]
    }
}

/// Execution statistics of a streaming sweep (the data a collected
/// [`SweepRun`] would carry besides the points themselves).
#[derive(Debug, Clone)]
pub struct StreamStats {
    pub n_points: usize,
    pub n_threads: usize,
    pub wall_s: f64,
}

/// Worker-thread count to use when the caller does not care: the
/// machine's available parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// In-flight bound of the reorder buffer: enough lookahead that
/// workers never starve on one slow cell, small enough that report
/// memory stays O(threads), not O(points).
pub fn reorder_capacity(n_threads: usize) -> usize {
    (2 * n_threads).max(4)
}

/// Run every cell of `grid` and hand each [`PointResult`] to `sink`
/// in strict grid-index order, regardless of completion order or
/// thread count.
///
/// Determinism rule (pinned by the differential report tests): the
/// sink observes exactly the sequence index 0, 1, 2, …, so anything
/// built from the stream — canonical JSON, CSV, online aggregates —
/// is a pure function of the grid. Workers are credit-gated: cell `i`
/// may only *start* once `i < emitted_floor + capacity`, which bounds
/// buffered results by [`reorder_capacity`] and guarantees progress
/// (the cell at the floor is always either buffered or actively
/// simulating on an ungated worker).
///
/// A sink error aborts the sweep: gated workers are woken and drain
/// out, and the error is returned.
pub fn run_streaming(
    grid: &SweepGrid,
    n_threads: usize,
    sink: &mut dyn FnMut(PointResult) -> Result<(), String>,
) -> Result<StreamStats, String> {
    grid.validate()?;
    let points = grid.points();
    let n_threads = n_threads.max(1).min(points.len().max(1));
    let cap = reorder_capacity(n_threads);
    let t0 = Instant::now();

    let (tx, rx) = mpsc::channel::<PointResult>();
    let cursor = AtomicUsize::new(0);
    // emitted floor: index of the next result the sink is owed
    let floor = Mutex::new(0usize);
    let gate = Condvar::new();
    let aborted = AtomicBool::new(false);

    let mut next = 0usize;
    let mut sink_err: Option<String> = None;
    {
        let points = &points;
        let cursor = &cursor;
        let base = &grid.base;
        let floor = &floor;
        let gate = &gate;
        let aborted = &aborted;
        std::thread::scope(|scope| {
            for _ in 0..n_threads {
                let tx = tx.clone();
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= points.len() {
                        break;
                    }
                    {
                        // wait for emission credit
                        let mut f = floor.lock().unwrap();
                        while i >= *f + cap
                            && !aborted.load(Ordering::Relaxed)
                        {
                            f = gate.wait(f).unwrap();
                        }
                    }
                    if aborted.load(Ordering::Relaxed) {
                        break;
                    }
                    let point = points[i].clone();
                    let cfg = point.config(base);
                    let cell_t0 = Instant::now();
                    let result = simulate(&cfg);
                    let wall_s = cell_t0.elapsed().as_secs_f64();
                    if tx
                        .send(PointResult {
                            point,
                            result,
                            wall_s,
                        })
                        .is_err()
                    {
                        break;
                    }
                });
            }
            drop(tx); // only workers hold senders now

            // in-order drain through the bounded reorder buffer
            let mut buffer: BTreeMap<usize, PointResult> =
                BTreeMap::new();
            'drain: while next < points.len() {
                let pr = match rx.recv() {
                    Ok(pr) => pr,
                    Err(_) => break 'drain, // loss detected below
                };
                buffer.insert(pr.point.index, pr);
                while let Some(pr) = buffer.remove(&next) {
                    match sink(pr) {
                        Ok(()) => {
                            next += 1;
                            *floor.lock().unwrap() = next;
                            gate.notify_all();
                        }
                        Err(e) => {
                            sink_err = Some(e);
                            break 'drain;
                        }
                    }
                }
            }
            if next < points.len() {
                // early exit (sink error or lost worker): unhook any
                // credit-gated workers so the scope can join
                aborted.store(true, Ordering::Relaxed);
                gate.notify_all();
            }
        });
    }

    if let Some(e) = sink_err {
        return Err(e);
    }
    if next != points.len() {
        return Err(format!(
            "sweep lost results: {} of {} cells reported",
            next,
            points.len()
        ));
    }
    Ok(StreamStats {
        n_points: points.len(),
        n_threads,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// Run every cell of `grid` across `n_threads` workers, collecting
/// all results. Thin wrapper over [`run_streaming`]: the streamed
/// in-order sequence is pushed into a `Vec`, so collected and
/// streamed sweeps are the same bytes by construction.
pub fn run(grid: &SweepGrid, n_threads: usize) -> Result<SweepRun, String> {
    let mut points = Vec::new();
    let stats = run_streaming(grid, n_threads, &mut |pr| {
        points.push(pr);
        Ok(())
    })?;
    Ok(SweepRun {
        points,
        n_threads: stats.n_threads,
        wall_s: stats.wall_s,
    })
}

/// [`run`] with [`default_threads`] workers.
pub fn run_parallel(grid: &SweepGrid) -> Result<SweepRun, String> {
    run(grid, default_threads())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;

    fn tiny_grid() -> SweepGrid {
        let mut g = SweepGrid::default();
        g.policies = vec![Policy::TLora, Policy::Megatron];
        g.n_jobs = vec![8];
        g.gpus = vec![16];
        g.rate_scales = vec![2.0];
        g.months = vec![1];
        g.seeds = vec![5];
        g
    }

    #[test]
    fn runs_every_cell_in_order() {
        let g = tiny_grid();
        let run = run(&g, 2).unwrap();
        assert_eq!(run.points.len(), g.len());
        for (i, p) in run.points.iter().enumerate() {
            assert_eq!(p.point.index, i);
            assert_eq!(p.result.jct.len(), 8, "{}", p.point.label());
        }
    }

    #[test]
    fn select_and_expect_one() {
        let g = tiny_grid();
        let run = run(&g, 1).unwrap();
        assert_eq!(run.select(|p| p.gpus == 16).len(), 2);
        let one = run.expect_one(|p| p.policy == Policy::Megatron);
        assert_eq!(one.point.policy, Policy::Megatron);
    }

    #[test]
    fn thread_count_clamped_to_grid() {
        let g = tiny_grid();
        let r = run(&g, 64).unwrap();
        assert!(r.n_threads <= g.len());
    }

    #[test]
    fn invalid_grid_rejected() {
        let mut g = tiny_grid();
        g.gpus = vec![];
        assert!(run(&g, 2).is_err());
    }

    #[test]
    fn streaming_sink_sees_strict_index_order() {
        // 8-cell grid, more threads than reorder credit — the sink
        // must still observe 0,1,2,… with no gaps or repeats
        let mut g = tiny_grid();
        g.seeds = vec![5, 6, 7, 8];
        let mut seen = 0usize;
        let stats = run_streaming(&g, 8, &mut |pr| {
            assert_eq!(pr.point.index, seen, "out-of-order emission");
            seen += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, g.len());
        assert_eq!(stats.n_points, g.len());
    }

    #[test]
    fn streaming_sink_error_aborts_without_deadlock() {
        // a failing sink must unhook credit-gated workers and return
        // the error (regression test for the abort/notify handshake)
        let mut g = tiny_grid();
        g.seeds = vec![5, 6, 7, 8];
        let err = run_streaming(&g, 8, &mut |pr| {
            if pr.point.index >= 1 {
                Err("sink exploded".into())
            } else {
                Ok(())
            }
        })
        .unwrap_err();
        assert!(err.contains("sink exploded"), "{err}");
    }

    #[test]
    fn streamed_and_collected_runs_match() {
        let g = tiny_grid();
        let collected = run(&g, 2).unwrap();
        let mut streamed = Vec::new();
        run_streaming(&g, 2, &mut |pr| {
            streamed.push(pr);
            Ok(())
        })
        .unwrap();
        assert_eq!(streamed.len(), collected.points.len());
        for (a, b) in streamed.iter().zip(&collected.points) {
            assert_eq!(a.point.index, b.point.index);
            assert_eq!(a.result.jct, b.result.jct);
        }
    }
}
