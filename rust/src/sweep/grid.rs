//! Declarative scenario grids.
//!
//! A [`SweepGrid`] is the cartesian product of the evaluation axes every
//! figure of the paper varies: policy × job count × cluster size ×
//! arrival-rate scale × trace month × node MTBF × GPU MTBF ×
//! straggler MTBS × hardware mix × topology × shrink-in-place × seed. [`SweepGrid::points`] enumerates the cells in a fixed
//! row-major order, so a sweep's output is a pure function of the grid
//! regardless of how many worker threads execute it. The MTBF axis
//! (seconds; 0 = no churn) opens the failure/SLO workload dimension;
//! the GPU-MTBF axis (per-device mean seconds between single-GPU
//! faults; 0 = no GPU faults) opens the partial-node dimension; the
//! straggler axis (mean seconds between degrade episodes per node;
//! 0 = no stragglers) opens the degraded-node dimension. Every other
//! fault/straggler knob (MTTR, preemption rate, restore cost model,
//! severity bounds, detection thresholds) comes from the grid's base
//! config.

use crate::cluster::ClusterSpec;
use crate::config::{ExperimentConfig, Policy};
use crate::workload::trace::TraceProfile;

/// The trace profile for a month index (1, 2 or 3; anything else falls
/// back to month 1, matching the CLI's `--month` handling).
pub fn month_profile(month: usize) -> TraceProfile {
    match month {
        2 => TraceProfile::month2(),
        3 => TraceProfile::month3(),
        _ => TraceProfile::month1(),
    }
}

/// Cartesian sweep specification. Every axis must be non-empty; `base`
/// supplies the knobs the grid does not vary (scheduler horizon, AIMD
/// parameters, concurrency cap, ...).
#[derive(Debug, Clone)]
pub struct SweepGrid {
    pub base: ExperimentConfig,
    pub policies: Vec<Policy>,
    pub n_jobs: Vec<usize>,
    pub gpus: Vec<usize>,
    pub rate_scales: Vec<f64>,
    pub months: Vec<usize>,
    /// node MTBF values in seconds; 0 disables node failures for the
    /// cell (other fault knobs come from `base.faults`)
    pub mtbfs: Vec<f64>,
    /// per-GPU MTBF values in seconds (single-device faults that hole
    /// one GPU out of its node); 0 disables GPU faults for the cell
    /// (the matching MTTR comes from `base.faults.gpu_mttr_s`)
    pub gpu_mtbfs: Vec<f64>,
    /// straggler MTBS values in seconds (mean time between degrade
    /// episodes per node); 0 disables stragglers for the cell (other
    /// straggler knobs come from `base.stragglers`)
    pub stragglers: Vec<f64>,
    /// hardware-mix strings (`cluster::parse_hardware_mix` syntax,
    /// e.g. `"a100*3:h100"`); the empty string is the homogeneous
    /// reference fleet and keeps the cell key byte-identical to
    /// pre-tier sweeps
    pub hardware_mixes: Vec<String>,
    /// topology strings (`cluster::parse_topology` syntax, e.g.
    /// `"racks=4:rack_bw=0.5"`); the empty string is the flat
    /// single-switch topology and keeps the cell key byte-identical
    /// to pre-topology sweeps
    pub topologies: Vec<String>,
    /// shrink-in-place settings (`faults.shrink`); `false` keeps the
    /// evict-and-requeue fault semantics and a cell key
    /// byte-identical to pre-shrink sweeps, `true` lets capable
    /// policies shrink gangs through single-GPU failures
    pub shrinks: Vec<bool>,
    pub seeds: Vec<u64>,
}

impl Default for SweepGrid {
    fn default() -> Self {
        let base = ExperimentConfig::default();
        SweepGrid {
            policies: vec![base.policy],
            n_jobs: vec![base.n_jobs],
            gpus: vec![base.cluster.total_gpus()],
            rate_scales: vec![1.0],
            months: vec![1],
            mtbfs: vec![base.faults.mtbf_s],
            gpu_mtbfs: vec![base.faults.gpu_mtbf_s],
            stragglers: vec![base.stragglers.mtbs_s],
            hardware_mixes: vec![base.cluster.hardware_mix.clone()],
            topologies: vec![base.cluster.topology.spec_str.clone()],
            shrinks: vec![base.faults.shrink],
            seeds: vec![base.seed],
            base,
        }
    }
}

impl SweepGrid {
    /// Number of grid cells (simulations) the sweep will run.
    pub fn len(&self) -> usize {
        self.policies.len()
            * self.n_jobs.len()
            * self.gpus.len()
            * self.rate_scales.len()
            * self.months.len()
            * self.mtbfs.len()
            * self.gpu_mtbfs.len()
            * self.stragglers.len()
            * self.hardware_mixes.len()
            * self.topologies.len()
            * self.shrinks.len()
            * self.seeds.len()
    }

    /// True when any cell of the grid requests a non-default hardware
    /// mix. The streaming report derives its gated `hardware_mix` /
    /// `tier_util` columns from this *before* any point completes;
    /// it equals the legacy writers' any-point check because every
    /// point's mix comes verbatim from this axis.
    pub fn is_heterogeneous(&self) -> bool {
        self.hardware_mixes.iter().any(|m| !m.is_empty())
    }

    /// True when any cell of the grid requests a non-flat topology.
    /// Gates the streaming report's `topology` / rack-span columns the
    /// same way [`SweepGrid::is_heterogeneous`] gates the tier columns.
    pub fn has_topology(&self) -> bool {
        self.topologies.iter().any(|t| !t.is_empty())
    }

    /// True when any cell of the grid turns single-GPU faults on.
    /// Gates the streaming report's `gpu_mtbf_s` / `gpu_failures` /
    /// `holed_gpu_time_s` columns the same way
    /// [`SweepGrid::has_topology`] gates the rack-span columns.
    pub fn has_gpu_faults(&self) -> bool {
        self.gpu_mtbfs.iter().any(|&m| m > 0.0)
    }

    /// True when any cell of the grid turns shrink-in-place on. Gates
    /// the streaming report's `shrink` / `shrinks` / `regrows` /
    /// `degraded_rate_time_s` columns the same way
    /// [`SweepGrid::has_gpu_faults`] gates the holed-GPU columns.
    pub fn has_shrink(&self) -> bool {
        self.shrinks.iter().any(|&s| s)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Check every axis is non-empty and every cell yields a valid
    /// [`ExperimentConfig`].
    pub fn validate(&self) -> Result<(), String> {
        for (axis, empty) in [
            ("policies", self.policies.is_empty()),
            ("n_jobs", self.n_jobs.is_empty()),
            ("gpus", self.gpus.is_empty()),
            ("rate_scales", self.rate_scales.is_empty()),
            ("months", self.months.is_empty()),
            ("mtbfs", self.mtbfs.is_empty()),
            ("gpu_mtbfs", self.gpu_mtbfs.is_empty()),
            ("stragglers", self.stragglers.is_empty()),
            ("hardware_mixes", self.hardware_mixes.is_empty()),
            ("topologies", self.topologies.is_empty()),
            ("shrinks", self.shrinks.is_empty()),
            ("seeds", self.seeds.is_empty()),
        ] {
            if empty {
                return Err(format!("sweep axis {axis} is empty"));
            }
        }
        // reject malformed mix strings up front so `SweepPoint::config`
        // (which is infallible) can rely on them parsing
        for m in &self.hardware_mixes {
            ClusterSpec::with_gpus(8)
                .apply_hardware_mix(m)
                .map_err(|e| format!("hardware mix {m:?}: {e}"))?;
        }
        for t in &self.topologies {
            ClusterSpec::with_gpus(8)
                .apply_topology(t)
                .map_err(|e| format!("topology {t:?}: {e}"))?;
        }
        for p in self.points() {
            p.config(&self.base)
                .validate()
                .map_err(|e| format!("grid cell {}: {e}", p.label()))?;
        }
        Ok(())
    }

    /// Enumerate all cells in deterministic row-major order (seeds vary
    /// fastest, so one scenario's replicas are adjacent).
    pub fn points(&self) -> Vec<SweepPoint> {
        let mut out = Vec::with_capacity(self.len());
        let mut index = 0usize;
        for &policy in &self.policies {
            for &n_jobs in &self.n_jobs {
                for &gpus in &self.gpus {
                    for &rate_scale in &self.rate_scales {
                        for &month in &self.months {
                            for &mtbf_s in &self.mtbfs {
                                for &gpu_mtbf_s in &self.gpu_mtbfs {
                                    for &mtbs in &self.stragglers {
                                        for mix in &self.hardware_mixes
                                        {
                                            for topo in
                                                &self.topologies
                                            {
                                                for &shrink in
                                                    &self.shrinks
                                                {
                                                    for &seed in
                                                        &self.seeds
                                                    {
                                                        out.push(SweepPoint {
                                                            index,
                                                            policy,
                                                            n_jobs,
                                                            gpus,
                                                            rate_scale,
                                                            month,
                                                            mtbf_s,
                                                            gpu_mtbf_s,
                                                            straggler_mtbs_s:
                                                                mtbs,
                                                            hardware_mix:
                                                                mix.clone(),
                                                            topology: topo
                                                                .clone(),
                                                            shrink,
                                                            seed,
                                                        });
                                                        index += 1;
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// One grid cell: a complete scenario description plus its position in
/// the enumeration order.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    pub index: usize,
    pub policy: Policy,
    pub n_jobs: usize,
    pub gpus: usize,
    pub rate_scale: f64,
    pub month: usize,
    /// node MTBF in seconds (0 = no node failures for this cell)
    pub mtbf_s: f64,
    /// per-GPU MTBF in seconds (0 = no single-GPU faults for this cell)
    pub gpu_mtbf_s: f64,
    /// straggler MTBS in seconds (0 = no stragglers for this cell)
    pub straggler_mtbs_s: f64,
    /// hardware-mix string ("" = homogeneous reference fleet)
    pub hardware_mix: String,
    /// topology string ("" = flat single-switch cluster)
    pub topology: String,
    /// shrink-in-place gangs through single-GPU failures (false =
    /// legacy evict-and-requeue semantics)
    pub shrink: bool,
    pub seed: u64,
}

impl SweepPoint {
    /// Materialize the scenario's experiment configuration on top of the
    /// grid's base config.
    pub fn config(&self, base: &ExperimentConfig) -> ExperimentConfig {
        let mut cfg = base.clone();
        cfg.policy = self.policy;
        cfg.n_jobs = self.n_jobs;
        cfg.cluster = ClusterSpec::with_gpus(self.gpus);
        cfg.cluster
            .apply_hardware_mix(&self.hardware_mix)
            .expect("SweepGrid::validate rejects malformed mixes");
        cfg.cluster
            .apply_topology(&self.topology)
            .expect("SweepGrid::validate rejects malformed topologies");
        cfg.trace = month_profile(self.month).scaled(self.rate_scale);
        cfg.faults.mtbf_s = self.mtbf_s;
        cfg.faults.gpu_mtbf_s = self.gpu_mtbf_s;
        cfg.faults.shrink = self.shrink;
        cfg.stragglers.mtbs_s = self.straggler_mtbs_s;
        cfg.seed = self.seed;
        cfg
    }

    /// Short machine-friendly label, e.g.
    /// `tlora/j200/g128/r1x/m1/f0/d0/s42`.
    pub fn label(&self) -> String {
        format!("{}/s{}", self.cell_key(), self.seed)
    }

    /// Scenario key ignoring the seed — replicas of one scenario share a
    /// cell key and are aggregated together by the report layer. The
    /// `f` component is the node MTBF in seconds (0 = fault-free); the
    /// `d` component is the straggler MTBS in seconds (0 = no
    /// degraded nodes). A `/G<gpu_mtbf>` component appears only for
    /// cells with single-GPU faults on, a trailing `/h<mix>` component
    /// only for heterogeneous cells, a trailing `/t<topology>`
    /// component only for non-flat cells and a trailing `/S1`
    /// component only for shrink-in-place cells, so GPU-fault-free
    /// homogeneous flat evict-semantics sweep keys stay byte-identical
    /// to pre-tier, pre-topology, pre-GPU-fault and pre-shrink builds.
    pub fn cell_key(&self) -> String {
        let mut key = format!(
            "{}/j{}/g{}/r{}x/m{}/f{}/d{}",
            self.policy.slug(),
            self.n_jobs,
            self.gpus,
            self.rate_scale,
            self.month,
            self.mtbf_s,
            self.straggler_mtbs_s
        );
        if self.gpu_mtbf_s > 0.0 {
            key.push_str(&format!("/G{}", self.gpu_mtbf_s));
        }
        if !self.hardware_mix.is_empty() {
            key.push_str("/h");
            key.push_str(&self.hardware_mix);
        }
        if !self.topology.is_empty() {
            key.push_str("/t");
            key.push_str(&self.topology);
        }
        if self.shrink {
            key.push_str("/S1");
        }
        key
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> SweepGrid {
        let mut g = SweepGrid::default();
        g.policies = vec![Policy::TLora, Policy::MLora];
        g.n_jobs = vec![10];
        g.gpus = vec![16, 32];
        g.rate_scales = vec![1.0, 2.0];
        g.months = vec![1];
        g.seeds = vec![1, 2, 3];
        g
    }

    #[test]
    fn len_matches_enumeration() {
        let g = grid();
        assert_eq!(g.len(), 2 * 2 * 2 * 3);
        let pts = g.points();
        assert_eq!(pts.len(), g.len());
        for (i, p) in pts.iter().enumerate() {
            assert_eq!(p.index, i);
        }
    }

    #[test]
    fn enumeration_is_deterministic() {
        let g = grid();
        assert_eq!(g.points(), g.points());
    }

    #[test]
    fn seeds_vary_fastest() {
        let pts = grid().points();
        assert_eq!(pts[0].seed, 1);
        assert_eq!(pts[1].seed, 2);
        assert_eq!(pts[2].seed, 3);
        assert_eq!(pts[0].cell_key(), pts[2].cell_key());
        assert_ne!(pts[0].cell_key(), pts[3].cell_key());
        assert_ne!(pts[0].label(), pts[1].label());
    }

    #[test]
    fn point_config_applies_all_axes() {
        let g = grid();
        let pts = g.points();
        let p = &pts[g.len() - 1];
        let cfg = p.config(&g.base);
        assert_eq!(cfg.policy, Policy::MLora);
        assert_eq!(cfg.n_jobs, 10);
        assert_eq!(cfg.cluster.total_gpus(), 32);
        assert_eq!(cfg.seed, 3);
        let base_rate = month_profile(1).rate;
        assert!((cfg.trace.rate - base_rate * 2.0).abs() < 1e-12);
    }

    #[test]
    fn validate_rejects_empty_axes_and_bad_cells() {
        let mut g = grid();
        g.seeds.clear();
        assert!(g.validate().is_err());
        let mut g = grid();
        g.n_jobs = vec![0];
        assert!(g.validate().is_err());
        let mut g = grid();
        g.mtbfs.clear();
        assert!(g.validate().is_err());
        let mut g = grid();
        g.mtbfs = vec![-5.0];
        assert!(g.validate().is_err());
        let mut g = grid();
        g.stragglers.clear();
        assert!(g.validate().is_err());
        let mut g = grid();
        g.stragglers = vec![-60.0];
        assert!(g.validate().is_err());
        assert!(grid().validate().is_ok());
    }

    #[test]
    fn mtbf_axis_enumerates_and_applies() {
        let mut g = grid();
        g.mtbfs = vec![0.0, 1800.0];
        assert_eq!(g.len(), 2 * 2 * 2 * 2 * 3);
        let pts = g.points();
        assert_eq!(pts.len(), g.len());
        // mtbf varies faster than month, slower than seed
        assert_eq!(pts[0].mtbf_s, 0.0);
        assert_eq!(pts[3].mtbf_s, 1800.0);
        assert_ne!(pts[0].cell_key(), pts[3].cell_key());
        assert!(pts[0].cell_key().ends_with("/f0/d0"));
        assert!(pts[3].cell_key().ends_with("/f1800/d0"));
        let cfg0 = pts[0].config(&g.base);
        let cfg1 = pts[3].config(&g.base);
        assert!(!cfg0.faults.enabled());
        assert_eq!(cfg1.faults.mtbf_s, 1800.0);
        assert!(cfg1.faults.enabled());
        assert!(cfg0.validate().is_ok() && cfg1.validate().is_ok());
    }

    #[test]
    fn gpu_mtbf_axis_enumerates_and_applies() {
        let mut g = grid();
        g.gpu_mtbfs = vec![0.0, 40_000.0];
        assert_eq!(g.len(), 2 * 2 * 2 * 2 * 3);
        let pts = g.points();
        assert_eq!(pts.len(), g.len());
        // GPU MTBF varies faster than node MTBF, slower than seed
        assert_eq!(pts[0].gpu_mtbf_s, 0.0);
        assert_eq!(pts[3].gpu_mtbf_s, 40_000.0);
        assert_ne!(pts[0].cell_key(), pts[3].cell_key());
        // the GPU-fault-free cell's key is byte-identical to the
        // pre-GPU-fault format; only fault-on cells grow /G
        assert!(pts[0].cell_key().ends_with("/f0/d0"));
        assert!(pts[3].cell_key().ends_with("/f0/d0/G40000"));
        let cfg0 = pts[0].config(&g.base);
        let cfg1 = pts[3].config(&g.base);
        assert_eq!(cfg0.faults.gpu_mtbf_s, 0.0);
        assert_eq!(cfg1.faults.gpu_mtbf_s, 40_000.0);
        // the matching MTTR rides along from the base config
        assert_eq!(cfg1.faults.gpu_mttr_s, g.base.faults.gpu_mttr_s);
        assert!(cfg0.validate().is_ok() && cfg1.validate().is_ok());
        assert!(g.has_gpu_faults());
        assert!(!grid().has_gpu_faults());
        // rejections
        let mut g = grid();
        g.gpu_mtbfs.clear();
        assert!(g.validate().is_err());
        let mut g = grid();
        g.gpu_mtbfs = vec![-10.0];
        assert!(g.validate().is_err());
    }

    #[test]
    fn shrink_axis_enumerates_and_applies() {
        let mut g = grid();
        g.shrinks = vec![false, true];
        assert_eq!(g.len(), 2 * 2 * 2 * 2 * 3);
        let pts = g.points();
        assert_eq!(pts.len(), g.len());
        // shrink varies faster than topology, slower than seed
        assert!(!pts[0].shrink);
        assert!(pts[3].shrink);
        assert_ne!(pts[0].cell_key(), pts[3].cell_key());
        // the evict-semantics cell's key is byte-identical to the
        // pre-shrink format; only shrink cells grow the /S1 suffix
        assert!(pts[0].cell_key().ends_with("/f0/d0"));
        assert!(pts[3].cell_key().ends_with("/f0/d0/S1"));
        let cfg0 = pts[0].config(&g.base);
        let cfg1 = pts[3].config(&g.base);
        assert!(!cfg0.faults.shrink);
        assert!(cfg1.faults.shrink);
        assert!(cfg0.validate().is_ok() && cfg1.validate().is_ok());
        assert!(g.has_shrink());
        assert!(!grid().has_shrink());
        // rejection: the axis must be non-empty like every other
        let mut g = grid();
        g.shrinks.clear();
        assert!(g.validate().is_err());
    }

    #[test]
    fn straggler_axis_enumerates_and_applies() {
        let mut g = grid();
        g.stragglers = vec![0.0, 1200.0];
        assert_eq!(g.len(), 2 * 2 * 2 * 2 * 3);
        let pts = g.points();
        assert_eq!(pts.len(), g.len());
        // straggler MTBS varies faster than MTBF, slower than seed
        assert_eq!(pts[0].straggler_mtbs_s, 0.0);
        assert_eq!(pts[3].straggler_mtbs_s, 1200.0);
        assert_ne!(pts[0].cell_key(), pts[3].cell_key());
        assert!(pts[0].cell_key().ends_with("/f0/d0"));
        assert!(pts[3].cell_key().ends_with("/f0/d1200"));
        let cfg0 = pts[0].config(&g.base);
        let cfg1 = pts[3].config(&g.base);
        assert!(!cfg0.stragglers.enabled());
        assert_eq!(cfg1.stragglers.mtbs_s, 1200.0);
        assert!(cfg1.stragglers.enabled());
        // non-axis straggler knobs ride along from the base config
        assert_eq!(
            cfg1.stragglers.severity_min,
            g.base.stragglers.severity_min
        );
        assert_eq!(cfg1.stragglers.detect, g.base.stragglers.detect);
        assert!(cfg0.validate().is_ok() && cfg1.validate().is_ok());
    }

    #[test]
    fn hardware_mix_axis_enumerates_and_applies() {
        let mut g = grid();
        g.hardware_mixes = vec!["".into(), "a100:v100".into()];
        assert_eq!(g.len(), 2 * 2 * 2 * 2 * 3);
        let pts = g.points();
        assert_eq!(pts.len(), g.len());
        // mix varies faster than straggler MTBS, slower than seed
        assert_eq!(pts[0].hardware_mix, "");
        assert_eq!(pts[3].hardware_mix, "a100:v100");
        // the homogeneous cell's key is byte-identical to the
        // pre-tier format; only mixed cells grow the /h component
        assert!(pts[0].cell_key().ends_with("/f0/d0"));
        assert!(pts[3].cell_key().ends_with("/f0/d0/ha100:v100"));
        assert_ne!(pts[0].cell_key(), pts[3].cell_key());
        let cfg0 = pts[0].config(&g.base);
        let cfg1 = pts[3].config(&g.base);
        assert!(cfg0.cluster.is_uniform_reference());
        assert!(!cfg1.cluster.is_uniform_reference());
        assert_eq!(cfg1.cluster.tiers.len(), 2);
        assert_eq!(cfg1.cluster.hardware_mix, "a100:v100");
        // the mix survives the gpus-axis cluster rebuild
        assert_eq!(cfg1.cluster.total_gpus(), pts[3].gpus);
        assert!(cfg0.validate().is_ok() && cfg1.validate().is_ok());
    }

    #[test]
    fn validate_rejects_malformed_hardware_mix() {
        let mut g = grid();
        g.hardware_mixes = vec!["tpu9".into()];
        assert!(g.validate().is_err());
        let mut g = grid();
        g.hardware_mixes.clear();
        assert!(g.validate().is_err());
    }

    #[test]
    fn topology_axis_enumerates_and_applies() {
        let mut g = grid();
        g.topologies = vec!["".into(), "racks=4:rack_bw=0.5".into()];
        assert_eq!(g.len(), 2 * 2 * 2 * 2 * 3);
        let pts = g.points();
        assert_eq!(pts.len(), g.len());
        // topology varies faster than hardware mix, slower than seed
        assert_eq!(pts[0].topology, "");
        assert_eq!(pts[3].topology, "racks=4:rack_bw=0.5");
        // the flat cell's key is byte-identical to the pre-topology
        // format; only non-flat cells grow the /t component
        assert!(pts[0].cell_key().ends_with("/f0/d0"));
        assert!(pts[3]
            .cell_key()
            .ends_with("/f0/d0/tracks=4:rack_bw=0.5"));
        assert_ne!(pts[0].cell_key(), pts[3].cell_key());
        let cfg0 = pts[0].config(&g.base);
        let cfg1 = pts[3].config(&g.base);
        assert!(cfg0.cluster.topology.is_flat());
        assert!(!cfg1.cluster.topology.is_flat());
        assert_eq!(cfg1.cluster.topology.racks, 4);
        assert_eq!(cfg1.cluster.topology.rack_bw, 0.5);
        // the topology survives the gpus-axis cluster rebuild
        assert_eq!(cfg1.cluster.total_gpus(), pts[3].gpus);
        assert!(cfg0.validate().is_ok() && cfg1.validate().is_ok());
        assert!(g.has_topology());
        assert!(!grid().has_topology());
    }

    #[test]
    fn validate_rejects_malformed_topology() {
        let mut g = grid();
        g.topologies = vec!["racks=zero".into()];
        assert!(g.validate().is_err());
        let mut g = grid();
        g.topologies.clear();
        assert!(g.validate().is_err());
    }
}
