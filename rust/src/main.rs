//! tLoRA command-line interface.
//!
//! ```text
//! tlora simulate  [--policy tlora|mlora|megatron|...] [--n-jobs N]
//!                 [--n-gpus N] [--seed S] [--month 1|2|3] [--rate-scale F]
//!                 [--mtbf S] [--mttr S] [--gpu-mtbf S] [--gpu-mttr S]
//!                 [--gpu-wear-alpha F] [--shrink]
//!                 [--preempt-rate R]
//!                 [--straggler-mtbs S] [--straggler-mtts S]
//!                 [--straggler-oblivious] [--hardware-mix SPEC]
//!                 [--topology SPEC] [--trace file.csv]
//! tlora compare   [--n-jobs N] [--n-gpus N] [--seed S]     # all policies
//! tlora sweep     [--policies a,b|all] [--n-jobs N,..] [--gpus N,..]
//!                 [--rate-scales F,..] [--months M,..] [--mtbfs S,..]
//!                 [--gpu-mtbf S,..] [--shrink B,..] [--stragglers S,..]
//!                 [--hardware-mix SPEC,..]
//!                 [--topology SPEC,..] [--seeds S,..] [--threads T]
//!                 [--out-json f] [--out-csv f] [--canonical]
//!                 [--legacy-report]
//! tlora train     [--variant tiny|small|...] [--steps N] [--seed S]
//! tlora microbench [--steps N]
//! tlora trace-gen [--n-jobs N] [--month M] [--seed S] [--out file.csv]
//!                 [--hyperscale] [--diurnal-amp F] [--diurnal-period S]
//! ```

use std::path::PathBuf;

use tlora::cli::Args;
use tlora::config::{ExperimentConfig, Policy};
use tlora::metrics::Table;
use tlora::sim::simulate;
use tlora::workload::trace::{
    save_csv, DiurnalProfile, TraceGenerator, TraceProfile,
};

fn main() -> std::process::ExitCode {
    let args = match Args::parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            return std::process::ExitCode::from(2);
        }
    };
    let code = match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("compare") => cmd_compare(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("train") => cmd_train(&args),
        Some("microbench") => cmd_microbench(&args),
        Some("trace-gen") => cmd_trace_gen(&args),
        _ => {
            print!("{}", HELP);
            0
        }
    };
    // NOTE: returning (instead of process::exit) flushes stdout and runs
    // PJRT drop order cleanly.
    use std::io::Write;
    let _ = std::io::stdout().flush();
    std::process::ExitCode::from(code as u8)
}

const HELP: &str = "\
tLoRA — efficient multi-LoRA training with elastic shared super-models

USAGE: tlora <subcommand> [flags]

  simulate     trace-driven cluster simulation for one policy
  compare      run all policies on the same trace, print §4.2 metrics
  sweep        parallel scenario grid (policy x jobs x gpus x rate x
               month x seed) with mean±CI aggregation + JSON/CSV output
  train        real fused training via PJRT on an AOT'd SSM variant
  microbench   measure step times + simulator calibration (Fig. 10)
  trace-gen    emit a synthetic ACMETrace-style CSV

Common flags: --n-jobs N --n-gpus N --seed S --month 1|2|3
              --rate-scale F --policy NAME --artifacts DIR
Fault flags:  --mtbf SECONDS (0 = off) --mttr SECONDS
              --gpu-mtbf SECONDS (per-GPU single-device failures,
              0 = off; a hit holes one GPU out of its node and evicts
              only the gangs touching it) --gpu-mttr SECONDS
              --gpu-wear-alpha F (wear coupling: each device's fault
              rate grows by a factor of (1 + alpha) per prior fault
              on that device; 0 = memoryless) --shrink (graceful
              degradation: capable policies shrink a gang in place
              through a single-GPU failure — re-plan at surviving
              width, roll back only to the last checkpoint — and
              regrow on recovery; other policies keep evicting)
              --preempt-rate EVENTS/S  (simulate/compare)
Straggler flags: --straggler-mtbs SECONDS (mean time between degrade
              episodes per node, 0 = off) --straggler-mtts SECONDS
              (mean episode length) --straggler-oblivious (disable
              detection even for detection-capable policies;
              severity/detection knobs via --config JSON 'stragglers')
Hardware flags: --hardware-mix SPEC, a cyclic per-node tier pattern
              over calibrated generations, e.g. 'a100*3:h100' (three
              A100 nodes per H100 node). Known tiers: a100 (reference),
              h100, a100-40g, v100, a10g. simulate/compare take one
              mix; sweep takes a comma list as a grid axis and reports
              per-tier utilization columns for mixed cells
Topology flags: --topology SPEC, a rack/region tree with per-tier
              bandwidth discounts, e.g. 'racks=4:rack_bw=0.5' (keys:
              racks, regions, rack_bw, region_bw, rack_lat,
              region_lat; empty = flat single-switch cluster). The
              allocator packs gangs into one tier and one rack when
              it can; cross-rack/region traffic pays the discounted
              bandwidth. simulate/compare take one spec; sweep takes
              a comma list as a grid axis and reports rack-span
              columns for non-flat cells
Sweep flags:  --policies a,b|all --n-jobs N,.. --gpus N,..
              --rate-scales F,.. --months M,.. --mtbfs S,..
              --gpu-mtbf S,.. --shrink false,true (grid axis; true
              cells report shrink/regrow columns)
              --stragglers S,.. --hardware-mix SPEC,..
              --topology SPEC,.. --seeds S,.. --threads T
              --out-json FILE --out-csv FILE
              --canonical (strip wall-clock/thread fields from JSON so
              runs diff bit-exactly; used by the golden-trace fixture)
              --legacy-report (collect every point before writing
              reports, the pre-streaming path; the default streams
              rows as workers finish in O(1) report memory, emitting
              byte-identical output)
Trace-gen flags: --hyperscale (dense diurnal multi-tenant preset for
              million-arrival traces) --diurnal-amp F (sinusoidal
              day/night arrival swing, 0..1) --diurnal-period S
              (cycle length, default 86400)
";

fn build_config(args: &Args) -> Result<ExperimentConfig, String> {
    let mut cfg = ExperimentConfig::default();
    if let Some(p) = args.get("policy") {
        cfg.policy =
            Policy::parse(p).ok_or_else(|| format!("unknown policy {p}"))?;
    }
    cfg.n_jobs = args.get_usize("n-jobs", 100)?;
    let n_gpus = args.get_usize("n-gpus", 128)?;
    cfg.cluster = tlora::cluster::ClusterSpec::with_gpus(n_gpus);
    if let Some(mix) = args.get("hardware-mix") {
        cfg.cluster.apply_hardware_mix(mix)?;
    }
    if let Some(topo) = args.get("topology") {
        cfg.cluster.apply_topology(topo)?;
    }
    cfg.seed = args.get_u64("seed", 42)?;
    cfg.trace = match args.get_usize("month", 1)? {
        2 => TraceProfile::month2(),
        3 => TraceProfile::month3(),
        _ => TraceProfile::month1(),
    };
    let scale = args.get_f64("rate-scale", 1.0)?;
    cfg.trace = cfg.trace.scaled(scale);
    cfg.faults.mtbf_s = args.get_f64("mtbf", cfg.faults.mtbf_s)?;
    cfg.faults.mttr_s = args.get_f64("mttr", cfg.faults.mttr_s)?;
    cfg.faults.gpu_mtbf_s =
        args.get_f64("gpu-mtbf", cfg.faults.gpu_mtbf_s)?;
    cfg.faults.gpu_mttr_s =
        args.get_f64("gpu-mttr", cfg.faults.gpu_mttr_s)?;
    cfg.faults.gpu_wear_alpha =
        args.get_f64("gpu-wear-alpha", cfg.faults.gpu_wear_alpha)?;
    if args.has("shrink") {
        cfg.faults.shrink = true;
    }
    cfg.faults.preempt_rate =
        args.get_f64("preempt-rate", cfg.faults.preempt_rate)?;
    cfg.stragglers.mtbs_s =
        args.get_f64("straggler-mtbs", cfg.stragglers.mtbs_s)?;
    cfg.stragglers.mtts_s =
        args.get_f64("straggler-mtts", cfg.stragglers.mtts_s)?;
    if args.has("straggler-oblivious") {
        cfg.stragglers.detect = false;
    }
    if let Some(path) = args.get("config") {
        let j = tlora::util::json::parse_file(std::path::Path::new(path))?;
        cfg.apply_json(&j)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn cmd_simulate(args: &Args) -> i32 {
    let cfg = match build_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    // --trace file.csv replays an explicit (real or generated) trace
    // instead of sampling from the synthetic profile. The CSV text is
    // streamed line-by-line (never held in memory whole — a
    // million-job trace parses in O(1) text memory); the engine still
    // needs the parsed job vector to size its state tables.
    let r = if let Some(path) = args.get("trace") {
        let iter = match tlora::workload::trace::stream_csv_file(
            std::path::Path::new(path),
        ) {
            Ok(it) => it,
            Err(e) => {
                eprintln!("read {path}: {e}");
                return 2;
            }
        };
        match iter.collect::<Result<Vec<_>, String>>() {
            Ok(jobs) => tlora::sim::simulate_jobs(&cfg, jobs),
            Err(e) => {
                eprintln!("parse {path}: {e}");
                return 2;
            }
        }
    } else {
        simulate(&cfg)
    };
    let mut t = Table::new(
        &format!(
            "simulate: {} ({} jobs, {} GPUs)",
            cfg.policy.name(),
            cfg.n_jobs,
            cfg.cluster.total_gpus()
        ),
        &["metric", "value"],
    );
    t.row(&["completed jobs".into(), r.jct.len().to_string()]);
    t.row(&["mean JCT (s)".into(), format!("{:.1}", r.mean_jct)]);
    t.row(&["p99 JCT (s)".into(), format!("{:.1}", r.p99_jct)]);
    t.row(&[
        "avg throughput (samples/s)".into(),
        format!("{:.2}", r.avg_throughput),
    ]);
    t.row(&[
        "avg GPU utilization".into(),
        format!("{:.1}%", r.avg_gpu_util * 100.0),
    ]);
    t.row(&["makespan (s)".into(), format!("{:.0}", r.makespan)]);
    t.row(&["mean slowdown".into(), format!("{:.3}", r.mean_slowdown)]);
    t.row(&[
        "goodput (samples/s)".into(),
        format!("{:.2}", r.goodput),
    ]);
    t.row(&[
        "SLO attainment".into(),
        format!("{:.1}%", r.slo_attainment * 100.0),
    ]);
    t.row(&["scheduling rounds".into(), r.sched_rounds.to_string()]);
    t.row(&["events processed".into(), r.events.to_string()]);
    if cfg.faults.enabled() || cfg.faults.gpu_mtbf_s > 0.0
        || r.restarts > 0
    {
        t.row(&["node failures".into(), r.node_failures.to_string()]);
        t.row(&["preemptions".into(), r.preemptions.to_string()]);
        t.row(&["restarts".into(), r.restarts.to_string()]);
        if cfg.faults.gpu_mtbf_s > 0.0 || r.gpu_failures > 0 {
            t.row(&["GPU failures".into(), r.gpu_failures.to_string()]);
            t.row(&[
                "holed GPU-time (s)".into(),
                format!("{:.1}", r.holed_gpu_time_s),
            ]);
        }
        if cfg.faults.shrink || r.shrinks > 0 {
            t.row(&["gang shrinks".into(), r.shrinks.to_string()]);
            t.row(&["gang regrows".into(), r.regrows.to_string()]);
            t.row(&[
                "degraded-rate time (s)".into(),
                format!("{:.1}", r.degraded_rate_time_s),
            ]);
        }
        t.row(&[
            "lost step-time (s)".into(),
            format!("{:.1}", r.lost_step_time_s),
        ]);
        t.row(&[
            "restore delay (s)".into(),
            format!("{:.1}", r.restore_delay_s),
        ]);
    }
    if cfg.stragglers.enabled() || r.node_degrades > 0 {
        t.row(&["node degrades".into(), r.node_degrades.to_string()]);
        t.row(&[
            "degraded node-time (s)".into(),
            format!("{:.1}", r.degraded_node_time_s),
        ]);
        t.row(&[
            "straggler slowdown".into(),
            format!("{:.2}x", r.straggler_slowdown),
        ]);
        t.row(&[
            "straggler migrations".into(),
            r.migrations.to_string(),
        ]);
    }
    if !r.incomplete_jobs.is_empty() {
        t.row(&[
            "INCOMPLETE jobs".into(),
            format!("{} ({:?})", r.incomplete_jobs.len(),
                    r.incomplete_jobs),
        ]);
    }
    t.print();
    if !r.incomplete_jobs.is_empty() {
        eprintln!(
            "warning: {} job(s) never completed (unsatisfiable GPU \
             request or simulation cutoff); JCT/throughput metrics \
             cover completed jobs only",
            r.incomplete_jobs.len()
        );
    }
    0
}

fn cmd_compare(args: &Args) -> i32 {
    let base = match build_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let mut t = Table::new(
        &format!(
            "policy comparison ({} jobs, {} GPUs, seed {})",
            base.n_jobs,
            base.cluster.total_gpus(),
            base.seed
        ),
        &["policy", "thr (samples/s)", "mean JCT (s)", "p99 JCT (s)",
          "GPU util"],
    );
    for policy in Policy::all() {
        let mut cfg = base.clone();
        cfg.policy = policy;
        let r = simulate(&cfg);
        t.row(&[
            policy.name().to_string(),
            format!("{:.2}", r.avg_throughput),
            format!("{:.1}", r.mean_jct),
            format!("{:.1}", r.p99_jct),
            format!("{:.1}%", r.avg_gpu_util * 100.0),
        ]);
    }
    t.print();
    0
}

/// Parse a comma-separated flag into a typed list, with a default.
fn parse_list<T: std::str::FromStr>(
    args: &Args,
    name: &str,
    default: Vec<T>,
) -> Result<Vec<T>, String> {
    match args.get(name) {
        None => Ok(default),
        Some(raw) => {
            let mut out = vec![];
            for tok in raw.split(',').map(str::trim) {
                if tok.is_empty() {
                    continue;
                }
                out.push(tok.parse::<T>().map_err(|_| {
                    format!("--{name}: cannot parse {tok:?}")
                })?);
            }
            if out.is_empty() {
                return Err(format!("--{name}: empty list"));
            }
            Ok(out)
        }
    }
}

fn parse_policies(
    args: &Args,
    default: Policy,
) -> Result<Vec<Policy>, String> {
    if args.get("policies") == Some("all") {
        return Ok(Policy::all().to_vec());
    }
    parse_list(args, "policies", vec![default])
}

fn cmd_sweep(args: &Args) -> i32 {
    let build = || -> Result<tlora::sweep::SweepGrid, String> {
        let mut grid = tlora::sweep::SweepGrid::default();
        // --config loads FIRST so its policy/n_jobs/n_gpus/seed become
        // the axis defaults below (explicit axis flags still win). The
        // trace itself is rebuilt per grid cell from --months and
        // --rate-scales, so trace keys in the file cannot take effect.
        if let Some(path) = args.get("config") {
            let j = tlora::util::json::parse_file(
                std::path::Path::new(path),
            )?;
            for key in ["trace_rate", "burst_prob"] {
                if j.get(key).is_some() {
                    eprintln!(
                        "sweep: note: config key {key} is overridden \
                         by the --months/--rate-scales axes"
                    );
                }
            }
            grid.base.apply_json(&j)?;
        }
        grid.policies = parse_policies(args, grid.base.policy)?;
        grid.n_jobs = parse_list(args, "n-jobs", vec![grid.base.n_jobs])?;
        grid.gpus = parse_list(
            args,
            "gpus",
            vec![grid.base.cluster.total_gpus()],
        )?;
        grid.rate_scales = parse_list(args, "rate-scales", vec![1.0])?;
        grid.months = parse_list(args, "months", vec![1])?;
        grid.mtbfs = parse_list(
            args,
            "mtbfs",
            vec![grid.base.faults.mtbf_s],
        )?;
        grid.gpu_mtbfs = parse_list(
            args,
            "gpu-mtbf",
            vec![grid.base.faults.gpu_mtbf_s],
        )?;
        grid.stragglers = parse_list(
            args,
            "stragglers",
            vec![grid.base.stragglers.mtbs_s],
        )?;
        grid.hardware_mixes = parse_list(
            args,
            "hardware-mix",
            vec![grid.base.cluster.hardware_mix.clone()],
        )?;
        grid.topologies = parse_list(
            args,
            "topology",
            vec![grid.base.cluster.topology.spec_str.clone()],
        )?;
        grid.shrinks = parse_list(
            args,
            "shrink",
            vec![grid.base.faults.shrink],
        )?;
        grid.seeds = parse_list(args, "seeds", vec![grid.base.seed])?;
        grid.validate()?;
        Ok(grid)
    };
    let grid = match build() {
        Ok(g) => g,
        Err(e) => {
            eprintln!("sweep config error: {e}");
            return 2;
        }
    };
    let threads = match args
        .get_usize("threads", tlora::sweep::default_threads())
    {
        Ok(t) => t.max(1),
        Err(e) => {
            eprintln!("sweep config error: {e}");
            return 2;
        }
    };
    println!(
        "sweep: {} scenarios x {} seeds = {} simulations on {} threads",
        grid.len() / grid.seeds.len(),
        grid.seeds.len(),
        grid.len(),
        threads.min(grid.len().max(1))
    );
    // --legacy-report: collect-everything path, kept as the
    // differential reference for the streaming writer (the two are
    // pinned byte-identical in tests/integration_report_stream.rs)
    if args.has("legacy-report") {
        return cmd_sweep_legacy(args, &grid, threads);
    }
    let json_path = args.get("out-json");
    let csv_path = args.get("out-csv");
    let json_opt = json_path.map(|p| {
        // --canonical: strip wall-clock + thread-count fields so the
        // file is bit-identical across runs and thread counts (golden
        // fixtures, CI determinism diffs)
        (std::path::Path::new(p), args.has("canonical"))
    });
    let (cells, stats) = match tlora::sweep::run_streaming_report(
        &grid,
        threads,
        json_opt,
        csv_path.map(std::path::Path::new),
    ) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("sweep failed: {e}");
            return 1;
        }
    };
    tlora::sweep::sweep_table(
        &format!(
            "sweep — {} cells in {:.2}s on {} threads",
            stats.n_points, stats.wall_s, stats.n_threads
        ),
        &cells,
    )
    .print();
    if let Some(path) = json_path {
        println!("JSON report -> {path}");
    }
    if let Some(path) = csv_path {
        println!("CSV report -> {path}");
    }
    0
}

fn cmd_sweep_legacy(
    args: &Args,
    grid: &tlora::sweep::SweepGrid,
    threads: usize,
) -> i32 {
    let run = match tlora::sweep::run(grid, threads) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("sweep failed: {e}");
            return 1;
        }
    };
    let cells = tlora::sweep::aggregate(&run);
    tlora::sweep::sweep_table(
        &format!(
            "sweep — {} cells in {:.2}s on {} threads",
            run.points.len(),
            run.wall_s,
            run.n_threads
        ),
        &cells,
    )
    .print();
    if let Some(path) = args.get("out-json") {
        let text = if args.has("canonical") {
            tlora::sweep::to_json_canonical(&run).to_pretty()
        } else {
            tlora::sweep::to_json(&run).to_pretty()
        };
        match std::fs::write(path, text) {
            Ok(()) => println!("JSON report -> {path}"),
            Err(e) => {
                eprintln!("write {path}: {e}");
                return 1;
            }
        }
    }
    if let Some(path) = args.get("out-csv") {
        match std::fs::write(path, tlora::sweep::to_csv(&run)) {
            Ok(()) => println!("CSV report -> {path}"),
            Err(e) => {
                eprintln!("write {path}: {e}");
                return 1;
            }
        }
    }
    0
}

fn cmd_train(args: &Args) -> i32 {
    let variant = args.get_or("variant", "tiny").to_string();
    let steps = args.get_u64("steps", 50).unwrap_or(50);
    let seed = args.get_u64("seed", 0).unwrap_or(0);
    let log_every = args.get_u64("log-every", 10).unwrap_or(10);
    // --resume file.ckpt / --save file.ckpt go through the lower-level
    // trainer path; the plain run uses the driver
    if args.get("resume").is_some() || args.get("save").is_some() {
        return cmd_train_ckpt(args, &variant, steps, seed);
    }
    match tlora::train::train_variant(
        &artifacts_dir(args),
        &variant,
        steps,
        seed,
        log_every,
    ) {
        Ok(report) => {
            print!("{}", report.render());
            if report.converged() {
                println!("loss decreased: OK");
                0
            } else {
                println!("WARNING: loss did not decrease");
                1
            }
        }
        Err(e) => {
            eprintln!("train failed: {e:#}");
            1
        }
    }
}

fn cmd_train_ckpt(args: &Args, variant: &str, steps: u64, seed: u64)
    -> i32 {
    let run = || -> anyhow::Result<()> {
        use tlora::runtime::{Checkpoint, Runtime, Trainer};
        use tlora::train::data::SyntheticCorpus;
        let rt = Runtime::new(&artifacts_dir(args))?;
        let mut trainer = match args.get("resume") {
            Some(path) => {
                let ck = Checkpoint::load(std::path::Path::new(path))?;
                println!(
                    "resumed {} at step {} from {path}",
                    ck.variant, ck.steps_done
                );
                ck.restore(&rt)?
            }
            None => Trainer::new(&rt, variant, seed as i32)?,
        };
        let cfg = trainer.variant().config.clone();
        let mut corpus = SyntheticCorpus::new(
            cfg.vocab,
            cfg.seq_len,
            cfg.num_adapters,
            seed ^ 0xDA7A,
        );
        // replay the corpus to the current step so resume continues the
        // same data stream
        for _ in 0..trainer.steps_done {
            let _ = corpus.fused_batch(&cfg.batch_sizes);
        }
        let mut last = f32::NAN;
        for s in 0..steps {
            let (tokens, ids) = corpus.fused_batch(&cfg.batch_sizes);
            let st = trainer.step(&tokens, &ids)?;
            last = st.loss;
            if s % 10 == 0 {
                println!("step {:>6} loss {:.4}", trainer.steps_done,
                         st.loss);
            }
        }
        println!("final loss {last:.4} at step {}", trainer.steps_done);
        if let Some(path) = args.get("save") {
            Checkpoint::capture(&trainer, seed as i32)?
                .save(std::path::Path::new(path))?;
            println!("checkpoint -> {path}");
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("train failed: {e:#}");
            1
        }
    }
}

fn cmd_microbench(args: &Args) -> i32 {
    let steps = args.get_u64("steps", 5).unwrap_or(5);
    let variants = ["tiny", "small", "med"];
    match tlora::train::calibrate(
        &artifacts_dir(args),
        &variants,
        &["tiny", "small"],
        2,
        steps,
    ) {
        Ok(results) => {
            let mut t = Table::new(
                "microbench: measured vs simulator-extrapolated step time",
                &["variant", "measured (ms)", "predicted (ms)", "error",
                  "role"],
            );
            for r in &results {
                t.row(&[
                    r.variant.clone(),
                    format!("{:.1}", r.measured_step_s * 1e3),
                    format!("{:.1}", r.predicted_step_s * 1e3),
                    format!("{:.1}%", r.error * 100.0),
                    if r.is_calibration {
                        "calibration".into()
                    } else {
                        "held-out".into()
                    },
                ]);
            }
            t.print();
            0
        }
        Err(e) => {
            eprintln!("microbench failed: {e:#}");
            1
        }
    }
}

fn cmd_trace_gen(args: &Args) -> i32 {
    let n = args.get_usize("n-jobs", 100).unwrap_or(100);
    let seed = args.get_u64("seed", 42).unwrap_or(42);
    let mut profile = if args.has("hyperscale") {
        TraceProfile::hyperscale()
    } else {
        match args.get_usize("month", 1).unwrap_or(1) {
            2 => TraceProfile::month2(),
            3 => TraceProfile::month3(),
            _ => TraceProfile::month1(),
        }
    };
    let period = match args.get_f64("diurnal-period", 86_400.0) {
        Ok(v) if v > 0.0 => v,
        Ok(v) => {
            eprintln!("--diurnal-period: must be positive, got {v}");
            return 2;
        }
        Err(e) => {
            eprintln!("argument error: {e}");
            return 2;
        }
    };
    if args.get("diurnal-amp").is_some() {
        let amp = match args.get_f64("diurnal-amp", 0.0) {
            Ok(a) if (0.0..1.0).contains(&a) => a,
            Ok(a) => {
                eprintln!("--diurnal-amp: must be in [0, 1), got {a}");
                return 2;
            }
            Err(e) => {
                eprintln!("argument error: {e}");
                return 2;
            }
        };
        profile.diurnal = Some(DiurnalProfile {
            period_s: period,
            amplitude: amp,
            phase: 0.0,
        });
    } else if let Some(d) = profile.diurnal.as_mut() {
        // --hyperscale already enables a daily cycle; let
        // --diurnal-period reshape it without restating the amplitude
        if args.get("diurnal-period").is_some() {
            d.period_s = period;
        }
    }
    let jobs = TraceGenerator::new(profile, seed).generate(n);
    let csv = save_csv(&jobs);
    match args.get("out") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, &csv) {
                eprintln!("write {path}: {e}");
                return 1;
            }
            println!("wrote {n} jobs to {path}");
        }
        None => print!("{csv}"),
    }
    0
}
