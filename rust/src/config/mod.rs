//! Typed experiment configuration with JSON I/O and validation.
//!
//! Everything a run needs — cluster shape, workload profile, scheduler
//! policy, kernel/AIMD knobs — in one validated struct, loadable from a
//! JSON file (`tlora simulate --config run.json`) and overridable from
//! the CLI. Defaults reproduce the paper's §4.1 setup.

use crate::cluster::ClusterSpec;
use crate::util::json::Json;
use crate::workload::trace::TraceProfile;

/// Which end-to-end policy stack to run (§4.1 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// full tLoRA: Adapter Scheduler + Model Fuser + Kernel Fuser
    TLora,
    /// ablation: mLoRA's memory-only grouping + tLoRA kernels
    TLoraNoSched,
    /// ablation: tLoRA scheduler + unfused per-adapter kernels
    TLoraNoKernel,
    /// mLoRA baseline: FIFO memory-capacity grouping, unfused kernels
    MLora,
    /// Megatron baseline: every job isolated on its own allocation
    Megatron,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::TLora => "tLoRA",
            Policy::TLoraNoSched => "tLoRA w/o Scheduler",
            Policy::TLoraNoKernel => "tLoRA w/o Kernel Fuser",
            Policy::MLora => "mLoRA",
            Policy::Megatron => "Megatron",
        }
    }

    /// Canonical machine-friendly name: exactly the strings
    /// [`Policy::parse`] accepts, so every emitted slug (config JSON,
    /// sweep labels/CSV/JSON) loads back.
    pub fn slug(&self) -> &'static str {
        match self {
            Policy::TLora => "tlora",
            Policy::TLoraNoSched => "tlora-no-sched",
            Policy::TLoraNoKernel => "tlora-no-kernel",
            Policy::MLora => "mlora",
            Policy::Megatron => "megatron",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "tlora" => Some(Policy::TLora),
            "tlora-no-sched" | "no-sched" => Some(Policy::TLoraNoSched),
            "tlora-no-kernel" | "no-kernel" => Some(Policy::TLoraNoKernel),
            "mlora" => Some(Policy::MLora),
            "megatron" => Some(Policy::Megatron),
            _ => None,
        }
    }

    pub fn all() -> [Policy; 5] {
        [
            Policy::TLora,
            Policy::TLoraNoSched,
            Policy::TLoraNoKernel,
            Policy::MLora,
            Policy::Megatron,
        ]
    }

    /// Does this policy group jobs with the tLoRA Adapter Scheduler?
    pub fn uses_tlora_scheduler(&self) -> bool {
        matches!(self, Policy::TLora | Policy::TLoraNoKernel)
    }

    /// Does this policy execute groups with the fused kernel + AIMD
    /// nano-batching?
    pub fn uses_kernel_fuser(&self) -> bool {
        matches!(self, Policy::TLora | Policy::TLoraNoSched)
    }

    /// Does this policy group at all?
    pub fn groups_jobs(&self) -> bool {
        !matches!(self, Policy::Megatron)
    }
}

impl std::str::FromStr for Policy {
    type Err = String;

    fn from_str(s: &str) -> Result<Policy, String> {
        Policy::parse(s).ok_or_else(|| format!("unknown policy {s}"))
    }
}

/// AIMD controller knobs (§3.3 Eq. 2; α=4, β=1/2 are the paper defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct AimdConfig {
    pub alpha: usize,
    pub beta: f64,
    /// stability margin τ as a fraction of the previous step time
    pub tau_frac: f64,
    /// initial nano-batch count
    pub n0: usize,
    pub n_max: usize,
}

impl Default for AimdConfig {
    fn default() -> Self {
        AimdConfig {
            alpha: 4,
            beta: 0.5,
            // τ as a fraction of the previous step time. Tight enough
            // that the shallow slope near the optimum still registers
            // as regression (a looser margin lets exploratory probes
            // ratchet N upward); the EMA of real step times supplies
            // the actual noise floor.
            tau_frac: 0.005,
            n0: 1,
            n_max: 64,
        }
    }
}

/// Adapter Scheduler knobs (§3.4).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerConfig {
    /// Maximum interval between scheduling rounds in seconds. The
    /// event-driven engine regroups reactively on every arrival and
    /// completion (§3.4); this bound caps how long a schedule under
    /// pressure (queued jobs, adapting AIMD controllers) may go
    /// unexamined. (Formerly the fixed per-horizon tick of the legacy
    /// loop — see `sim::EngineOptions::legacy_tick`.)
    pub horizon_s: f64,
    /// default Δ^max when a job does not specify one
    pub default_max_slowdown: f64,
    /// max jobs per fused group (memory/compile guardrail)
    pub max_group_size: usize,
    /// minimum predicted throughput gain to accept a merge
    pub min_merge_gain: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            horizon_s: 60.0,
            default_max_slowdown: 1.5,
            max_group_size: 8,
            min_merge_gain: 1.02,
        }
    }
}

/// Fault & SLO scenario knobs: node churn, exogenous preemptions, and
/// the checkpoint-restore cost model charged on every eviction.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Per-node mean time between failures in seconds (exponential).
    /// 0 disables node failures entirely.
    pub mtbf_s: f64,
    /// Per-node mean time to recovery in seconds (exponential). Must
    /// be > 0 whenever `mtbf_s` > 0.
    pub mttr_s: f64,
    /// Cluster-level preemption rate (events/second, Poisson). 0
    /// disables preemptions.
    pub preempt_rate: f64,
    /// Fixed restart overhead per evicted job (reschedule, process
    /// spin-up, backbone re-init from the recorded seed — the part of
    /// `runtime::Checkpoint::restore` that is size-independent).
    pub restore_overhead_s: f64,
    /// Bandwidth at which the adapter-only checkpoint (LoRA params +
    /// Adam moments, `model::cost`/`LoraSpec::train_state_bytes`) is
    /// read back, bytes/second.
    pub ckpt_read_bw: f64,
    /// Checkpoint cadence in steps (>= 1): a durable checkpoint exists
    /// at every multiple of this count, and an eviction rolls progress
    /// back to the last such boundary. The default of 1 models the
    /// optimistic every-step checkpoint the engine historically
    /// assumed — and keeps its accounting byte-identical
    /// (`floor(steps / 1.0) * 1.0 == floor(steps)` in IEEE bits).
    pub ckpt_interval_steps: u64,
    /// Seconds to write one periodic checkpoint. Charged into the
    /// effective step time as `ckpt_write_s / ckpt_interval_steps`
    /// (amortized), so cheap-but-rare and dear-but-frequent cadences
    /// trade off faithfully. 0 (the default) adds exactly nothing
    /// (`x + 0.0 == x` in IEEE bits).
    pub ckpt_write_s: f64,
    /// SLO deadline factor: a job meets its deadline when
    /// `jct <= slo_factor * max_slowdown * total_steps *
    /// iso_step_time` (queueing + churn allowance on top of its
    /// slowdown-adjusted ideal runtime).
    pub slo_factor: f64,
    /// Mean time between *correlated* failure episodes per failure
    /// domain (rack/switch), seconds, exponential. One episode fails
    /// every node under the drawn domain at once. 0 disables; only
    /// meaningful with a non-flat `--topology` (a flat cluster has no
    /// domains).
    pub domain_mtbf_s: f64,
    /// Mean recovery time for a domain episode, seconds. Must be > 0
    /// whenever `domain_mtbf_s` > 0.
    pub domain_mttr_s: f64,
    /// Per-GPU mean time between single-device failures in seconds
    /// (exponential, seeded independently per (node, gpu)). A hit
    /// holes one GPU out of its node — the rest of the node keeps
    /// serving — and evicts only the gangs touching that device. 0
    /// disables GPU faults entirely.
    pub gpu_mtbf_s: f64,
    /// Per-GPU mean time to recovery in seconds (exponential). Must
    /// be > 0 whenever `gpu_mtbf_s` > 0.
    pub gpu_mttr_s: f64,
    /// Wear coupling for the per-device renewal stream: a device's
    /// effective MTBF for its next uptime draw is
    /// `gpu_mtbf_s / (1.0 + gpu_wear_alpha * wear)` where `wear` is
    /// its accumulated service time in MTBF units plus its past
    /// failure count. Pure in `(seed, node, gpu)` like the base
    /// stream. The default `0.0` is an exact float no-op
    /// (`x / (1.0 + 0.0 * w) == x` in IEEE bits for finite `w`).
    pub gpu_wear_alpha: f64,
    /// Graceful degradation: when a `GpuFailure` holes a device inside
    /// a running gang and the active policy supports it
    /// (`PolicyHooks::shrinks_in_place`), the gang is shrunk in place —
    /// re-planned at the surviving width, members rolled back only to
    /// the last checkpoint boundary without a restart penalty — and
    /// regrown when the allocator can backfill. Members whose Δ^max
    /// would be violated at the shrunken rate spill through the normal
    /// eviction/requeue path. Off (the default) keeps the historic
    /// evict-whole-gang semantics byte-identically.
    pub shrink: bool,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            mtbf_s: 0.0,
            mttr_s: 600.0,
            preempt_rate: 0.0,
            restore_overhead_s: 30.0,
            ckpt_read_bw: 1.0e9,
            ckpt_interval_steps: 1,
            ckpt_write_s: 0.0,
            slo_factor: 3.0,
            domain_mtbf_s: 0.0,
            domain_mttr_s: 600.0,
            gpu_mtbf_s: 0.0,
            gpu_mttr_s: 600.0,
            gpu_wear_alpha: 0.0,
            shrink: false,
        }
    }
}

impl FaultConfig {
    /// Is any fault source active?
    pub fn enabled(&self) -> bool {
        self.mtbf_s > 0.0 || self.preempt_rate > 0.0
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.mtbf_s < 0.0 || self.preempt_rate < 0.0 {
            return Err("faults: mtbf_s/preempt_rate must be >= 0".into());
        }
        if self.mtbf_s > 0.0 && self.mttr_s <= 0.0 {
            return Err("faults: mttr_s must be > 0 with failures on".into());
        }
        if self.restore_overhead_s < 0.0 {
            return Err("faults: restore_overhead_s must be >= 0".into());
        }
        if self.ckpt_read_bw <= 0.0 {
            return Err("faults: ckpt_read_bw must be > 0".into());
        }
        if self.ckpt_interval_steps == 0 {
            return Err(
                "faults: ckpt_interval_steps must be >= 1".into()
            );
        }
        if !(self.ckpt_write_s >= 0.0 && self.ckpt_write_s.is_finite())
        {
            return Err(
                "faults: ckpt_write_s must be finite and >= 0".into()
            );
        }
        if self.slo_factor <= 0.0 {
            return Err("faults: slo_factor must be > 0".into());
        }
        if self.domain_mtbf_s < 0.0 {
            return Err("faults: domain_mtbf_s must be >= 0".into());
        }
        if self.domain_mtbf_s > 0.0 && self.domain_mttr_s <= 0.0 {
            return Err(
                "faults: domain_mttr_s must be > 0 with domain \
                 episodes on"
                    .into(),
            );
        }
        if self.gpu_mtbf_s < 0.0 {
            return Err("faults: gpu_mtbf_s must be >= 0".into());
        }
        if self.gpu_mtbf_s > 0.0 && self.gpu_mttr_s <= 0.0 {
            return Err(
                "faults: gpu_mttr_s must be > 0 with GPU faults on"
                    .into(),
            );
        }
        if !(self.gpu_wear_alpha >= 0.0
            && self.gpu_wear_alpha.is_finite())
        {
            return Err(
                "faults: gpu_wear_alpha must be finite and >= 0".into()
            );
        }
        Ok(())
    }
}

/// Straggler (degraded-node) scenario knobs: seeded slow-node episodes
/// plus the detection machinery tLoRA's scheduler uses to route around
/// them (`scheduler::NodeSpeedEstimator`). Oblivious baselines ignore
/// every `detect_*` knob.
#[derive(Debug, Clone, PartialEq)]
pub struct StragglerConfig {
    /// Per-node mean time between straggler episodes in seconds
    /// (exponential). 0 disables the seeded straggler model entirely
    /// (scripted stragglers via `EngineOptions::straggler_script`
    /// still apply).
    pub mtbs_s: f64,
    /// Mean degraded-span duration in seconds (exponential). Must be
    /// > 0 whenever `mtbs_s` > 0.
    pub mtts_s: f64,
    /// Episode severity bounds: the degraded node's speed multiplier
    /// is drawn uniformly from `[severity_min, severity_max]`,
    /// requiring `0 < min <= max < 1`.
    pub severity_min: f64,
    pub severity_max: f64,
    /// Straggler detection on/off for detection-capable policies
    /// (`PolicyHooks::straggler_aware`). Off = even tLoRA runs
    /// oblivious — the control arm of the detection-vs-oblivious
    /// comparison.
    pub detect: bool,
    /// EWMA weight per observed step for the per-node slowdown
    /// estimate, in (0, 1]. Smaller = smoother but later detection —
    /// this is the detection-lag knob.
    pub detect_alpha: f64,
    /// A node is *suspected* (no new placements or riders) when its
    /// estimated slowdown exceeds this factor (> 1).
    pub detect_threshold: f64,
    /// Jobs allocated on a node whose estimated slowdown exceeds this
    /// factor are migrated off it (evicted with the usual
    /// checkpoint-restore cost and re-placed on healthy nodes). Must
    /// be >= `detect_threshold`.
    pub migrate_threshold: f64,
    /// Forgiveness time constant (seconds, > 0): a node that produces
    /// *no* observations over an interval `dt` has its estimate pulled
    /// toward healthy by `exp(-dt / rehab_tau_s)`. Without this, an
    /// avoided node could never be exonerated — suspicion suppresses
    /// the very placements whose observations would clear it.
    pub rehab_tau_s: f64,
    /// Mean time between *correlated* straggler episodes per failure
    /// domain (shared switch / power domain), seconds, exponential.
    /// One draw degrades every node under the domain to the same
    /// sampled severity. 0 disables; needs a non-flat `--topology`.
    pub domain_mtbs_s: f64,
    /// Mean degraded-span duration for a domain episode, seconds.
    /// Must be > 0 whenever `domain_mtbs_s` > 0.
    pub domain_mtts_s: f64,
}

impl Default for StragglerConfig {
    fn default() -> Self {
        StragglerConfig {
            mtbs_s: 0.0,
            mtts_s: 900.0,
            severity_min: 0.2,
            severity_max: 0.5,
            detect: true,
            detect_alpha: 0.08,
            detect_threshold: 1.25,
            migrate_threshold: 1.6,
            rehab_tau_s: 600.0,
            domain_mtbs_s: 0.0,
            domain_mtts_s: 900.0,
        }
    }
}

impl StragglerConfig {
    /// Is the seeded straggler model active?
    pub fn enabled(&self) -> bool {
        self.mtbs_s > 0.0
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.mtbs_s < 0.0 {
            return Err("stragglers: mtbs_s must be >= 0".into());
        }
        if self.mtbs_s > 0.0 && self.mtts_s <= 0.0 {
            return Err(
                "stragglers: mtts_s must be > 0 with episodes on"
                    .into(),
            );
        }
        if !(self.severity_min > 0.0
            && self.severity_min <= self.severity_max
            && self.severity_max < 1.0)
        {
            return Err(
                "stragglers: severity bounds must satisfy \
                 0 < min <= max < 1"
                    .into(),
            );
        }
        if !(self.detect_alpha > 0.0 && self.detect_alpha <= 1.0) {
            return Err(
                "stragglers: detect_alpha must be in (0,1]".into()
            );
        }
        if self.detect_threshold <= 1.0 {
            return Err(
                "stragglers: detect_threshold must be > 1".into()
            );
        }
        if self.migrate_threshold < self.detect_threshold {
            return Err(
                "stragglers: migrate_threshold must be >= \
                 detect_threshold"
                    .into(),
            );
        }
        if self.rehab_tau_s <= 0.0 {
            return Err(
                "stragglers: rehab_tau_s must be > 0".into()
            );
        }
        if self.domain_mtbs_s < 0.0 {
            return Err(
                "stragglers: domain_mtbs_s must be >= 0".into()
            );
        }
        if self.domain_mtbs_s > 0.0 && self.domain_mtts_s <= 0.0 {
            return Err(
                "stragglers: domain_mtts_s must be > 0 with domain \
                 episodes on"
                    .into(),
            );
        }
        Ok(())
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub policy: Policy,
    pub cluster: ClusterSpec,
    pub trace: TraceProfile,
    pub n_jobs: usize,
    pub seed: u64,
    pub scheduler: SchedulerConfig,
    pub aimd: AimdConfig,
    pub faults: FaultConfig,
    pub stragglers: StragglerConfig,
    /// global concurrency cap (§A.1: 128 runnable jobs)
    pub max_concurrent_jobs: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            policy: Policy::TLora,
            cluster: ClusterSpec::default_128(),
            trace: TraceProfile::month1(),
            n_jobs: 200,
            seed: 42,
            scheduler: SchedulerConfig::default(),
            aimd: AimdConfig::default(),
            faults: FaultConfig::default(),
            stragglers: StragglerConfig::default(),
            max_concurrent_jobs: 128,
        }
    }
}

impl ExperimentConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.cluster.total_gpus() == 0 {
            return Err("cluster has zero GPUs".into());
        }
        if self.n_jobs == 0 {
            return Err("n_jobs must be > 0".into());
        }
        if !(0.0..1.0).contains(&self.aimd.beta) {
            return Err(format!("aimd.beta {} not in (0,1)", self.aimd.beta));
        }
        if self.aimd.n0 == 0 || self.aimd.n_max < self.aimd.n0 {
            return Err("aimd n0/n_max invalid".into());
        }
        if self.scheduler.horizon_s <= 0.0 {
            return Err("scheduler horizon must be positive".into());
        }
        if self.scheduler.max_group_size == 0 {
            return Err("max_group_size must be > 0".into());
        }
        if self.trace.rate <= 0.0 {
            return Err("trace rate must be positive".into());
        }
        self.faults.validate()?;
        self.stragglers.validate()?;
        self.cluster.validate()?;
        Ok(())
    }

    // ---------------- JSON ----------------

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("policy", self.policy.slug())
            .set("n_gpus", self.cluster.total_gpus())
            .set("n_jobs", self.n_jobs)
            .set("seed", self.seed)
            .set("trace_rate", self.trace.rate)
            .set("burst_prob", self.trace.burst_prob)
            .set("horizon_s", self.scheduler.horizon_s)
            .set("max_group_size", self.scheduler.max_group_size)
            .set("min_merge_gain", self.scheduler.min_merge_gain)
            .set("default_max_slowdown",
                 self.scheduler.default_max_slowdown)
            .set("aimd_alpha", self.aimd.alpha)
            .set("aimd_beta", self.aimd.beta)
            .set("aimd_tau_frac", self.aimd.tau_frac)
            .set("aimd_n0", self.aimd.n0)
            .set("aimd_n_max", self.aimd.n_max)
            .set("max_concurrent_jobs", self.max_concurrent_jobs)
            .set(
                "faults",
                Json::obj()
                    .set("mtbf_s", self.faults.mtbf_s)
                    .set("mttr_s", self.faults.mttr_s)
                    .set("preempt_rate", self.faults.preempt_rate)
                    .set(
                        "restore_overhead_s",
                        self.faults.restore_overhead_s,
                    )
                    .set("ckpt_read_bw", self.faults.ckpt_read_bw)
                    .set(
                        "ckpt_interval_steps",
                        self.faults.ckpt_interval_steps,
                    )
                    .set("ckpt_write_s", self.faults.ckpt_write_s)
                    .set("slo_factor", self.faults.slo_factor)
                    .set("domain_mtbf_s", self.faults.domain_mtbf_s)
                    .set("domain_mttr_s", self.faults.domain_mttr_s)
                    .set("gpu_mtbf_s", self.faults.gpu_mtbf_s)
                    .set("gpu_mttr_s", self.faults.gpu_mttr_s)
                    .set(
                        "gpu_wear_alpha",
                        self.faults.gpu_wear_alpha,
                    )
                    .set("shrink", self.faults.shrink),
            )
            .set(
                "hardware",
                Json::obj()
                    .set("mix", self.cluster.hardware_mix.as_str()),
            )
            .set(
                "topology",
                Json::obj().set(
                    "spec",
                    self.cluster.topology.spec_str.as_str(),
                ),
            )
            .set(
                "stragglers",
                Json::obj()
                    .set("mtbs_s", self.stragglers.mtbs_s)
                    .set("mtts_s", self.stragglers.mtts_s)
                    .set("severity_min", self.stragglers.severity_min)
                    .set("severity_max", self.stragglers.severity_max)
                    .set("detect", self.stragglers.detect)
                    .set("detect_alpha", self.stragglers.detect_alpha)
                    .set(
                        "detect_threshold",
                        self.stragglers.detect_threshold,
                    )
                    .set(
                        "migrate_threshold",
                        self.stragglers.migrate_threshold,
                    )
                    .set("rehab_tau_s", self.stragglers.rehab_tau_s)
                    .set(
                        "domain_mtbs_s",
                        self.stragglers.domain_mtbs_s,
                    )
                    .set(
                        "domain_mtts_s",
                        self.stragglers.domain_mtts_s,
                    ),
            )
    }

    /// Apply JSON overrides onto `self` (missing keys keep defaults).
    pub fn apply_json(&mut self, j: &Json) -> Result<(), String> {
        if let Some(p) = j.get("policy").and_then(Json::as_str) {
            self.policy = Policy::parse(p)
                .ok_or_else(|| format!("unknown policy {p}"))?;
        }
        if let Some(n) = j.get("n_gpus").and_then(Json::as_usize) {
            // rebuilding the cluster must not drop a previously applied
            // hardware mix or topology (e.g. config file sets them, a
            // later CLI override resizes the fleet)
            let mix = self.cluster.hardware_mix.clone();
            let topo = self.cluster.topology.spec_str.clone();
            self.cluster = ClusterSpec::with_gpus(n);
            self.cluster.apply_hardware_mix(&mix)?;
            self.cluster.apply_topology(&topo)?;
        }
        if let Some(n) = j.get("n_jobs").and_then(Json::as_usize) {
            self.n_jobs = n;
        }
        if let Some(s) = j.get("seed").and_then(Json::as_i64) {
            self.seed = s as u64;
        }
        if let Some(r) = j.get("trace_rate").and_then(Json::as_f64) {
            self.trace.rate = r;
        }
        if let Some(p) = j.get("burst_prob").and_then(Json::as_f64) {
            self.trace.burst_prob = p;
        }
        if let Some(h) = j.get("horizon_s").and_then(Json::as_f64) {
            self.scheduler.horizon_s = h;
        }
        if let Some(m) = j.get("max_group_size").and_then(Json::as_usize) {
            self.scheduler.max_group_size = m;
        }
        if let Some(g) = j.get("min_merge_gain").and_then(Json::as_f64) {
            self.scheduler.min_merge_gain = g;
        }
        if let Some(d) = j.get("default_max_slowdown").and_then(Json::as_f64)
        {
            self.scheduler.default_max_slowdown = d;
        }
        if let Some(a) = j.get("aimd_alpha").and_then(Json::as_usize) {
            self.aimd.alpha = a;
        }
        if let Some(b) = j.get("aimd_beta").and_then(Json::as_f64) {
            self.aimd.beta = b;
        }
        if let Some(t) = j.get("aimd_tau_frac").and_then(Json::as_f64) {
            self.aimd.tau_frac = t;
        }
        if let Some(n) = j.get("aimd_n0").and_then(Json::as_usize) {
            self.aimd.n0 = n;
        }
        if let Some(n) = j.get("aimd_n_max").and_then(Json::as_usize) {
            self.aimd.n_max = n;
        }
        if let Some(m) =
            j.get("max_concurrent_jobs").and_then(Json::as_usize)
        {
            self.max_concurrent_jobs = m;
        }
        if let Some(f) = j.get("faults") {
            if let Some(v) = f.get("mtbf_s").and_then(Json::as_f64) {
                self.faults.mtbf_s = v;
            }
            if let Some(v) = f.get("mttr_s").and_then(Json::as_f64) {
                self.faults.mttr_s = v;
            }
            if let Some(v) =
                f.get("preempt_rate").and_then(Json::as_f64)
            {
                self.faults.preempt_rate = v;
            }
            if let Some(v) =
                f.get("restore_overhead_s").and_then(Json::as_f64)
            {
                self.faults.restore_overhead_s = v;
            }
            if let Some(v) =
                f.get("ckpt_read_bw").and_then(Json::as_f64)
            {
                self.faults.ckpt_read_bw = v;
            }
            if let Some(v) =
                f.get("ckpt_interval_steps").and_then(Json::as_i64)
            {
                self.faults.ckpt_interval_steps = v.max(0) as u64;
            }
            if let Some(v) =
                f.get("ckpt_write_s").and_then(Json::as_f64)
            {
                self.faults.ckpt_write_s = v;
            }
            if let Some(v) = f.get("slo_factor").and_then(Json::as_f64)
            {
                self.faults.slo_factor = v;
            }
            if let Some(v) =
                f.get("domain_mtbf_s").and_then(Json::as_f64)
            {
                self.faults.domain_mtbf_s = v;
            }
            if let Some(v) =
                f.get("domain_mttr_s").and_then(Json::as_f64)
            {
                self.faults.domain_mttr_s = v;
            }
            if let Some(v) = f.get("gpu_mtbf_s").and_then(Json::as_f64)
            {
                self.faults.gpu_mtbf_s = v;
            }
            if let Some(v) = f.get("gpu_mttr_s").and_then(Json::as_f64)
            {
                self.faults.gpu_mttr_s = v;
            }
            if let Some(v) =
                f.get("gpu_wear_alpha").and_then(Json::as_f64)
            {
                self.faults.gpu_wear_alpha = v;
            }
            if let Some(v) = f.get("shrink").and_then(Json::as_bool) {
                self.faults.shrink = v;
            }
        }
        if let Some(s) = j.get("stragglers") {
            if let Some(v) = s.get("mtbs_s").and_then(Json::as_f64) {
                self.stragglers.mtbs_s = v;
            }
            if let Some(v) = s.get("mtts_s").and_then(Json::as_f64) {
                self.stragglers.mtts_s = v;
            }
            if let Some(v) =
                s.get("severity_min").and_then(Json::as_f64)
            {
                self.stragglers.severity_min = v;
            }
            if let Some(v) =
                s.get("severity_max").and_then(Json::as_f64)
            {
                self.stragglers.severity_max = v;
            }
            if let Some(v) = s.get("detect").and_then(Json::as_bool) {
                self.stragglers.detect = v;
            }
            if let Some(v) =
                s.get("detect_alpha").and_then(Json::as_f64)
            {
                self.stragglers.detect_alpha = v;
            }
            if let Some(v) =
                s.get("detect_threshold").and_then(Json::as_f64)
            {
                self.stragglers.detect_threshold = v;
            }
            if let Some(v) =
                s.get("migrate_threshold").and_then(Json::as_f64)
            {
                self.stragglers.migrate_threshold = v;
            }
            if let Some(v) =
                s.get("rehab_tau_s").and_then(Json::as_f64)
            {
                self.stragglers.rehab_tau_s = v;
            }
            if let Some(v) =
                s.get("domain_mtbs_s").and_then(Json::as_f64)
            {
                self.stragglers.domain_mtbs_s = v;
            }
            if let Some(v) =
                s.get("domain_mtts_s").and_then(Json::as_f64)
            {
                self.stragglers.domain_mtts_s = v;
            }
        }
        // applied after `n_gpus` (which rebuilds the cluster): the mix
        // and topology layer onto whatever fleet size is now in effect
        if let Some(h) = j.get("hardware") {
            if let Some(m) = h.get("mix").and_then(Json::as_str) {
                self.cluster.apply_hardware_mix(m)?;
            }
        }
        if let Some(t) = j.get("topology") {
            if let Some(s) = t.get("spec").and_then(Json::as_str) {
                self.cluster.apply_topology(s)?;
            }
        }
        self.validate()
    }

    pub fn from_json(j: &Json) -> Result<ExperimentConfig, String> {
        let mut c = ExperimentConfig::default();
        c.apply_json(j)?;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn default_is_valid() {
        assert!(ExperimentConfig::default().validate().is_ok());
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in Policy::all() {
            let s = match p {
                Policy::TLora => "tlora",
                Policy::TLoraNoSched => "tlora-no-sched",
                Policy::TLoraNoKernel => "tlora-no-kernel",
                Policy::MLora => "mlora",
                Policy::Megatron => "megatron",
            };
            assert_eq!(Policy::parse(s), Some(p));
        }
        assert_eq!(Policy::parse("nonsense"), None);
    }

    #[test]
    fn policy_slug_parses_back() {
        for p in Policy::all() {
            assert_eq!(Policy::parse(p.slug()), Some(p), "{}", p.slug());
            assert_eq!(p.slug().parse::<Policy>(), Ok(p));
        }
        assert!("bogus".parse::<Policy>().is_err());
    }

    #[test]
    fn to_json_policy_roundtrips_for_every_policy() {
        // the emitted slug must load back — including the ablations,
        // whose display names ("tLoRA w/o Scheduler") are not parseable
        for p in Policy::all() {
            let mut c = ExperimentConfig::default();
            c.policy = p;
            let j = json::parse(&c.to_json().to_string()).unwrap();
            let back = ExperimentConfig::from_json(&j).unwrap();
            assert_eq!(back.policy, p);
        }
    }

    #[test]
    fn policy_capability_matrix() {
        assert!(Policy::TLora.uses_tlora_scheduler());
        assert!(Policy::TLora.uses_kernel_fuser());
        assert!(!Policy::MLora.uses_tlora_scheduler());
        assert!(!Policy::MLora.uses_kernel_fuser());
        assert!(Policy::TLoraNoSched.uses_kernel_fuser());
        assert!(!Policy::TLoraNoSched.uses_tlora_scheduler());
        assert!(Policy::TLoraNoKernel.uses_tlora_scheduler());
        assert!(!Policy::TLoraNoKernel.uses_kernel_fuser());
        assert!(!Policy::Megatron.groups_jobs());
    }

    #[test]
    fn json_roundtrip_overrides() {
        let text = r#"{"policy": "mlora", "n_gpus": 32, "n_jobs": 10,
                       "aimd_beta": 0.25, "horizon_s": 30.0}"#;
        let j = json::parse(text).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.policy, Policy::MLora);
        assert_eq!(c.cluster.total_gpus(), 32);
        assert_eq!(c.n_jobs, 10);
        assert_eq!(c.aimd.beta, 0.25);
        assert_eq!(c.scheduler.horizon_s, 30.0);
        // untouched keys keep defaults
        assert_eq!(c.aimd.alpha, 4);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ExperimentConfig::default();
        c.aimd.beta = 1.5;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.n_jobs = 0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.scheduler.horizon_s = -1.0;
        assert!(c.validate().is_err());
        let j = json::parse(r#"{"policy": "bogus"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn to_json_parses_back() {
        let c = ExperimentConfig::default();
        let j = c.to_json();
        let j2 = json::parse(&j.to_string()).unwrap();
        assert_eq!(j2.get("aimd_alpha").unwrap().as_usize().unwrap(), 4);
    }

    #[test]
    fn faults_default_disabled_and_valid() {
        let f = FaultConfig::default();
        assert!(!f.enabled());
        assert!(f.validate().is_ok());
        let mut c = ExperimentConfig::default();
        c.faults.mtbf_s = 3600.0;
        assert!(c.faults.enabled());
        assert!(c.validate().is_ok());
    }

    #[test]
    fn faults_section_roundtrips_through_json() {
        let mut c = ExperimentConfig::default();
        c.faults.mtbf_s = 1800.0;
        c.faults.mttr_s = 120.0;
        c.faults.preempt_rate = 0.001;
        c.faults.slo_factor = 2.5;
        let j = json::parse(&c.to_json().to_string()).unwrap();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.faults, c.faults);
        // partial override: only mtbf_s set, rest keep defaults
        let j = json::parse(r#"{"faults": {"mtbf_s": 900.0}}"#).unwrap();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.faults.mtbf_s, 900.0);
        assert_eq!(c2.faults.mttr_s, FaultConfig::default().mttr_s);
    }

    #[test]
    fn stragglers_default_disabled_and_valid() {
        let s = StragglerConfig::default();
        assert!(!s.enabled());
        assert!(s.validate().is_ok());
        let mut c = ExperimentConfig::default();
        c.stragglers.mtbs_s = 3600.0;
        assert!(c.stragglers.enabled());
        assert!(c.validate().is_ok());
    }

    #[test]
    fn stragglers_section_roundtrips_through_json() {
        let mut c = ExperimentConfig::default();
        c.stragglers.mtbs_s = 1800.0;
        c.stragglers.mtts_s = 300.0;
        c.stragglers.severity_min = 0.3;
        c.stragglers.severity_max = 0.6;
        c.stragglers.detect = false;
        c.stragglers.detect_alpha = 0.2;
        c.stragglers.detect_threshold = 1.4;
        c.stragglers.migrate_threshold = 2.0;
        c.stragglers.rehab_tau_s = 450.0;
        let j = json::parse(&c.to_json().to_string()).unwrap();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.stragglers, c.stragglers);
        // partial override keeps the other defaults
        let j =
            json::parse(r#"{"stragglers": {"mtbs_s": 900.0}}"#)
                .unwrap();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.stragglers.mtbs_s, 900.0);
        assert_eq!(
            c2.stragglers.detect,
            StragglerConfig::default().detect
        );
        assert_eq!(
            c2.stragglers.mtts_s,
            StragglerConfig::default().mtts_s
        );
    }

    #[test]
    fn invalid_straggler_configs_rejected() {
        let mut c = ExperimentConfig::default();
        c.stragglers.mtbs_s = -1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.stragglers.mtbs_s = 100.0;
        c.stragglers.mtts_s = 0.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.stragglers.severity_min = 0.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.stragglers.severity_max = 1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.stragglers.severity_min = 0.7;
        c.stragglers.severity_max = 0.4;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.stragglers.detect_alpha = 0.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.stragglers.detect_threshold = 1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.stragglers.migrate_threshold = 1.1;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.stragglers.rehab_tau_s = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn ckpt_cadence_defaults_pin_legacy_accounting() {
        // the optimistic every-step checkpoint the engine historically
        // assumed: interval 1, free writes — the byte-identity
        // differential in sim depends on these exact defaults
        let f = FaultConfig::default();
        assert_eq!(f.ckpt_interval_steps, 1);
        assert_eq!(f.ckpt_write_s, 0.0);
        assert!(f.validate().is_ok());
    }

    #[test]
    fn ckpt_cadence_roundtrips_and_validates() {
        let mut c = ExperimentConfig::default();
        c.faults.ckpt_interval_steps = 25;
        c.faults.ckpt_write_s = 4.5;
        let j = json::parse(&c.to_json().to_string()).unwrap();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.faults, c.faults);
        // partial override keeps the other knobs
        let j = json::parse(
            r#"{"faults": {"ckpt_interval_steps": 10}}"#,
        )
        .unwrap();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.faults.ckpt_interval_steps, 10);
        assert_eq!(c2.faults.ckpt_write_s, 0.0);
        // rejections
        let mut c = ExperimentConfig::default();
        c.faults.ckpt_interval_steps = 0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.faults.ckpt_write_s = -1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.faults.ckpt_write_s = f64::INFINITY;
        assert!(c.validate().is_err());
    }

    #[test]
    fn hardware_section_roundtrips_through_json() {
        let mut c = ExperimentConfig::default();
        c.cluster.apply_hardware_mix("a100*3:h100").unwrap();
        let j = json::parse(&c.to_json().to_string()).unwrap();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.cluster, c.cluster);
        assert!(!back.cluster.is_uniform_reference());
        // default emits an empty mix and loads back homogeneous
        let d = ExperimentConfig::default();
        let j = json::parse(&d.to_json().to_string()).unwrap();
        assert_eq!(j.path("hardware.mix").unwrap().as_str(), Some(""));
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.cluster, d.cluster);
    }

    #[test]
    fn hardware_mix_survives_n_gpus_override_and_rejects_garbage() {
        // mix from one apply, fleet resize from a later one: the
        // resized cluster keeps its tiers
        let mut c = ExperimentConfig::default();
        let j = json::parse(r#"{"hardware": {"mix": "a100:v100"}}"#)
            .unwrap();
        c.apply_json(&j).unwrap();
        let j = json::parse(r#"{"n_gpus": 32}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.cluster.total_gpus(), 32);
        assert_eq!(c.cluster.hardware_mix, "a100:v100");
        assert!(!c.cluster.is_uniform_reference());
        // both in one document: order of application is n_gpus first
        let j = json::parse(
            r#"{"n_gpus": 64, "hardware": {"mix": "h100"}}"#,
        )
        .unwrap();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.cluster.total_gpus(), 64);
        assert_eq!(c2.cluster.hardware_mix, "h100");
        // unknown generation is a load error
        let j = json::parse(r#"{"hardware": {"mix": "tpu9"}}"#)
            .unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn topology_section_roundtrips_through_json() {
        let mut c = ExperimentConfig::default();
        c.cluster.apply_topology("racks=4:rack_bw=0.5").unwrap();
        let j = json::parse(&c.to_json().to_string()).unwrap();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.cluster, c.cluster);
        assert!(!back.cluster.topology.is_flat());
        // default emits an empty spec and loads back flat
        let d = ExperimentConfig::default();
        let j = json::parse(&d.to_json().to_string()).unwrap();
        assert_eq!(j.path("topology.spec").unwrap().as_str(), Some(""));
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.cluster, d.cluster);
    }

    #[test]
    fn topology_survives_n_gpus_override_and_rejects_garbage() {
        let mut c = ExperimentConfig::default();
        let j = json::parse(
            r#"{"topology": {"spec": "racks=4:regions=2"}}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        let j = json::parse(r#"{"n_gpus": 32}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.cluster.total_gpus(), 32);
        assert_eq!(c.cluster.topology.racks, 4);
        assert_eq!(c.cluster.topology.regions, 2);
        // garbage specs are load errors
        let j = json::parse(r#"{"topology": {"spec": "racks=zero"}}"#)
            .unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn domain_fault_knobs_roundtrip_and_validate() {
        let mut c = ExperimentConfig::default();
        c.cluster.apply_topology("racks=4").unwrap();
        c.faults.domain_mtbf_s = 7200.0;
        c.faults.domain_mttr_s = 300.0;
        c.stragglers.domain_mtbs_s = 3600.0;
        c.stragglers.domain_mtts_s = 450.0;
        let j = json::parse(&c.to_json().to_string()).unwrap();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.faults, c.faults);
        assert_eq!(back.stragglers, c.stragglers);
        // rejections
        let mut c = ExperimentConfig::default();
        c.faults.domain_mtbf_s = -1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.faults.domain_mtbf_s = 100.0;
        c.faults.domain_mttr_s = 0.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.stragglers.domain_mtbs_s = 100.0;
        c.stragglers.domain_mtts_s = 0.0;
        assert!(c.validate().is_err());
        // defaults keep everything off
        let d = FaultConfig::default();
        assert_eq!(d.domain_mtbf_s, 0.0);
        assert_eq!(StragglerConfig::default().domain_mtbs_s, 0.0);
    }

    #[test]
    fn gpu_fault_knobs_roundtrip_and_validate() {
        let mut c = ExperimentConfig::default();
        c.faults.gpu_mtbf_s = 40_000.0;
        c.faults.gpu_mttr_s = 900.0;
        c.validate().unwrap();
        let j = json::parse(&c.to_json().to_string()).unwrap();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.faults, c.faults);
        // partial override: only gpu_mtbf_s set, rest keep defaults
        let j =
            json::parse(r#"{"faults": {"gpu_mtbf_s": 1234.0}}"#).unwrap();
        let mut c2 = ExperimentConfig::default();
        c2.apply_json(&j).unwrap();
        assert_eq!(c2.faults.gpu_mtbf_s, 1234.0);
        assert_eq!(c2.faults.gpu_mttr_s, FaultConfig::default().gpu_mttr_s);
        // rejections
        let mut c = ExperimentConfig::default();
        c.faults.gpu_mtbf_s = -1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.faults.gpu_mtbf_s = 100.0;
        c.faults.gpu_mttr_s = 0.0;
        assert!(c.validate().is_err());
        // defaults keep GPU faults off
        assert_eq!(FaultConfig::default().gpu_mtbf_s, 0.0);
    }

    #[test]
    fn shrink_and_wear_knobs_roundtrip_and_validate() {
        let mut c = ExperimentConfig::default();
        c.faults.shrink = true;
        c.faults.gpu_wear_alpha = 0.5;
        c.validate().unwrap();
        let j = json::parse(&c.to_json().to_string()).unwrap();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.faults, c.faults);
        // partial override: only shrink set, wear keeps default
        let j = json::parse(r#"{"faults": {"shrink": true}}"#).unwrap();
        let mut c2 = ExperimentConfig::default();
        c2.apply_json(&j).unwrap();
        assert!(c2.faults.shrink);
        assert_eq!(c2.faults.gpu_wear_alpha, 0.0);
        // rejections
        let mut c = ExperimentConfig::default();
        c.faults.gpu_wear_alpha = -0.1;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.faults.gpu_wear_alpha = f64::NAN;
        assert!(c.validate().is_err());
        // defaults keep both off
        let d = FaultConfig::default();
        assert!(!d.shrink);
        assert_eq!(d.gpu_wear_alpha, 0.0);
    }

    #[test]
    fn invalid_fault_configs_rejected() {
        let mut c = ExperimentConfig::default();
        c.faults.mtbf_s = -1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.faults.mtbf_s = 100.0;
        c.faults.mttr_s = 0.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.faults.ckpt_read_bw = 0.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.faults.slo_factor = 0.0;
        assert!(c.validate().is_err());
    }
}
