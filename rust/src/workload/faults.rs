//! Seeded failure-trace synthesis: the fault dimension of the
//! workload.
//!
//! Production multi-tenant clusters churn — nodes fail and are
//! repaired, spot capacity is reclaimed, priority tenants preempt. The
//! paper's scheduler is built to react to exactly this kind of event
//! stream (§3.4: regroup on arrivals/completions, reclaim resources
//! elastically), so the simulator models churn as first-class workload
//! input:
//!
//! * [`NodeFaultModel`] — a per-node alternating renewal process:
//!   up-times are exponential with mean `mtbf_s`, down-times
//!   exponential with mean `mttr_s`. Each node owns an independent
//!   seeded RNG stream, so a node's failure/repair sequence is a pure
//!   function of `(seed, node)` — it does not shift when the engine
//!   interleaves draws across nodes, which keeps faulted sweeps
//!   bit-deterministic across thread counts.
//! * [`PreemptionModel`] — cluster-level Poisson preemptions at
//!   `rate_per_s`, each targeting a uniformly drawn job id. Preempting
//!   a job that is not currently placed is a no-op in the engine.
//! * [`ScriptedFault`] — a deterministic injected fault for pinned
//!   scenarios ("kill node 0 at t=100"); tests and benches thread a
//!   script through `sim::EngineOptions::fault_script`.
//! * [`synthesize_node_faults`] — materialize the renewal process up to
//!   a horizon as a sorted script; its prefix is exactly what the
//!   engine's lazy draws produce, which the module tests pin.
//! * [`StragglerModel`] / [`ScriptedStraggler`] — the *degraded* (not
//!   dead) fault mode: a node keeps its GPUs but runs every co-located
//!   group at a fraction of its nominal rate. Same per-node seeded
//!   renewal construction as [`NodeFaultModel`] (healthy spans with
//!   mean `mtbs_s`, degraded spans with mean `mtts_s`), plus a sampled
//!   *severity* — the node's speed multiplier in
//!   `[severity_min, severity_max]` — drawn per episode.
//!   [`synthesize_stragglers`] materializes the stream like
//!   `synthesize_node_faults` does for failures.
//! * [`GpuFaultModel`] / [`ScriptedGpuFault`] — the *partial-node*
//!   fault mode: one GPU fails while its node keeps serving from the
//!   survivors. Per-GPU alternating renewal streams (up-times
//!   exponential with mean `gpu_mtbf_s`, repairs with mean
//!   `gpu_mttr_s`), each pure in `(seed, node, gpu)` on its own salt,
//!   so enabling GPU faults never shifts the node-level streams and a
//!   device's sequence survives any engine interleaving.
//!   [`synthesize_gpu_faults`] materializes the stream pinned to the
//!   engine's lazy draw order.

use crate::cluster::FailureDomain;
use crate::util::f64_cmp;
use crate::util::rng::Rng;

/// Kind of an injected fault (mirrors the engine's event kinds without
/// depending on `sim`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `target` is a node index.
    NodeFailure,
    /// `target` is a node index.
    NodeRecovery,
    /// `target` is a job id.
    Preemption,
}

/// One deterministic injected fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScriptedFault {
    pub time: f64,
    pub kind: FaultKind,
    pub target: u64,
}

/// Salt folded into fault seeds so fault streams never alias the trace
/// generator's streams for the same experiment seed.
const FAULT_SALT: u64 = 0xFA17_7E57;

/// Per-node MTBF/MTTR exponential renewal model with independent
/// per-node RNG streams.
#[derive(Debug)]
pub struct NodeFaultModel {
    mtbf_s: f64,
    mttr_s: f64,
    rngs: Vec<Rng>,
}

impl NodeFaultModel {
    /// `mtbf_s` must be > 0 (a zero MTBF means "faults disabled" and
    /// callers should not build the model at all); `mttr_s` must be
    /// > 0 so every failure schedules a recovery.
    pub fn new(
        mtbf_s: f64,
        mttr_s: f64,
        n_nodes: usize,
        seed: u64,
    ) -> NodeFaultModel {
        assert!(mtbf_s > 0.0 && mttr_s > 0.0, "mtbf/mttr must be > 0");
        let rngs = (0..n_nodes)
            .map(|n| {
                Rng::new(
                    seed ^ FAULT_SALT
                        ^ (n as u64 + 1)
                            .wrapping_mul(0x9E37_79B9_7F4A_7C15),
                )
            })
            .collect();
        NodeFaultModel {
            mtbf_s,
            mttr_s,
            rngs,
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.rngs.len()
    }

    /// Draw the next up-time span for `node` (seconds until its next
    /// failure, measured from now / from recovery).
    pub fn uptime(&mut self, node: usize) -> f64 {
        self.rngs[node].exponential(1.0 / self.mtbf_s)
    }

    /// Draw the repair span for `node` (seconds from failure to
    /// recovery).
    pub fn downtime(&mut self, node: usize) -> f64 {
        self.rngs[node].exponential(1.0 / self.mttr_s)
    }
}

/// Cluster-level Poisson preemption stream over an explicit job-id
/// catalog.
#[derive(Debug)]
pub struct PreemptionModel {
    rate_per_s: f64,
    job_ids: Vec<u64>,
    rng: Rng,
}

impl PreemptionModel {
    /// `rate_per_s` must be > 0 and `job_ids` non-empty.
    pub fn new(
        rate_per_s: f64,
        mut job_ids: Vec<u64>,
        seed: u64,
    ) -> PreemptionModel {
        assert!(rate_per_s > 0.0, "preemption rate must be > 0");
        assert!(!job_ids.is_empty(), "preemption needs target jobs");
        // canonical order: the stream must not depend on caller order
        job_ids.sort_unstable();
        PreemptionModel {
            rate_per_s,
            job_ids,
            rng: Rng::new(seed ^ FAULT_SALT ^ 0x5B07_F00D),
        }
    }

    /// Draw the next preemption: (seconds from now, target job id).
    pub fn next(&mut self) -> (f64, u64) {
        let dt = self.rng.exponential(self.rate_per_s);
        let target = *self.rng.choice(&self.job_ids);
        (dt, target)
    }
}

/// One deterministic injected straggler transition: at `time`, `node`
/// starts running at `speed` × its nominal rate. `speed` in (0, 1) is
/// a degrade; `speed >= 1` restores the node (scripts normally use
/// exactly 1.0). Threaded through
/// `sim::EngineOptions::straggler_script` for pinned scenarios.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScriptedStraggler {
    pub time: f64,
    pub node: u64,
    pub speed: f64,
}

/// Salt for straggler streams — distinct from [`FAULT_SALT`] so a
/// config running both fault models never correlates their draws.
const STRAGGLER_SALT: u64 = 0x5708_661E;

/// Per-node straggler renewal model: healthy spans exponential with
/// mean `mtbs_s`, degraded spans exponential with mean `mtts_s`, and a
/// per-episode severity (the node's speed multiplier) uniform in
/// `[severity_min, severity_max]`. Each node owns an independent RNG
/// stream pure in `(seed, node)`, like [`NodeFaultModel`] — the engine
/// interleaving draws across nodes never shifts a node's sequence.
///
/// Lazy draw order per node (pinned by [`synthesize_stragglers`] and
/// the module tests): healthy span → (severity, degraded span) →
/// healthy span → ...
#[derive(Debug)]
pub struct StragglerModel {
    mtbs_s: f64,
    mtts_s: f64,
    severity_min: f64,
    severity_max: f64,
    rngs: Vec<Rng>,
}

impl StragglerModel {
    /// `mtbs_s`/`mtts_s` must be > 0 (a zero MTBS means "stragglers
    /// disabled" and callers should not build the model); severities
    /// must satisfy `0 < severity_min <= severity_max < 1` — a
    /// degraded node is strictly slower, never stopped.
    pub fn new(
        mtbs_s: f64,
        mtts_s: f64,
        severity_min: f64,
        severity_max: f64,
        n_nodes: usize,
        seed: u64,
    ) -> StragglerModel {
        assert!(mtbs_s > 0.0 && mtts_s > 0.0, "mtbs/mtts must be > 0");
        assert!(
            severity_min > 0.0
                && severity_min <= severity_max
                && severity_max < 1.0,
            "severity bounds must satisfy 0 < min <= max < 1"
        );
        let rngs = (0..n_nodes)
            .map(|n| {
                Rng::new(
                    seed ^ STRAGGLER_SALT
                        ^ (n as u64 + 1)
                            .wrapping_mul(0x9E37_79B9_7F4A_7C15),
                )
            })
            .collect();
        StragglerModel {
            mtbs_s,
            mtts_s,
            severity_min,
            severity_max,
            rngs,
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.rngs.len()
    }

    /// Draw the next healthy span for `node` (seconds until it starts
    /// straggling, measured from now / from restore).
    pub fn healthy_span(&mut self, node: usize) -> f64 {
        self.rngs[node].exponential(1.0 / self.mtbs_s)
    }

    /// Draw one degrade episode for `node`: `(speed, duration_s)` —
    /// the sampled severity (speed multiplier in
    /// `[severity_min, severity_max]`) and how long it lasts.
    pub fn episode(&mut self, node: usize) -> (f64, f64) {
        let speed = self.rngs[node]
            .range_f64(self.severity_min, self.severity_max);
        let dur = self.rngs[node].exponential(1.0 / self.mtts_s);
        (speed, dur)
    }
}

/// Materialize the per-node straggler renewal process as a sorted
/// script covering `[0, horizon_s)` — degrade entries carry the
/// sampled severity, each followed by its `speed = 1.0` restore (the
/// restore may land beyond the horizon so no node straggles forever).
/// Its prefix is exactly what the engine's lazy draws produce.
pub fn synthesize_stragglers(
    mtbs_s: f64,
    mtts_s: f64,
    severity_min: f64,
    severity_max: f64,
    n_nodes: usize,
    seed: u64,
    horizon_s: f64,
) -> Vec<ScriptedStraggler> {
    let mut model = StragglerModel::new(
        mtbs_s,
        mtts_s,
        severity_min,
        severity_max,
        n_nodes,
        seed,
    );
    let mut out = vec![];
    for node in 0..n_nodes {
        let mut t = model.healthy_span(node);
        while t < horizon_s {
            let (speed, dur) = model.episode(node);
            out.push(ScriptedStraggler {
                time: t,
                node: node as u64,
                speed,
            });
            let restore = t + dur;
            out.push(ScriptedStraggler {
                time: restore,
                node: node as u64,
                speed: 1.0,
            });
            t = restore + model.healthy_span(node);
        }
    }
    out.sort_by(|a, b| {
        f64_cmp(a.time, b.time).then(a.node.cmp(&b.node))
    });
    out
}

/// Salt for *domain*-correlated fault streams — distinct from
/// [`FAULT_SALT`] so enabling rack-scoped episodes never shifts the
/// per-node streams drawn for the same experiment seed.
const DOMAIN_FAULT_SALT: u64 = 0xD0E5_FA17;

/// Salt for domain-correlated straggler streams (see
/// [`DOMAIN_FAULT_SALT`]).
const DOMAIN_STRAGGLER_SALT: u64 = 0xD0E5_5708;

fn domain_rng(seed: u64, salt: u64, domain: usize) -> Rng {
    Rng::new(
        seed ^ salt
            ^ (domain as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    )
}

/// Materialize *correlated* failure episodes over named failure
/// domains (racks/switches) as a sorted fault script covering
/// `[0, horizon_s)`.
///
/// Each domain owns an independent seeded renewal stream — up-times
/// exponential with mean `mtbf_s`, down-times exponential with mean
/// `mttr_s` — and one episode draw fails **every node under the
/// domain** at the same instant, with one shared recovery time. A
/// domain's sequence is a pure function of `(seed, domain_index)`, so
/// the script is bit-deterministic regardless of fleet shape changes
/// elsewhere. Reuses the existing `NodeFailure`/`NodeRecovery` event
/// machinery: the engine needs no new event kinds.
pub fn synthesize_domain_faults(
    mtbf_s: f64,
    mttr_s: f64,
    domains: &[FailureDomain],
    seed: u64,
    horizon_s: f64,
) -> Vec<ScriptedFault> {
    assert!(mtbf_s > 0.0 && mttr_s > 0.0, "mtbf/mttr must be > 0");
    let mut out = vec![];
    for (d, dom) in domains.iter().enumerate() {
        let mut rng = domain_rng(seed, DOMAIN_FAULT_SALT, d);
        let mut t = rng.exponential(1.0 / mtbf_s);
        while t < horizon_s {
            let rec = t + rng.exponential(1.0 / mttr_s);
            for &node in &dom.nodes {
                out.push(ScriptedFault {
                    time: t,
                    kind: FaultKind::NodeFailure,
                    target: node as u64,
                });
                out.push(ScriptedFault {
                    time: rec,
                    kind: FaultKind::NodeRecovery,
                    target: node as u64,
                });
            }
            t = rec + rng.exponential(1.0 / mtbf_s);
        }
    }
    out.sort_by(|a, b| {
        f64_cmp(a.time, b.time).then(a.target.cmp(&b.target))
    });
    out
}

/// Materialize *correlated* straggler episodes over failure domains as
/// a sorted script covering `[0, horizon_s)` — the shared-switch /
/// power-domain degradation mode: one draw degrades every node under
/// the domain to the **same** sampled severity, with one shared
/// restore time. Same per-domain seeded construction as
/// [`synthesize_domain_faults`].
pub fn synthesize_domain_stragglers(
    mtbs_s: f64,
    mtts_s: f64,
    severity_min: f64,
    severity_max: f64,
    domains: &[FailureDomain],
    seed: u64,
    horizon_s: f64,
) -> Vec<ScriptedStraggler> {
    assert!(mtbs_s > 0.0 && mtts_s > 0.0, "mtbs/mtts must be > 0");
    assert!(
        severity_min > 0.0
            && severity_min <= severity_max
            && severity_max < 1.0,
        "severity bounds must satisfy 0 < min <= max < 1"
    );
    let mut out = vec![];
    for (d, dom) in domains.iter().enumerate() {
        let mut rng = domain_rng(seed, DOMAIN_STRAGGLER_SALT, d);
        let mut t = rng.exponential(1.0 / mtbs_s);
        while t < horizon_s {
            let speed = rng.range_f64(severity_min, severity_max);
            let restore = t + rng.exponential(1.0 / mtts_s);
            for &node in &dom.nodes {
                out.push(ScriptedStraggler {
                    time: t,
                    node: node as u64,
                    speed,
                });
                out.push(ScriptedStraggler {
                    time: restore,
                    node: node as u64,
                    speed: 1.0,
                });
            }
            t = restore + rng.exponential(1.0 / mtbs_s);
        }
    }
    out.sort_by(|a, b| {
        f64_cmp(a.time, b.time).then(a.node.cmp(&b.node))
    });
    out
}

/// Salt for per-GPU fault streams — distinct from [`FAULT_SALT`],
/// [`STRAGGLER_SALT`], and the domain salts, so enabling single-GPU
/// faults never shifts any node-level stream drawn for the same
/// experiment seed.
const GPU_FAULT_SALT: u64 = 0x67B0_FA17;

/// Kind of an injected single-GPU fault (mirrors the engine's
/// `GpuFailure`/`GpuRecovery` event kinds without depending on `sim`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuFaultKind {
    Failure,
    Recovery,
}

/// One deterministic injected single-GPU fault: at `time`, GPU `gpu`
/// of node `node` fails or comes back. Threaded through
/// `sim::EngineOptions::gpu_fault_script` for pinned scenarios.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScriptedGpuFault {
    pub time: f64,
    pub kind: GpuFaultKind,
    pub node: u64,
    pub gpu: u64,
}

/// Per-GPU MTBF/MTTR exponential renewal model: one independent RNG
/// stream per device, seeded pure in `(seed, node, gpu)` via the flat
/// device index `node * gpus_per_node + gpu` on [`GPU_FAULT_SALT`].
/// Same construction as [`NodeFaultModel`], one level down the
/// hardware tree.
#[derive(Debug)]
pub struct GpuFaultModel {
    mtbf_s: f64,
    mttr_s: f64,
    gpus_per_node: usize,
    rngs: Vec<Rng>,
    /// Wear coupling α: the effective MTBF for a device's next uptime
    /// draw is `mtbf_s / (1 + α * wear)` where `wear` is its
    /// accumulated service time in MTBF units plus its past failure
    /// count. 0 (the default) reproduces the memoryless renewal
    /// stream bit-exactly (`x / (1.0 + 0.0 * w) == x` in IEEE bits
    /// for finite `w`).
    wear_alpha: f64,
    /// Accumulated up-time (service) per flat device index, seconds.
    service_s: Vec<f64>,
    /// Past failure count per flat device index (a downtime draw is a
    /// failure that happened).
    failures: Vec<u64>,
}

impl GpuFaultModel {
    /// `mtbf_s` must be > 0 (zero means "GPU faults disabled" and
    /// callers should not build the model); `mttr_s` must be > 0 so
    /// every failure schedules a recovery.
    pub fn new(
        mtbf_s: f64,
        mttr_s: f64,
        n_nodes: usize,
        gpus_per_node: usize,
        seed: u64,
    ) -> GpuFaultModel {
        GpuFaultModel::with_wear(
            mtbf_s,
            mttr_s,
            n_nodes,
            gpus_per_node,
            seed,
            0.0,
        )
    }

    /// Wear-coupled construction (`faults.gpu_wear_alpha`). The wear
    /// state lives inside each device's own renewal stream, so draws
    /// stay pure in `(seed, node, gpu)` exactly like the base model —
    /// one device aging never shifts another device's stream.
    pub fn with_wear(
        mtbf_s: f64,
        mttr_s: f64,
        n_nodes: usize,
        gpus_per_node: usize,
        seed: u64,
        wear_alpha: f64,
    ) -> GpuFaultModel {
        assert!(mtbf_s > 0.0 && mttr_s > 0.0, "mtbf/mttr must be > 0");
        assert!(
            wear_alpha >= 0.0 && wear_alpha.is_finite(),
            "wear_alpha must be finite and >= 0"
        );
        let n = n_nodes * gpus_per_node;
        let rngs = (0..n)
            .map(|flat| {
                Rng::new(
                    seed ^ GPU_FAULT_SALT
                        ^ (flat as u64 + 1)
                            .wrapping_mul(0x9E37_79B9_7F4A_7C15),
                )
            })
            .collect();
        GpuFaultModel {
            mtbf_s,
            mttr_s,
            gpus_per_node,
            rngs,
            wear_alpha,
            service_s: vec![0.0; n],
            failures: vec![0; n],
        }
    }

    pub fn n_gpus(&self) -> usize {
        self.rngs.len()
    }

    fn flat(&self, node: usize, gpu: usize) -> usize {
        debug_assert!(gpu < self.gpus_per_node);
        node * self.gpus_per_node + gpu
    }

    /// Draw the next up-time span for device `(node, gpu)` (seconds
    /// until its next failure, measured from now / from recovery).
    /// With wear coupling on, the draw uses the device's *effective*
    /// MTBF — degraded by its accumulated service time and past
    /// failures — and the span itself then ages the device further.
    pub fn uptime(&mut self, node: usize, gpu: usize) -> f64 {
        let flat = self.flat(node, gpu);
        let wear = self.service_s[flat] / self.mtbf_s
            + self.failures[flat] as f64;
        let mtbf_eff = self.mtbf_s / (1.0 + self.wear_alpha * wear);
        let span = self.rngs[flat].exponential(1.0 / mtbf_eff);
        self.service_s[flat] += span;
        span
    }

    /// Draw the repair span for device `(node, gpu)`. Each repair
    /// records one more past failure in the device's wear state
    /// (repairs themselves stay memoryless — only the MTBF degrades).
    pub fn downtime(&mut self, node: usize, gpu: usize) -> f64 {
        let flat = self.flat(node, gpu);
        self.failures[flat] += 1;
        self.rngs[flat].exponential(1.0 / self.mttr_s)
    }
}

/// Materialize the per-GPU renewal process as a sorted fault script
/// covering `[0, horizon_s)` — the single-device analogue of
/// [`synthesize_node_faults`]. Its prefix is exactly what the engine's
/// lazy draws produce (uptime → downtime → uptime per device, devices
/// in flat-index order), which the module tests pin.
pub fn synthesize_gpu_faults(
    gpu_mtbf_s: f64,
    gpu_mttr_s: f64,
    n_nodes: usize,
    gpus_per_node: usize,
    seed: u64,
    horizon_s: f64,
) -> Vec<ScriptedGpuFault> {
    synthesize_gpu_faults_wear(
        gpu_mtbf_s,
        gpu_mttr_s,
        n_nodes,
        gpus_per_node,
        seed,
        horizon_s,
        0.0,
    )
}

/// [`synthesize_gpu_faults`] with wear coupling
/// (`faults.gpu_wear_alpha`): because the wear state lives inside the
/// per-device draw sequence itself, the materialized script matches
/// the engine's lazy wear-coupled draws by construction. `wear_alpha
/// == 0.0` reproduces the memoryless script bit-exactly.
pub fn synthesize_gpu_faults_wear(
    gpu_mtbf_s: f64,
    gpu_mttr_s: f64,
    n_nodes: usize,
    gpus_per_node: usize,
    seed: u64,
    horizon_s: f64,
    wear_alpha: f64,
) -> Vec<ScriptedGpuFault> {
    let mut model = GpuFaultModel::with_wear(
        gpu_mtbf_s,
        gpu_mttr_s,
        n_nodes,
        gpus_per_node,
        seed,
        wear_alpha,
    );
    let mut out = vec![];
    for node in 0..n_nodes {
        for gpu in 0..gpus_per_node {
            let mut t = model.uptime(node, gpu);
            while t < horizon_s {
                out.push(ScriptedGpuFault {
                    time: t,
                    kind: GpuFaultKind::Failure,
                    node: node as u64,
                    gpu: gpu as u64,
                });
                let rec = t + model.downtime(node, gpu);
                out.push(ScriptedGpuFault {
                    time: rec,
                    kind: GpuFaultKind::Recovery,
                    node: node as u64,
                    gpu: gpu as u64,
                });
                t = rec + model.uptime(node, gpu);
            }
        }
    }
    out.sort_by(|a, b| {
        f64_cmp(a.time, b.time)
            .then(a.node.cmp(&b.node))
            .then(a.gpu.cmp(&b.gpu))
    });
    out
}

/// Materialize the per-node renewal process as a sorted fault script
/// covering `[0, horizon_s)`. Failure times are measured from t=0;
/// each failure is followed by its recovery (the recovery may land
/// beyond the horizon — it is included so the script never leaves a
/// node down forever).
pub fn synthesize_node_faults(
    mtbf_s: f64,
    mttr_s: f64,
    n_nodes: usize,
    seed: u64,
    horizon_s: f64,
) -> Vec<ScriptedFault> {
    let mut model = NodeFaultModel::new(mtbf_s, mttr_s, n_nodes, seed);
    let mut out = vec![];
    for node in 0..n_nodes {
        let mut t = model.uptime(node);
        while t < horizon_s {
            out.push(ScriptedFault {
                time: t,
                kind: FaultKind::NodeFailure,
                target: node as u64,
            });
            let rec = t + model.downtime(node);
            out.push(ScriptedFault {
                time: rec,
                kind: FaultKind::NodeRecovery,
                target: node as u64,
            });
            t = rec + model.uptime(node);
        }
    }
    out.sort_by(|a, b| {
        f64_cmp(a.time, b.time).then(a.target.cmp(&b.target))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_streams_deterministic_and_independent() {
        let mut a = NodeFaultModel::new(1000.0, 100.0, 4, 7);
        let mut b = NodeFaultModel::new(1000.0, 100.0, 4, 7);
        for node in 0..4 {
            for _ in 0..20 {
                assert_eq!(a.uptime(node), b.uptime(node));
                assert_eq!(a.downtime(node), b.downtime(node));
            }
        }
        // a node's stream is untouched by draws on other nodes
        let mut c = NodeFaultModel::new(1000.0, 100.0, 4, 7);
        let mut d = NodeFaultModel::new(1000.0, 100.0, 4, 7);
        for _ in 0..50 {
            let _ = d.uptime(0);
            let _ = d.downtime(0);
        }
        assert_eq!(c.uptime(3), d.uptime(3));
    }

    #[test]
    fn uptime_mean_tracks_mtbf() {
        let mut m = NodeFaultModel::new(500.0, 50.0, 1, 3);
        let n = 20_000;
        let mean_up: f64 =
            (0..n).map(|_| m.uptime(0)).sum::<f64>() / n as f64;
        let mean_down: f64 =
            (0..n).map(|_| m.downtime(0)).sum::<f64>() / n as f64;
        assert!((mean_up - 500.0).abs() < 25.0, "{mean_up}");
        assert!((mean_down - 50.0).abs() < 2.5, "{mean_down}");
    }

    #[test]
    fn synthesized_script_alternates_per_node_and_sorts() {
        let script =
            synthesize_node_faults(300.0, 60.0, 3, 11, 10_000.0);
        assert!(!script.is_empty());
        // globally time-sorted
        for w in script.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
        // per node: failure/recovery strictly alternate, times increase
        for node in 0..3u64 {
            let evs: Vec<&ScriptedFault> = script
                .iter()
                .filter(|f| f.target == node)
                .collect();
            let mut last = 0.0;
            for (i, f) in evs.iter().enumerate() {
                let want = if i % 2 == 0 {
                    FaultKind::NodeFailure
                } else {
                    FaultKind::NodeRecovery
                };
                assert_eq!(f.kind, want, "node {node} event {i}");
                assert!(f.time >= last);
                last = f.time;
            }
            // every failure has its recovery in the script
            assert_eq!(evs.len() % 2, 0, "node {node} left down");
        }
    }

    #[test]
    fn synthesis_matches_lazy_model_draws() {
        // the engine draws lazily (uptime -> downtime -> uptime ...);
        // the synthesized script must be exactly that sequence
        let script =
            synthesize_node_faults(400.0, 40.0, 2, 5, 5_000.0);
        let mut model = NodeFaultModel::new(400.0, 40.0, 2, 5);
        for node in 0..2u64 {
            let evs: Vec<&ScriptedFault> = script
                .iter()
                .filter(|f| f.target == node)
                .collect();
            let mut t = model.uptime(node as usize);
            let mut i = 0;
            while t < 5_000.0 {
                assert_eq!(evs[i].time, t, "failure {i} node {node}");
                let rec = t + model.downtime(node as usize);
                assert_eq!(
                    evs[i + 1].time,
                    rec,
                    "recovery {i} node {node}"
                );
                t = rec + model.uptime(node as usize);
                i += 2;
            }
            assert_eq!(i, evs.len());
        }
    }

    #[test]
    fn straggler_streams_deterministic_and_independent() {
        let mut a = StragglerModel::new(1000.0, 200.0, 0.2, 0.5, 4, 7);
        let mut b = StragglerModel::new(1000.0, 200.0, 0.2, 0.5, 4, 7);
        for node in 0..4 {
            for _ in 0..20 {
                assert_eq!(
                    a.healthy_span(node),
                    b.healthy_span(node)
                );
                assert_eq!(a.episode(node), b.episode(node));
            }
        }
        // a node's stream is untouched by draws on other nodes
        let mut c = StragglerModel::new(1000.0, 200.0, 0.2, 0.5, 4, 7);
        let mut d = StragglerModel::new(1000.0, 200.0, 0.2, 0.5, 4, 7);
        for _ in 0..50 {
            let _ = d.healthy_span(0);
            let _ = d.episode(0);
        }
        assert_eq!(c.healthy_span(3), d.healthy_span(3));
        // and straggler streams never alias the failure streams for
        // the same experiment seed
        let mut f = NodeFaultModel::new(1000.0, 200.0, 4, 7);
        let mut s = StragglerModel::new(1000.0, 200.0, 0.2, 0.5, 4, 7);
        assert_ne!(f.uptime(0), s.healthy_span(0));
    }

    #[test]
    fn straggler_severity_within_bounds() {
        let mut m = StragglerModel::new(500.0, 100.0, 0.25, 0.6, 1, 3);
        for _ in 0..2_000 {
            let (speed, dur) = m.episode(0);
            assert!((0.25..=0.6).contains(&speed), "{speed}");
            assert!(dur >= 0.0);
        }
    }

    #[test]
    fn synthesized_stragglers_alternate_and_match_lazy_draws() {
        let script = synthesize_stragglers(
            300.0, 60.0, 0.2, 0.5, 3, 11, 10_000.0,
        );
        assert!(!script.is_empty());
        for w in script.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
        let mut model =
            StragglerModel::new(300.0, 60.0, 0.2, 0.5, 3, 11);
        for node in 0..3u64 {
            let evs: Vec<&ScriptedStraggler> = script
                .iter()
                .filter(|s| s.node == node)
                .collect();
            // degrade (speed < 1) / restore (speed == 1) alternate and
            // every degrade has its restore in the script
            for (i, s) in evs.iter().enumerate() {
                if i % 2 == 0 {
                    assert!(s.speed < 1.0, "node {node} event {i}");
                } else {
                    assert_eq!(s.speed, 1.0, "node {node} event {i}");
                }
            }
            assert_eq!(evs.len() % 2, 0, "node {node} left degraded");
            // the script is exactly the lazy draw sequence
            let mut t = model.healthy_span(node as usize);
            let mut i = 0;
            while t < 10_000.0 {
                let (speed, dur) = model.episode(node as usize);
                assert_eq!(evs[i].time, t, "degrade {i} node {node}");
                assert_eq!(evs[i].speed, speed);
                assert_eq!(
                    evs[i + 1].time,
                    t + dur,
                    "restore {i} node {node}"
                );
                t = t + dur + model.healthy_span(node as usize);
                i += 2;
            }
            assert_eq!(i, evs.len());
        }
    }

    fn two_rack_domains() -> Vec<FailureDomain> {
        vec![
            FailureDomain {
                name: "rack0".into(),
                nodes: vec![0, 1],
            },
            FailureDomain {
                name: "rack1".into(),
                nodes: vec![2, 3],
            },
        ]
    }

    #[test]
    fn domain_episode_touches_exactly_the_domain_nodes() {
        let domains = two_rack_domains();
        let script = synthesize_domain_faults(
            2_000.0, 300.0, &domains, 13, 50_000.0,
        );
        assert!(!script.is_empty());
        for w in script.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
        // group entries into episodes by (time, kind): every episode
        // must cover exactly one domain's full node set — no more, no
        // fewer, never a node from another rack
        let mut episodes: std::collections::BTreeMap<
            (u64, bool),
            Vec<u64>,
        > = std::collections::BTreeMap::new();
        for f in &script {
            episodes
                .entry((
                    f.time.to_bits(),
                    f.kind == FaultKind::NodeFailure,
                ))
                .or_default()
                .push(f.target);
        }
        for ((bits, _), targets) in &episodes {
            let hit = domains.iter().any(|d| {
                let want: Vec<u64> =
                    d.nodes.iter().map(|&n| n as u64).collect();
                *targets == want
            });
            assert!(
                hit,
                "episode at t={} touched {targets:?}, not a domain",
                f64::from_bits(*bits)
            );
        }
        // per node: failure/recovery strictly alternate and pair up
        for node in 0..4u64 {
            let evs: Vec<&ScriptedFault> = script
                .iter()
                .filter(|f| f.target == node)
                .collect();
            assert!(!evs.is_empty(), "node {node} never failed");
            for (i, f) in evs.iter().enumerate() {
                let want = if i % 2 == 0 {
                    FaultKind::NodeFailure
                } else {
                    FaultKind::NodeRecovery
                };
                assert_eq!(f.kind, want, "node {node} event {i}");
            }
            assert_eq!(evs.len() % 2, 0, "node {node} left down");
        }
        // both nodes of a domain share identical episode times
        let t0: Vec<u64> = script
            .iter()
            .filter(|f| f.target == 0)
            .map(|f| f.time.to_bits())
            .collect();
        let t1: Vec<u64> = script
            .iter()
            .filter(|f| f.target == 1)
            .map(|f| f.time.to_bits())
            .collect();
        assert_eq!(t0, t1, "rack0 nodes diverged");
    }

    #[test]
    fn domain_stragglers_share_one_severity_per_episode() {
        let domains = two_rack_domains();
        let script = synthesize_domain_stragglers(
            2_000.0, 300.0, 0.2, 0.5, &domains, 13, 50_000.0,
        );
        assert!(!script.is_empty());
        let mut degrades: std::collections::BTreeMap<
            u64,
            Vec<(u64, u64)>,
        > = std::collections::BTreeMap::new();
        for s in script.iter().filter(|s| s.speed < 1.0) {
            assert!((0.2..=0.5).contains(&s.speed), "{}", s.speed);
            degrades
                .entry(s.time.to_bits())
                .or_default()
                .push((s.node, s.speed.to_bits()));
        }
        for (bits, members) in &degrades {
            let nodes: Vec<u64> =
                members.iter().map(|&(n, _)| n).collect();
            assert!(
                domains.iter().any(|d| {
                    let want: Vec<u64> =
                        d.nodes.iter().map(|&n| n as u64).collect();
                    nodes == want
                }),
                "degrade at t={} hit {nodes:?}",
                f64::from_bits(*bits)
            );
            // correlated: one severity draw for the whole domain
            assert!(
                members.iter().all(|&(_, s)| s == members[0].1),
                "severities diverged within an episode"
            );
        }
        // every degrade is eventually restored
        for node in 0..4u64 {
            let evs: Vec<&ScriptedStraggler> = script
                .iter()
                .filter(|s| s.node == node)
                .collect();
            assert_eq!(evs.len() % 2, 0, "node {node} left degraded");
        }
    }

    #[test]
    fn domain_streams_deterministic_and_salted_apart() {
        let domains = two_rack_domains();
        let a = synthesize_domain_faults(
            1_000.0, 100.0, &domains, 7, 20_000.0,
        );
        let b = synthesize_domain_faults(
            1_000.0, 100.0, &domains, 7, 20_000.0,
        );
        assert_eq!(a, b);
        // a domain's stream never aliases the per-node stream for the
        // same experiment seed
        let one = vec![FailureDomain {
            name: "rack0".into(),
            nodes: vec![0],
        }];
        let dom =
            synthesize_domain_faults(1_000.0, 100.0, &one, 7, 20_000.0);
        let node = synthesize_node_faults(1_000.0, 100.0, 1, 7, 20_000.0);
        assert_ne!(dom[0].time, node[0].time);
        // and fault vs straggler domain streams are salted apart too
        let s = synthesize_domain_stragglers(
            1_000.0, 100.0, 0.2, 0.5, &one, 7, 20_000.0,
        );
        assert_ne!(dom[0].time, s[0].time);
    }

    #[test]
    fn gpu_streams_deterministic_independent_and_salted_apart() {
        let mut a = GpuFaultModel::new(1000.0, 100.0, 2, 4, 7);
        let mut b = GpuFaultModel::new(1000.0, 100.0, 2, 4, 7);
        assert_eq!(a.n_gpus(), 8);
        for node in 0..2 {
            for gpu in 0..4 {
                for _ in 0..20 {
                    assert_eq!(
                        a.uptime(node, gpu),
                        b.uptime(node, gpu)
                    );
                    assert_eq!(
                        a.downtime(node, gpu),
                        b.downtime(node, gpu)
                    );
                }
            }
        }
        // a device's stream is untouched by draws on other devices
        let mut c = GpuFaultModel::new(1000.0, 100.0, 2, 4, 7);
        let mut d = GpuFaultModel::new(1000.0, 100.0, 2, 4, 7);
        for _ in 0..50 {
            let _ = d.uptime(0, 0);
            let _ = d.downtime(0, 1);
        }
        assert_eq!(c.uptime(1, 3), d.uptime(1, 3));
        // GPU streams never alias the node-fault or straggler streams
        // for the same experiment seed: device (0,0) has flat index 0,
        // the same position node 0 holds in the node-level models
        let mut f = NodeFaultModel::new(1000.0, 100.0, 2, 7);
        let mut g = GpuFaultModel::new(1000.0, 100.0, 2, 4, 7);
        assert_ne!(f.uptime(0), g.uptime(0, 0));
        let mut s = StragglerModel::new(1000.0, 100.0, 0.2, 0.5, 2, 7);
        assert_ne!(s.healthy_span(0), g.downtime(0, 0));
    }

    #[test]
    fn synthesized_gpu_faults_alternate_and_match_lazy_draws() {
        let script = synthesize_gpu_faults(
            400.0, 40.0, 2, 2, 5, 5_000.0,
        );
        assert!(!script.is_empty());
        for w in script.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
        let mut model = GpuFaultModel::new(400.0, 40.0, 2, 2, 5);
        for node in 0..2u64 {
            for gpu in 0..2u64 {
                let evs: Vec<&ScriptedGpuFault> = script
                    .iter()
                    .filter(|f| f.node == node && f.gpu == gpu)
                    .collect();
                // failure/recovery strictly alternate and pair up
                for (i, f) in evs.iter().enumerate() {
                    let want = if i % 2 == 0 {
                        GpuFaultKind::Failure
                    } else {
                        GpuFaultKind::Recovery
                    };
                    assert_eq!(
                        f.kind, want,
                        "({node},{gpu}) event {i}"
                    );
                }
                assert_eq!(
                    evs.len() % 2,
                    0,
                    "({node},{gpu}) left down"
                );
                // the script is exactly the lazy draw sequence
                let mut t =
                    model.uptime(node as usize, gpu as usize);
                let mut i = 0;
                while t < 5_000.0 {
                    assert_eq!(
                        evs[i].time, t,
                        "failure {i} ({node},{gpu})"
                    );
                    let rec = t
                        + model
                            .downtime(node as usize, gpu as usize);
                    assert_eq!(
                        evs[i + 1].time,
                        rec,
                        "recovery {i} ({node},{gpu})"
                    );
                    t = rec
                        + model.uptime(node as usize, gpu as usize);
                    i += 2;
                }
                assert_eq!(i, evs.len());
            }
        }
    }

    #[test]
    fn zero_wear_alpha_is_an_exact_noop() {
        // α = 0 must reproduce the memoryless stream bit-for-bit:
        // mtbf / (1.0 + 0.0 * wear) == mtbf in IEEE bits
        let mut a = GpuFaultModel::new(1000.0, 100.0, 2, 4, 7);
        let mut b =
            GpuFaultModel::with_wear(1000.0, 100.0, 2, 4, 7, 0.0);
        for node in 0..2 {
            for gpu in 0..4 {
                for _ in 0..30 {
                    assert_eq!(
                        a.uptime(node, gpu).to_bits(),
                        b.uptime(node, gpu).to_bits()
                    );
                    assert_eq!(
                        a.downtime(node, gpu).to_bits(),
                        b.downtime(node, gpu).to_bits()
                    );
                }
            }
        }
        // and the synthesized scripts match bit-for-bit too
        let s0 = synthesize_gpu_faults(400.0, 40.0, 2, 2, 5, 5_000.0);
        let s1 = synthesize_gpu_faults_wear(
            400.0, 40.0, 2, 2, 5, 5_000.0, 0.0,
        );
        assert_eq!(s0.len(), s1.len());
        for (a, b) in s0.iter().zip(s1.iter()) {
            assert_eq!(a.time.to_bits(), b.time.to_bits());
            assert_eq!(a.kind, b.kind);
            assert_eq!((a.node, a.gpu), (b.node, b.gpu));
        }
    }

    #[test]
    fn wear_shortens_later_uptimes_and_stays_pure_per_device() {
        let mut base = GpuFaultModel::new(1000.0, 100.0, 2, 2, 11);
        let mut worn =
            GpuFaultModel::with_wear(1000.0, 100.0, 2, 2, 11, 0.5);
        // the first draw sees zero wear: identical to the base stream
        let u0 = base.uptime(0, 0);
        let w0 = worn.uptime(0, 0);
        assert_eq!(u0.to_bits(), w0.to_bits());
        // both streams consume draws in lockstep, so every later
        // uptime comes from the same underlying uniform — the worn
        // device's span is the base span scaled by mtbf_eff/mtbf < 1
        let _ = base.downtime(0, 0);
        let _ = worn.downtime(0, 0);
        let u1 = base.uptime(0, 0);
        let w1 = worn.uptime(0, 0);
        assert!(
            w1 < u1,
            "worn uptime {w1} not shorter than fresh {u1}"
        );
        // purity: heavy wear on (0,0) never shifts (1,1)'s stream
        let mut fresh =
            GpuFaultModel::with_wear(1000.0, 100.0, 2, 2, 11, 0.5);
        assert_eq!(
            worn.uptime(1, 1).to_bits(),
            fresh.uptime(1, 1).to_bits()
        );
    }

    #[test]
    fn wear_coupled_script_matches_lazy_draws() {
        let script = synthesize_gpu_faults_wear(
            400.0, 40.0, 2, 2, 5, 5_000.0, 0.3,
        );
        assert!(!script.is_empty());
        for w in script.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
        let mut model =
            GpuFaultModel::with_wear(400.0, 40.0, 2, 2, 5, 0.3);
        for node in 0..2u64 {
            for gpu in 0..2u64 {
                let evs: Vec<&ScriptedGpuFault> = script
                    .iter()
                    .filter(|f| f.node == node && f.gpu == gpu)
                    .collect();
                let mut t =
                    model.uptime(node as usize, gpu as usize);
                let mut i = 0;
                while t < 5_000.0 {
                    assert_eq!(evs[i].time, t);
                    let rec = t
                        + model
                            .downtime(node as usize, gpu as usize);
                    assert_eq!(evs[i + 1].time, rec);
                    t = rec
                        + model.uptime(node as usize, gpu as usize);
                    i += 2;
                }
                assert_eq!(i, evs.len());
            }
        }
        // wear strictly accelerates the failure process: at least as
        // many events in-horizon as the memoryless stream produces
        let memless =
            synthesize_gpu_faults(400.0, 40.0, 2, 2, 5, 5_000.0);
        assert!(script.len() >= memless.len());
    }

    #[test]
    fn preemption_stream_deterministic_and_order_free() {
        let mut a = PreemptionModel::new(0.01, vec![3, 1, 2], 9);
        let mut b = PreemptionModel::new(0.01, vec![1, 2, 3], 9);
        for _ in 0..50 {
            assert_eq!(a.next(), b.next());
        }
        let mut c = PreemptionModel::new(0.01, vec![1, 2, 3], 9);
        let (dt, target) = c.next();
        assert!(dt > 0.0);
        assert!([1, 2, 3].contains(&target));
    }
}
