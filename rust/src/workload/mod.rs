//! LoRA fine-tuning job specifications and trace generation.
//!
//! The paper replays ACMETrace (`trace_seren.csv`) with LoRA attributes
//! sampled per §4.1: rank ∈ {2,4,8,16}, batch ∈ {1,2,4,8}, base model ∈
//! {llama3-8b, qwen3-8b}, GPU counts from the trace. ACMETrace itself is
//! not redistributable, so [`TraceGenerator`] synthesizes traces with the
//! published shape (Poisson/bursty arrivals with month-over-month
//! concurrency scaling, lognormal service durations, power-of-two GPU
//! gangs) and [`trace`] loads real CSVs with the same schema if provided.
//! [`faults`] adds the churn dimension: seeded per-node MTBF/MTTR
//! failure streams, Poisson preemptions, per-node straggler
//! (degraded-node) renewal streams with sampled severities, and
//! deterministic injected fault/straggler scripts.

pub mod faults;
pub mod trace;

pub use faults::{
    synthesize_domain_faults, synthesize_domain_stragglers,
    synthesize_gpu_faults, synthesize_node_faults,
    synthesize_stragglers, FaultKind, GpuFaultKind, GpuFaultModel,
    NodeFaultModel, PreemptionModel, ScriptedFault, ScriptedGpuFault,
    ScriptedStraggler, StragglerModel,
};
pub use trace::{load_csv, save_csv, stream_csv, stream_csv_file,
                DiurnalProfile, TenantClass, TraceGenerator,
                TraceProfile};

/// One LoRA fine-tuning job (fixed at submission, §A.1).
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub id: u64,
    pub base_model: String,
    pub rank: usize,
    pub batch_size: usize,
    pub seq_len: usize,
    /// GPUs provisioned for the job when run in isolation
    pub gpus: usize,
    /// training step budget to completion
    pub total_steps: u64,
    /// submission time (seconds since trace start)
    pub submit_time: f64,
    /// Δ_j^max — max tolerated slowdown vs isolated execution (§3.4)
    pub max_slowdown: f64,
}

impl JobSpec {
    /// Tokens processed per step.
    pub fn tokens_per_step(&self) -> f64 {
        (self.batch_size * self.seq_len) as f64
    }

    /// Relative compute weight used for size classification (Fig. 6b
    /// classifies by "compute cost based on their profiles (rank, batch
    /// size)").
    pub fn compute_weight(&self) -> f64 {
        self.tokens_per_step() * (1.0 + self.rank as f64 / 16.0)
    }
}

/// Size class terciles of Fig. 6b.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SizeClass {
    Small,
    Medium,
    Large,
}

/// Classify jobs into compute-cost terciles.
pub fn classify(jobs: &[JobSpec]) -> Vec<(u64, SizeClass)> {
    let mut weights: Vec<(u64, f64)> =
        jobs.iter().map(|j| (j.id, j.compute_weight())).collect();
    weights.sort_by(|a, b| crate::util::f64_cmp(a.1, b.1));
    let n = weights.len();
    weights
        .iter()
        .enumerate()
        .map(|(i, &(id, _))| {
            let c = if i * 3 < n {
                SizeClass::Small
            } else if i * 3 < 2 * n {
                SizeClass::Medium
            } else {
                SizeClass::Large
            };
            (id, c)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, rank: usize, batch: usize) -> JobSpec {
        JobSpec {
            id,
            base_model: "llama3-8b".into(),
            rank,
            batch_size: batch,
            seq_len: 512,
            gpus: 1,
            total_steps: 100,
            submit_time: 0.0,
            max_slowdown: 1.5,
        }
    }

    #[test]
    fn tokens_per_step() {
        assert_eq!(job(0, 8, 4).tokens_per_step(), 2048.0);
    }

    #[test]
    fn classify_terciles() {
        let jobs: Vec<JobSpec> =
            (0..9).map(|i| job(i, 2, (i + 1) as usize)).collect();
        let classes = classify(&jobs);
        let small = classes
            .iter()
            .filter(|(_, c)| *c == SizeClass::Small)
            .count();
        let med = classes
            .iter()
            .filter(|(_, c)| *c == SizeClass::Medium)
            .count();
        let large = classes
            .iter()
            .filter(|(_, c)| *c == SizeClass::Large)
            .count();
        assert_eq!((small, med, large), (3, 3, 3));
        // batch 1..=3 are small, 7..=9 are large
        assert!(classes
            .iter()
            .any(|&(id, c)| id == 0 && c == SizeClass::Small));
        assert!(classes
            .iter()
            .any(|&(id, c)| id == 8 && c == SizeClass::Large));
    }

    #[test]
    fn compute_weight_monotone_in_rank_and_batch() {
        assert!(job(0, 16, 4).compute_weight() > job(1, 2, 4).compute_weight());
        assert!(job(0, 8, 8).compute_weight() > job(1, 8, 2).compute_weight());
    }
}
