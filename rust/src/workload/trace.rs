//! ACMETrace-style trace generation and CSV I/O.
//!
//! Month profiles follow §4.3 / Fig. 8b: month 1 has the sparsest
//! arrivals; months 2 and 3 are increasingly bursty with ~2× and ~4×
//! higher concurrency. Service demand (step budgets) is lognormal —
//! the heavy tail production traces exhibit — and GPU gangs are powers
//! of two, matching the original trace's allocation distribution.

use super::JobSpec;
use crate::util::rng::Rng;

/// Sinusoidal arrival-rate modulation for day/night load shapes:
/// `rate(t) = rate · (1 + amplitude · sin(2πt/period + phase))`.
/// Arrivals are drawn from the resulting nonhomogeneous Poisson
/// process by thinning, so million-arrival traces stream out in O(1)
/// memory per job like the homogeneous path.
#[derive(Debug, Clone, PartialEq)]
pub struct DiurnalProfile {
    pub period_s: f64,
    /// peak-to-mean rate swing, in [0, 1)
    pub amplitude: f64,
    /// radians; 0 puts the peak a quarter-period after t=0
    pub phase: f64,
}

impl DiurnalProfile {
    /// A 24-hour cycle with the given amplitude.
    pub fn daily(amplitude: f64) -> DiurnalProfile {
        DiurnalProfile {
            period_s: 86_400.0,
            amplitude,
            phase: 0.0,
        }
    }

    /// Instantaneous rate multiplier at time `t`.
    pub fn rate_factor(&self, t: f64) -> f64 {
        1.0 + self.amplitude
            * (std::f64::consts::TAU * t / self.period_s
                + self.phase)
                .sin()
    }
}

/// One tenant population in a mixed workload. `weight` is the
/// relative share of arrivals; `None` fields inherit the profile's
/// catalogs, so a class only perturbs what it overrides.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantClass {
    pub name: String,
    pub weight: f64,
    /// lognormal mu override for total training steps
    pub steps_mu: Option<f64>,
    pub gpu_gangs: Option<Vec<usize>>,
    pub ranks: Option<Vec<usize>>,
}

/// Arrival/workload shape knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceProfile {
    /// mean arrival rate (jobs/second)
    pub rate: f64,
    /// probability that an arrival is part of a burst
    pub burst_prob: f64,
    /// burst size range (jobs submitted near-simultaneously)
    pub burst_size: (usize, usize),
    /// lognormal(mu, sigma) of total training steps
    pub steps_mu: f64,
    pub steps_sigma: f64,
    /// candidate values sampled per §4.1
    pub ranks: Vec<usize>,
    pub batch_sizes: Vec<usize>,
    pub seq_lens: Vec<usize>,
    pub gpu_gangs: Vec<usize>,
    pub base_models: Vec<String>,
    /// Δ^max range (bounded-slowdown tolerance)
    pub max_slowdown: (f64, f64),
    /// day/night arrival modulation; `None` keeps the homogeneous
    /// Poisson process (and the exact pre-diurnal RNG stream — the
    /// month profiles all disable it, so their traces are byte-stable)
    pub diurnal: Option<DiurnalProfile>,
    /// tenant mix; empty means one population drawn straight from the
    /// profile catalogs (again the exact legacy RNG stream)
    pub tenants: Vec<TenantClass>,
}

impl TraceProfile {
    /// Month-1 of the seren trace: the sparsest month, but still enough
    /// pressure to keep a 128-GPU cluster contended (§A.1 caps runnable
    /// concurrency at 128 jobs; the evaluation operates near that
    /// regime).
    pub fn month1() -> TraceProfile {
        TraceProfile {
            rate: 1.0 / 6.0, // ~10 jobs/minute
            burst_prob: 0.05,
            burst_size: (2, 4),
            // fine-tuning jobs run for thousands of steps (tens of
            // minutes to hours) — what keeps the 128-GPU cluster at its
            // §A.1 concurrency cap and makes queueing delay the JCT
            // driver, as in the original trace
            steps_mu: 8.3, // median ~4000 steps
            steps_sigma: 1.0,
            ranks: vec![2, 4, 8, 16],
            batch_sizes: vec![1, 2, 4, 8],
            seq_lens: vec![256, 512, 1024],
            gpu_gangs: vec![1, 1, 2, 2, 4, 8],
            base_models: vec!["llama3-8b".into(), "qwen3-8b".into()],
            max_slowdown: (1.2, 2.0),
            diurnal: None,
            tenants: vec![],
        }
    }

    /// Month-2: ~2× concurrency, burstier.
    pub fn month2() -> TraceProfile {
        let mut p = TraceProfile::month1();
        p.rate *= 2.0;
        p.burst_prob = 0.15;
        p.burst_size = (2, 6);
        p
    }

    /// Month-3: ~4× concurrency, burstiest.
    pub fn month3() -> TraceProfile {
        let mut p = TraceProfile::month1();
        p.rate *= 4.0;
        p.burst_prob = 0.25;
        p.burst_size = (3, 8);
        p
    }

    /// Scale the arrival rate (Fig. 9a replays 0.5×/2×/5×).
    pub fn scaled(mut self, factor: f64) -> TraceProfile {
        self.rate *= factor;
        self
    }

    /// Million-arrival stress shape for the report-scaling bench and
    /// `trace-gen --hyperscale`: dense arrivals, a strong day/night
    /// cycle, and a three-class tenant mix (interactive fine-tunes,
    /// steady batch jobs, long-running research runs).
    pub fn hyperscale() -> TraceProfile {
        let mut p = TraceProfile::month1();
        p.rate *= 8.0;
        p.burst_prob = 0.15;
        p.diurnal = Some(DiurnalProfile::daily(0.6));
        p.tenants = vec![
            TenantClass {
                name: "interactive".into(),
                weight: 0.6,
                steps_mu: Some(6.9), // median ~1000 steps
                gpu_gangs: Some(vec![1, 1, 2]),
                ranks: None,
            },
            TenantClass {
                name: "batch".into(),
                weight: 0.3,
                steps_mu: None,
                gpu_gangs: None,
                ranks: None,
            },
            TenantClass {
                name: "research".into(),
                weight: 0.1,
                steps_mu: Some(9.6), // median ~15k steps
                gpu_gangs: Some(vec![4, 8]),
                ranks: Some(vec![8, 16]),
            },
        ];
        p
    }
}

/// Deterministic synthetic trace generator.
#[derive(Debug)]
pub struct TraceGenerator {
    profile: TraceProfile,
    rng: Rng,
}

impl TraceGenerator {
    pub fn new(profile: TraceProfile, seed: u64) -> TraceGenerator {
        TraceGenerator {
            profile,
            rng: Rng::new(seed),
        }
    }

    /// Generate `n` jobs with ids 0..n.
    pub fn generate(&mut self, n: usize) -> Vec<JobSpec> {
        let mut jobs = Vec::with_capacity(n);
        let mut t = 0.0;
        let mut id = 0u64;
        while jobs.len() < n {
            t += self.next_arrival_gap(t);
            let burst = if self.rng.bool(self.profile.burst_prob) {
                self.rng
                    .range(self.profile.burst_size.0, self.profile.burst_size.1)
            } else {
                1
            };
            for b in 0..burst {
                if jobs.len() >= n {
                    break;
                }
                // bursts land within a few seconds of each other
                let jitter = b as f64 * self.rng.range_f64(0.5, 3.0);
                jobs.push(self.sample_job(id, t + jitter));
                id += 1;
            }
        }
        jobs
    }

    /// Seconds until the next arrival after time `t`. Homogeneous
    /// profiles draw one exponential — the exact pre-diurnal RNG
    /// stream. Diurnal profiles thin a candidate stream at the peak
    /// rate: each candidate consumes one exponential plus one accept
    /// draw, so memory stays O(1) at any trace length.
    fn next_arrival_gap(&mut self, t: f64) -> f64 {
        let p = &self.profile;
        match &p.diurnal {
            None => self.rng.exponential(p.rate),
            Some(d) => {
                let peak = p.rate * (1.0 + d.amplitude);
                let mut gap = 0.0;
                loop {
                    gap += self.rng.exponential(peak);
                    let accept =
                        p.rate * d.rate_factor(t + gap) / peak;
                    if self.rng.f64() < accept {
                        return gap;
                    }
                }
            }
        }
    }

    fn sample_job(&mut self, id: u64, submit_time: f64) -> JobSpec {
        let p = &self.profile;
        // tenant class first (one weighted draw) — skipped entirely
        // for empty mixes so legacy profiles keep their RNG stream
        let tenant = if p.tenants.is_empty() {
            None
        } else {
            let weights: Vec<f64> =
                p.tenants.iter().map(|c| c.weight).collect();
            Some(&p.tenants[self.rng.weighted(&weights)])
        };
        let steps_mu = tenant
            .and_then(|c| c.steps_mu)
            .unwrap_or(p.steps_mu);
        let ranks = tenant
            .and_then(|c| c.ranks.as_ref())
            .unwrap_or(&p.ranks);
        let gangs = tenant
            .and_then(|c| c.gpu_gangs.as_ref())
            .unwrap_or(&p.gpu_gangs);
        let steps = self
            .rng
            .lognormal(steps_mu, p.steps_sigma)
            .clamp(20.0, 100_000.0) as u64;
        JobSpec {
            id,
            base_model: self.rng.choice(&p.base_models).clone(),
            rank: *self.rng.choice(ranks),
            batch_size: *self.rng.choice(&p.batch_sizes),
            seq_len: *self.rng.choice(&p.seq_lens),
            gpus: *self.rng.choice(gangs),
            total_steps: steps,
            submit_time,
            max_slowdown: self
                .rng
                .range_f64(p.max_slowdown.0, p.max_slowdown.1),
        }
    }
}

// ---------------------------------------------------------------------------
// CSV I/O (schema mirrors trace_seren.csv + the LoRA columns of §4.1)
// ---------------------------------------------------------------------------

pub const CSV_HEADER: &str =
    "job_id,base_model,rank,batch_size,seq_len,gpus,total_steps,\
     submit_time,max_slowdown";

pub fn save_csv(jobs: &[JobSpec]) -> String {
    let mut out = String::from(CSV_HEADER);
    out.push('\n');
    for j in jobs {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{}\n",
            j.id,
            j.base_model,
            j.rank,
            j.batch_size,
            j.seq_len,
            j.gpus,
            j.total_steps,
            j.submit_time,
            j.max_slowdown
        ));
    }
    out
}

/// Parsed header of a job-trace CSV: where each required column sits.
/// The streaming readers resolve this once, then parse data lines one
/// at a time — no line outlives its [`JobSpec`].
#[derive(Debug, Clone, Copy)]
struct ColumnMap {
    ci_id: usize,
    ci_model: usize,
    ci_rank: usize,
    ci_batch: usize,
    ci_seq: usize,
    ci_gpus: usize,
    ci_steps: usize,
    ci_submit: usize,
    ci_slow: usize,
}

impl ColumnMap {
    fn parse(header: &str) -> Result<ColumnMap, String> {
        let cols: Vec<&str> =
            header.split(',').map(str::trim).collect();
        let idx = |name: &str| -> Result<usize, String> {
            cols.iter()
                .position(|c| *c == name)
                .ok_or_else(|| format!("missing column {name}"))
        };
        Ok(ColumnMap {
            ci_id: idx("job_id")?,
            ci_model: idx("base_model")?,
            ci_rank: idx("rank")?,
            ci_batch: idx("batch_size")?,
            ci_seq: idx("seq_len")?,
            ci_gpus: idx("gpus")?,
            ci_steps: idx("total_steps")?,
            ci_submit: idx("submit_time")?,
            ci_slow: idx("max_slowdown")?,
        })
    }

    /// Parse one data line. `lineno` is the 0-based index among
    /// post-header lines (blank ones included) so error messages keep
    /// the eager loader's 1-based whole-file line numbers. Blank lines
    /// yield `Ok(None)`.
    fn parse_line(
        &self,
        lineno: usize,
        line: &str,
    ) -> Result<Option<JobSpec>, String> {
        if line.trim().is_empty() {
            return Ok(None);
        }
        let f: Vec<&str> = line.split(',').map(str::trim).collect();
        let get = |i: usize| -> Result<&str, String> {
            f.get(i).copied().ok_or_else(|| {
                format!("line {}: missing field", lineno + 2)
            })
        };
        let parse_num = |s: &str| -> Result<f64, String> {
            s.parse().map_err(|_| {
                format!("line {}: bad number {s}", lineno + 2)
            })
        };
        Ok(Some(JobSpec {
            id: parse_num(get(self.ci_id)?)? as u64,
            base_model: get(self.ci_model)?.to_string(),
            rank: parse_num(get(self.ci_rank)?)? as usize,
            batch_size: parse_num(get(self.ci_batch)?)? as usize,
            seq_len: parse_num(get(self.ci_seq)?)? as usize,
            gpus: parse_num(get(self.ci_gpus)?)? as usize,
            total_steps: parse_num(get(self.ci_steps)?)? as u64,
            submit_time: parse_num(get(self.ci_submit)?)?,
            max_slowdown: parse_num(get(self.ci_slow)?)?,
        }))
    }
}

/// Stream jobs out of in-memory CSV text without building a `Vec`.
/// Header problems ("empty csv", "missing column …") surface
/// immediately; per-line problems surface as `Err` items at the line
/// that has them, with messages byte-identical to the eager loader's.
pub fn stream_csv(
    text: &str,
) -> Result<impl Iterator<Item = Result<JobSpec, String>> + '_, String>
{
    let mut lines = text.lines();
    let header = lines.next().ok_or("empty csv")?;
    let cols = ColumnMap::parse(header)?;
    Ok(lines.enumerate().filter_map(move |(lineno, line)| {
        cols.parse_line(lineno, line).transpose()
    }))
}

/// Stream jobs straight off a file through a `BufReader`, one line in
/// memory at a time — a million-job trace never materializes as text
/// or as a `Vec<JobSpec>` inside this reader (what the *consumer*
/// retains is its own business).
pub fn stream_csv_file(
    path: &std::path::Path,
) -> Result<impl Iterator<Item = Result<JobSpec, String>>, String> {
    use std::io::BufRead;
    let file = std::fs::File::open(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    let mut lines = std::io::BufReader::new(file).lines();
    let header = match lines.next() {
        None => return Err("empty csv".into()),
        Some(h) => {
            h.map_err(|e| format!("{}: {e}", path.display()))?
        }
    };
    let cols = ColumnMap::parse(&header)?;
    Ok(lines.enumerate().filter_map(move |(lineno, line)| {
        match line {
            Err(e) => {
                Some(Err(format!("line {}: {e}", lineno + 2)))
            }
            Ok(l) => cols.parse_line(lineno, &l).transpose(),
        }
    }))
}

pub fn load_csv(text: &str) -> Result<Vec<JobSpec>, String> {
    stream_csv(text)?.collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_deterministic() {
        let a = TraceGenerator::new(TraceProfile::month1(), 7).generate(50);
        let b = TraceGenerator::new(TraceProfile::month1(), 7).generate(50);
        assert_eq!(a, b);
    }

    #[test]
    fn generator_arrival_times_increase_mostly() {
        let jobs = TraceGenerator::new(TraceProfile::month1(), 1)
            .generate(100);
        assert_eq!(jobs.len(), 100);
        // non-burst portion is sorted; allow burst jitter
        let sorted_violations = jobs
            .windows(2)
            .filter(|w| w[1].submit_time < w[0].submit_time - 30.0)
            .count();
        assert_eq!(sorted_violations, 0);
    }

    #[test]
    fn month_profiles_scale_concurrency() {
        let j1 = TraceGenerator::new(TraceProfile::month1(), 3)
            .generate(300);
        let j3 = TraceGenerator::new(TraceProfile::month3(), 3)
            .generate(300);
        let span1 = j1.last().unwrap().submit_time;
        let span3 = j3.last().unwrap().submit_time;
        // month 3 packs the same jobs into ~1/4 the wall-clock
        let ratio = span1 / span3;
        assert!(ratio > 2.5, "ratio {ratio}");
    }

    #[test]
    fn sampled_attrs_in_catalog() {
        let p = TraceProfile::month1();
        let jobs = TraceGenerator::new(p.clone(), 9).generate(200);
        for j in &jobs {
            assert!(p.ranks.contains(&j.rank));
            assert!(p.batch_sizes.contains(&j.batch_size));
            assert!(p.gpu_gangs.contains(&j.gpus));
            assert!(p.base_models.contains(&j.base_model));
            assert!(j.total_steps >= 20);
            assert!(j.max_slowdown >= 1.2 && j.max_slowdown <= 2.0);
        }
    }

    #[test]
    fn csv_roundtrip() {
        let jobs = TraceGenerator::new(TraceProfile::month2(), 5)
            .generate(40);
        let csv = save_csv(&jobs);
        let back = load_csv(&csv).unwrap();
        assert_eq!(jobs.len(), back.len());
        for (a, b) in jobs.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.rank, b.rank);
            assert_eq!(a.gpus, b.gpus);
            assert!((a.submit_time - b.submit_time).abs() < 1e-6);
        }
    }

    #[test]
    fn diurnal_generator_deterministic() {
        let a = TraceGenerator::new(TraceProfile::hyperscale(), 11)
            .generate(500);
        let b = TraceGenerator::new(TraceProfile::hyperscale(), 11)
            .generate(500);
        assert_eq!(a, b);
    }

    #[test]
    fn diurnal_modulates_arrival_density() {
        // short period so a few thousand arrivals span many cycles;
        // sin > 0 over the first half-period, so on-peak halves must
        // collect clearly more arrivals than off-peak halves
        let mut p = TraceProfile::month1();
        p.burst_prob = 0.0; // isolate the arrival process
        p.diurnal = Some(DiurnalProfile {
            period_s: 2_000.0,
            amplitude: 0.9,
            phase: 0.0,
        });
        let jobs =
            TraceGenerator::new(p, 5).generate(6_000);
        let (mut on_peak, mut off_peak) = (0usize, 0usize);
        for j in &jobs {
            if j.submit_time % 2_000.0 < 1_000.0 {
                on_peak += 1;
            } else {
                off_peak += 1;
            }
        }
        let ratio = on_peak as f64 / off_peak as f64;
        assert!(ratio > 1.5, "on/off-peak ratio {ratio}");
    }

    #[test]
    fn diurnal_rate_factor_shape() {
        let d = DiurnalProfile::daily(0.5);
        assert!((d.rate_factor(0.0) - 1.0).abs() < 1e-12);
        assert!((d.rate_factor(21_600.0) - 1.5).abs() < 1e-9);
        assert!((d.rate_factor(64_800.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn tenant_mix_overrides_catalogs() {
        let mut p = TraceProfile::month1();
        p.tenants = vec![TenantClass {
            name: "gang8".into(),
            weight: 1.0,
            steps_mu: None,
            gpu_gangs: Some(vec![8]),
            ranks: Some(vec![16]),
        }];
        let jobs = TraceGenerator::new(p, 2).generate(100);
        assert!(jobs.iter().all(|j| j.gpus == 8 && j.rank == 16));
    }

    #[test]
    fn tenant_mix_respects_weights() {
        // hyperscale: interactive (gangs ≤ 2) is 60% of arrivals and
        // never draws the research gangs; spot-check the split via
        // the gang catalogs, which partition the classes
        let p = TraceProfile::hyperscale();
        let jobs = TraceGenerator::new(p.clone(), 13).generate(2_000);
        let small = jobs.iter().filter(|j| j.gpus <= 2).count();
        assert!(
            small as f64 / jobs.len() as f64 > 0.55,
            "small-gang share {small}/{}",
            jobs.len()
        );
        for j in &jobs {
            assert!(
                p.gpu_gangs.contains(&j.gpus) || j.gpus == 4 || j.gpus == 8,
                "gang {} outside every catalog",
                j.gpus
            );
        }
    }

    #[test]
    fn hyperscale_sustains_large_traces() {
        // the bench pushes this to 1M+; unit tests keep it quick
        let jobs = TraceGenerator::new(TraceProfile::hyperscale(), 1)
            .generate(100_000);
        assert_eq!(jobs.len(), 100_000);
        assert_eq!(jobs[99_999].id, 99_999);
        let violations = jobs
            .windows(2)
            .filter(|w| w[1].submit_time < w[0].submit_time - 30.0)
            .count();
        assert_eq!(violations, 0);
    }

    #[test]
    fn csv_rejects_missing_columns() {
        assert!(load_csv("a,b,c\n1,2,3").is_err());
        assert!(load_csv("").is_err());
    }

    #[test]
    fn streaming_reader_matches_eager_loader_exactly() {
        // golden trace: every field of every job identical between the
        // one-line-at-a-time path and the materializing path
        let jobs = TraceGenerator::new(TraceProfile::month2(), 5)
            .generate(300);
        let csv = save_csv(&jobs);
        let eager = load_csv(&csv).unwrap();
        let streamed: Vec<JobSpec> = stream_csv(&csv)
            .unwrap()
            .collect::<Result<_, _>>()
            .unwrap();
        assert_eq!(eager, streamed);
        assert_eq!(streamed, jobs);
        // error messages are byte-identical too, including line
        // numbers counted across blank lines
        let bad = format!("{CSV_HEADER}\n\n1,llama3-8b,8,4,512,x,\
                           100,1.5,1.3\n");
        let e_eager = load_csv(&bad).unwrap_err();
        let e_stream = stream_csv(&bad)
            .unwrap()
            .find_map(Result::err)
            .unwrap();
        assert_eq!(e_eager, e_stream);
        assert_eq!(e_eager, "line 3: bad number x");
        let short = format!("{CSV_HEADER}\n1,llama3-8b,8\n");
        assert_eq!(
            load_csv(&short).unwrap_err(),
            stream_csv(&short).unwrap().find_map(Result::err).unwrap()
        );
        // header errors surface before any iteration
        assert!(stream_csv("").is_err());
        assert!(stream_csv("a,b\n1,2").is_err());
    }

    #[test]
    fn file_streamer_matches_in_memory_paths() {
        let jobs = TraceGenerator::new(TraceProfile::month1(), 17)
            .generate(64);
        let csv = save_csv(&jobs);
        let path = std::env::temp_dir()
            .join("tlora_stream_csv_file_test.csv");
        std::fs::write(&path, &csv).unwrap();
        let streamed: Vec<JobSpec> = stream_csv_file(&path)
            .unwrap()
            .collect::<Result<_, _>>()
            .unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(streamed, jobs);
        assert!(stream_csv_file(std::path::Path::new(
            "/nonexistent/tlora.csv"
        ))
        .is_err());
    }

    #[test]
    fn csv_tolerates_column_reorder_and_blank_lines() {
        let text = "rank,job_id,base_model,batch_size,seq_len,gpus,\
                    total_steps,submit_time,max_slowdown\n\
                    8,3,llama3-8b,4,512,2,100,1.5,1.3\n\n";
        let jobs = load_csv(text).unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].id, 3);
        assert_eq!(jobs[0].rank, 8);
    }
}
