//! # tLoRA — Efficient Multi-LoRA Training with Elastic Shared Super-Models
//!
//! Rust + JAX + Pallas reproduction of the tLoRA paper (Li et al., 2026).
//!
//! tLoRA batches heterogeneous LoRA fine-tuning jobs that share a frozen
//! backbone into an *elastic Shared Super-Model* (SSM), executes them with
//! a fused rank-aware LoRA kernel plus adaptive nano-batching, and groups
//! jobs online with a residual-capacity-aware scheduler.
//!
//! The crate is Layer 3 of a three-layer stack:
//!
//! * **L1** — Pallas fused multi-LoRA kernel (`python/compile/kernels/`),
//!   AOT-lowered to HLO text at build time.
//! * **L2** — JAX Shared Super-Model train step (`python/compile/model.py`).
//! * **L3** — this crate: the coordinator, the Adapter Scheduler, the
//!   Model/Kernel Fuser cost models, the discrete-event cluster simulator,
//!   and the PJRT runtime that executes the AOT artifacts. Python never
//!   runs on the training path.
//!
//! Module map (see DESIGN.md for the paper-section correspondence):
//!
//! | module | role |
//! |---|---|
//! | [`util`] | zero-dependency substrates: JSON, RNG, stats, prop-testing |
//! | [`config`] | typed configuration + JSON I/O |
//! | [`cluster`] | GPU/node/cluster topology model + gang allocator |
//! | [`model`] | transformer + LoRA cost model (FLOPs/bytes/memory) |
//! | [`workload`] | job specs, ACMETrace-like trace generation, fault/churn synthesis |
//! | [`ssm`] | Shared Super-Model graph + Model Fuser (§3.2) |
//! | [`planner`] | pipeline/TP parallelism planner over SSM (§3.2) |
//! | [`kernelsim`] | fused-kernel + nano-batch AIMD overlap model (§3.3) |
//! | [`scheduler`] | residual-capacity-aware Adapter Scheduler (§3.4) |
//! | [`sim`] | discrete-event cluster simulator (trace-driven eval) |
//! | [`sweep`] | parallel scenario-sweep engine over sim (grids, CIs) |
//! | [`baselines`] | mLoRA, Megatron-independent, tLoRA ablations |
//! | [`runtime`] | PJRT executor for `artifacts/*.hlo.txt` |
//! | [`train`] | real end-to-end training driver + micro-benchmarks |
//! | [`coordinator`] | leader event loop tying everything together |
//! | [`metrics`] | table/CSV/CDF reporters shared by benches |

pub mod util;
pub mod config;
pub mod cluster;
pub mod model;
pub mod workload;
pub mod ssm;
pub mod planner;
pub mod kernelsim;
pub mod scheduler;
pub mod sim;
pub mod sweep;
pub mod baselines;
pub mod runtime;
pub mod train;
pub mod coordinator;
pub mod metrics;
pub mod cli;
pub mod bench_util;
