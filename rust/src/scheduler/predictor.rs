//! Throughput predictor T̂(G): composes the Model Fuser, the planner and
//! the Kernel Fuser model into per-group performance estimates, with a
//! **two-level** memoization cache so the scheduler's repeated probes
//! are cheap:
//!
//! * **exact level** — keyed by (job ids, ordered per-node GPU-count
//!   runs of the allocation): repeats of the identical query return
//!   the memoized [`GroupPerf`] without even re-fusing the SSM. Local
//!   GPU indices are *not* part of the key — plans cannot depend on
//!   them (see [`crate::planner::PlanShapeKey`]).
//! * **shape level** — keyed by [`crate::planner::PlanShapeKey`]
//!   (SSM fingerprint + canonical node pattern + plan options):
//!   probing the same group *shape* on different physical nodes — the
//!   dominant pattern in binary-cut partner search and
//!   `allocate_avoiding` fallbacks — reuses the cached
//!   [`ParallelPlan`] instead of re-running the planner. The key
//!   contract guarantees the reused plan is bit-identical to what a
//!   cold planner run would produce, so caching never perturbs
//!   simulation output (pinned by the cached-vs-cold differential in
//!   `tests/integration_perf.rs`).
//!
//! [`Predictor::probes`] counts *planner evaluations* (shape-level
//! misses) — the quantity the `sched_scaling` bench gates on;
//! [`Predictor::shape_hits`] / [`Predictor::exact_hits`] count the
//! queries each cache level absorbed.

use std::collections::HashMap;

use crate::cluster::{Allocation, ClusterSpec};
use crate::planner::{
    alloc_node_runs, plan, ParallelPlan, PlanError, PlanOptions,
    PlanShapeKey,
};
use crate::ssm::Ssm;
use crate::workload::JobSpec;

/// Predicted performance of a fused group.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupPerf {
    /// group step time (all members step together)
    pub step_time_s: f64,
    /// Σ_j batch_j / step_time — cluster-throughput contribution
    pub throughput_samples_s: f64,
    /// per member (job id, Δ_j(G) = isolated progress rate / grouped)
    pub slowdowns: Vec<(u64, f64)>,
    /// compute utilization over the group's GPUs (Fig. 6a metric)
    pub compute_util: f64,
    pub plan: ParallelPlan,
}

impl GroupPerf {
    /// Does every member respect its Δ^max?
    pub fn within_slowdown(&self, jobs: &[JobSpec]) -> bool {
        self.slowdowns.iter().all(|(id, s)| {
            jobs.iter()
                .find(|j| j.id == *id)
                .map_or(true, |j| *s <= j.max_slowdown)
        })
    }
}

/// Memoizing predictor (see the module docs for the two cache levels
/// and the counter semantics).
pub struct Predictor {
    spec: ClusterSpec,
    opts: PlanOptions,
    iso_cache: HashMap<(u64, Vec<(usize, u32)>), f64>,
    /// exact-level residual memo (warm mode only): repeats of the
    /// per-round residual refresh skip even the SSM re-fuse and
    /// shape-key construction. Deliberately bypassed in cold mode,
    /// which models the pre-optimization predictor — residuals were
    /// its single hottest *uncached* probe source.
    residual_cache: HashMap<(u64, Vec<(usize, u32)>), f64>,
    group_cache: HashMap<CacheKey, Option<GroupPerf>>,
    /// shape level: canonical plan key → planner outcome (errors are
    /// cached too — an OOM shape stays OOM)
    shape_cache: HashMap<PlanShapeKey, Result<ParallelPlan, PlanError>>,
    /// holed (individually failed) GPUs per node, mirrored from the
    /// allocator by the engine via [`Predictor::set_node_holes`]:
    /// shape keys consulted while a hole is open carry the node's
    /// surviving GPU count, so hole-era plans never alias hole-free
    /// entries. All zeros (every fleet that never sees a GPU fault)
    /// contributes an empty key component — pre-hole keys and cached
    /// plans are byte-identical to before this field existed.
    holes: Vec<u32>,
    /// `false` = cold mode: every shape-level miss *and hit* runs the
    /// planner (the differential tests compare cold vs cached runs)
    shape_cache_enabled: bool,
    /// planner evaluations (shape-level misses)
    pub probes: u64,
    /// shape-level hits: a plan reused across allocations/groups
    pub shape_hits: u64,
    /// exact-level hits: an identical query answered without re-fusing
    pub exact_hits: u64,
}

type CacheKey = (Vec<u64>, Vec<(usize, u32)>);

fn key_of(jobs: &[JobSpec], alloc: &Allocation) -> CacheKey {
    let mut ids: Vec<u64> = jobs.iter().map(|j| j.id).collect();
    ids.sort_unstable();
    (ids, alloc_node_runs(alloc))
}

impl Predictor {
    pub fn new(spec: ClusterSpec, opts: PlanOptions) -> Predictor {
        let holes = vec![0; spec.n_nodes];
        Predictor {
            spec,
            opts,
            iso_cache: HashMap::new(),
            residual_cache: HashMap::new(),
            group_cache: HashMap::new(),
            shape_cache: HashMap::new(),
            shape_cache_enabled: true,
            holes,
            probes: 0,
            shape_hits: 0,
            exact_hits: 0,
        }
    }

    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// Disable (or re-enable) this PR-generation's cache additions:
    /// the shape-level plan cache *and* the exact-level residual memo.
    /// Cold mode (`false`) reproduces the pre-optimization predictor's
    /// cost profile — iso/group exact caches on (those predate the
    /// shape cache), residuals uncached, every plan-level consult a
    /// planner run — for the cached-vs-cold byte-identity
    /// differentials and the bench's ≥30% probe-drop gate.
    pub fn set_shape_cache(&mut self, enabled: bool) {
        self.shape_cache_enabled = enabled;
    }

    /// Record that `holed` GPUs of `node` are individually failed
    /// (0 = hole-free). Called by the engine on every GPU failure and
    /// recovery so plan-shape keys track the fleet's hole pattern;
    /// exact-level caches are untouched — a plan for a *given*
    /// allocation is a pure function of (SSM, allocation, spec,
    /// options), so entries memoized before a hole opened stay
    /// bit-identical to what a cold planner run would produce.
    pub fn set_node_holes(&mut self, node: usize, holed: u32) {
        self.holes[node] = holed;
    }

    /// Total queries absorbed by either cache level.
    pub fn cache_hits(&self) -> u64 {
        self.shape_hits + self.exact_hits
    }

    /// Fraction of *plan-level* consults served from the shape cache
    /// (exact-level hits never reach the plan level, so they are in
    /// neither numerator nor denominator — the all-levels query rate
    /// is [`crate::sim::SimResult::plan_cache_rate`]).
    pub fn shape_hit_rate(&self) -> f64 {
        let total = self.shape_hits + self.probes;
        if total == 0 {
            0.0
        } else {
            self.shape_hits as f64 / total as f64
        }
    }

    /// Plan `ssm` on `alloc` through the shape-level cache: a canonical
    /// shape seen before returns the memoized (bit-identical) plan
    /// without running the planner.
    fn plan_cached(
        &mut self,
        ssm: &Ssm,
        alloc: &Allocation,
    ) -> Result<ParallelPlan, PlanError> {
        if !self.shape_cache_enabled {
            self.probes += 1;
            return plan(ssm, alloc, &self.spec, &self.opts);
        }
        let key = PlanShapeKey::of_with_holes(
            ssm,
            alloc,
            &self.spec,
            &self.holes,
            &self.opts,
        );
        if let Some(r) = self.shape_cache.get(&key) {
            self.shape_hits += 1;
            return r.clone();
        }
        self.probes += 1;
        let r = plan(ssm, alloc, &self.spec, &self.opts);
        self.shape_cache.insert(key, r.clone());
        r
    }

    /// Step time of `job` running alone on `alloc`.
    pub fn isolated_step_time(
        &mut self,
        job: &JobSpec,
        alloc: &Allocation,
    ) -> Result<f64, PlanError> {
        let gkey = alloc_node_runs(alloc);
        if let Some(&t) = self.iso_cache.get(&(job.id, gkey.clone())) {
            self.exact_hits += 1;
            return Ok(t);
        }
        let ssm = Ssm::fuse(std::slice::from_ref(job))
            .map_err(|_| PlanError::NoGpus)?;
        let p = self.plan_cached(&ssm, alloc)?;
        self.iso_cache.insert((job.id, gkey), p.step_time_s);
        Ok(p.step_time_s)
    }

    /// Residual capacity of `job` on its allocation: 1 - isolated
    /// compute utilization. Served through both cache levels — the
    /// per-round residual refresh of every admitted candidate was the
    /// single hottest uncached probe source before them. The exact
    /// memo is skipped in cold mode so the cold reference keeps the
    /// pre-optimization cost profile.
    pub fn residual(
        &mut self,
        job: &JobSpec,
        alloc: &Allocation,
    ) -> Result<f64, PlanError> {
        let key = (job.id, alloc_node_runs(alloc));
        if self.shape_cache_enabled {
            if let Some(&r) = self.residual_cache.get(&key) {
                self.exact_hits += 1;
                return Ok(r);
            }
        }
        let ssm = Ssm::fuse(std::slice::from_ref(job))
            .map_err(|_| PlanError::NoGpus)?;
        let p = self.plan_cached(&ssm, alloc)?;
        let r = (1.0 - p.compute_util).clamp(0.0, 1.0);
        if self.shape_cache_enabled {
            self.residual_cache.insert(key, r);
        }
        Ok(r)
    }

    /// Full group performance on a (merged) allocation. `None` when the
    /// group does not fit (mixed base models, OOM, …).
    pub fn group_perf(
        &mut self,
        jobs: &[JobSpec],
        alloc: &Allocation,
    ) -> Option<GroupPerf> {
        let key = key_of(jobs, alloc);
        if let Some(cached) = self.group_cache.get(&key) {
            self.exact_hits += 1;
            return cached.clone();
        }
        let ssm = match Ssm::fuse(jobs) {
            Ok(s) => s,
            Err(_) => {
                self.group_cache.insert(key, None);
                return None;
            }
        };
        let p = match self.plan_cached(&ssm, alloc) {
            Ok(p) => p,
            Err(_) => {
                self.group_cache.insert(key, None);
                return None;
            }
        };
        let mut slowdowns = vec![];
        for j in jobs {
            // compare against the job's own provisioned allocation
            let iso_alloc = sub_alloc(alloc, j.gpus);
            let iso = self
                .isolated_step_time(j, &iso_alloc)
                .unwrap_or(f64::INFINITY);
            slowdowns.push((j.id, p.step_time_s / iso));
        }
        let throughput = jobs
            .iter()
            .map(|j| j.batch_size as f64)
            .sum::<f64>()
            / p.step_time_s;
        let perf = GroupPerf {
            step_time_s: p.step_time_s,
            throughput_samples_s: throughput,
            slowdowns,
            compute_util: p.compute_util,
            plan: p,
        };
        self.group_cache.insert(key, Some(perf.clone()));
        Some(perf)
    }

    /// Aggregate throughput if each of `groups` runs independently —
    /// the quantity hierarchical grouping tries to beat.
    pub fn sum_throughput(
        &mut self,
        groups: &[(&[JobSpec], &Allocation)],
    ) -> f64 {
        groups
            .iter()
            .filter_map(|(jobs, alloc)| {
                self.group_perf(jobs, alloc)
                    .map(|p| p.throughput_samples_s)
            })
            .sum()
    }
}

/// First `n` GPUs of an allocation (a job's nominal share of a merged
/// gang, used for isolated-baseline comparisons).
fn sub_alloc(alloc: &Allocation, n: usize) -> Allocation {
    Allocation {
        gpus: alloc
            .gpus
            .iter()
            .take(n.max(1).min(alloc.gpus.len()))
            .cloned()
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Allocator;

    fn job(id: u64, rank: usize, batch: usize, seq: usize, gpus: usize)
        -> JobSpec {
        JobSpec {
            id,
            base_model: "llama3-8b".into(),
            rank,
            batch_size: batch,
            seq_len: seq,
            gpus,
            total_steps: 100,
            submit_time: 0.0,
            max_slowdown: 2.0,
        }
    }

    fn predictor() -> (Predictor, Allocator) {
        let spec = ClusterSpec::default_128();
        (
            Predictor::new(spec.clone(), PlanOptions::default()),
            Allocator::new(spec),
        )
    }

    #[test]
    fn isolated_cached() {
        let (mut p, mut a) = predictor();
        let alloc = a.allocate(2).unwrap();
        let j = job(0, 8, 4, 512, 2);
        let t1 = p.isolated_step_time(&j, &alloc).unwrap();
        let probes = p.probes;
        let t2 = p.isolated_step_time(&j, &alloc).unwrap();
        assert_eq!(t1, t2);
        assert_eq!(p.probes, probes, "cache miss on identical query");
    }

    #[test]
    fn group_of_one_matches_isolated() {
        let (mut p, mut a) = predictor();
        let alloc = a.allocate(2).unwrap();
        let j = job(0, 8, 4, 512, 2);
        let iso = p.isolated_step_time(&j, &alloc).unwrap();
        let g = p.group_perf(&[j.clone()], &alloc).unwrap();
        assert!((g.step_time_s - iso).abs() < 1e-12);
        assert!((g.slowdowns[0].1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn complementary_jobs_gain_throughput() {
        // two under-utilized jobs (neither saturates its GPU): fused on
        // the union, the shared backbone pass amortizes the per-wave
        // fixed costs and aggregate throughput beats isolated execution
        let (mut p, mut a) = predictor();
        let small = job(0, 4, 2, 512, 1);
        let big = job(1, 8, 4, 512, 1);
        let a_small = a.allocate(1).unwrap();
        let a_big = a.allocate(1).unwrap();
        let iso_sum = p.sum_throughput(&[
            (std::slice::from_ref(&small), &a_small),
            (std::slice::from_ref(&big), &a_big),
        ]);
        let merged = a_small.union(&a_big);
        let g = p
            .group_perf(&[small.clone(), big.clone()], &merged)
            .unwrap();
        assert!(
            g.throughput_samples_s > iso_sum,
            "grouped {} vs isolated {}",
            g.throughput_samples_s,
            iso_sum
        );
    }

    #[test]
    fn mixed_base_models_unfusable() {
        let (mut p, mut a) = predictor();
        let alloc = a.allocate(2).unwrap();
        let j0 = job(0, 8, 4, 512, 1);
        let mut j1 = job(1, 8, 4, 512, 1);
        j1.base_model = "qwen3-8b".into();
        assert!(p.group_perf(&[j0, j1], &alloc).is_none());
    }

    #[test]
    fn unfusable_result_cached() {
        let (mut p, mut a) = predictor();
        let alloc = a.allocate(2).unwrap();
        let j0 = job(0, 8, 4, 512, 1);
        let mut j1 = job(1, 8, 4, 512, 1);
        j1.base_model = "qwen3-8b".into();
        assert!(p.group_perf(&[j0.clone(), j1.clone()], &alloc).is_none());
        let probes = p.probes;
        assert!(p.group_perf(&[j0, j1], &alloc).is_none());
        assert_eq!(p.probes, probes);
    }

    #[test]
    fn local_gpu_indices_not_part_of_exact_key() {
        // plans cannot depend on local GPU indices, so two allocations
        // differing only in idx share one exact-level entry
        use crate::cluster::GpuId;
        let (mut p, _) = predictor();
        let j = job(0, 8, 4, 512, 2);
        let a = Allocation {
            gpus: vec![
                GpuId { node: 0, idx: 0 },
                GpuId { node: 0, idx: 1 },
            ],
        };
        let b = Allocation {
            gpus: vec![
                GpuId { node: 0, idx: 6 },
                GpuId { node: 0, idx: 7 },
            ],
        };
        let pa = p.group_perf(&[j.clone()], &a).unwrap();
        let probes = p.probes;
        let hits = p.exact_hits;
        let pb = p.group_perf(&[j], &b).unwrap();
        assert_eq!(p.probes, probes, "idx change caused a planner run");
        assert!(p.exact_hits > hits, "idx change missed the exact level");
        assert_eq!(pa, pb);
    }

    #[test]
    fn same_shape_on_different_nodes_reuses_plan() {
        // the tentpole pattern: probing one group shape on different
        // physical nodes must hit the shape level, not the planner
        use crate::cluster::GpuId;
        let (mut p, _) = predictor();
        let jobs = vec![job(0, 8, 4, 512, 1), job(1, 4, 2, 256, 1)];
        let a = Allocation {
            gpus: vec![
                GpuId { node: 0, idx: 0 },
                GpuId { node: 0, idx: 1 },
            ],
        };
        let b = Allocation {
            gpus: vec![
                GpuId { node: 9, idx: 3 },
                GpuId { node: 9, idx: 4 },
            ],
        };
        let pa = p.group_perf(&jobs, &a).unwrap();
        let probes = p.probes;
        let shape_hits = p.shape_hits;
        let pb = p.group_perf(&jobs, &b).unwrap();
        assert_eq!(
            p.probes, probes,
            "same shape on other nodes re-ran the planner"
        );
        assert!(p.shape_hits > shape_hits, "shape level never consulted");
        assert_eq!(pa, pb, "cached shape produced a different perf");
    }

    #[test]
    fn prop_random_same_shape_allocations_identical_group_perf() {
        // property (satellite): for random groups and random same-shape
        // allocations, the cached predictor returns a GroupPerf
        // bit-identical both across the allocations and to a *cold*
        // (shape-cache-disabled) predictor evaluating the same query
        use crate::cluster::GpuId;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xCAFE);
        let spec = ClusterSpec::default_128();
        for trial in 0..25u64 {
            let n_runs = rng.range(1, 3);
            let runs: Vec<usize> =
                (0..n_runs).map(|_| rng.range(1, 3)).collect();
            // two disjoint node assignments of the same run pattern
            let build = |node0: usize, idx0: usize| Allocation {
                gpus: runs
                    .iter()
                    .enumerate()
                    .flat_map(|(r, &c)| {
                        (0..c).map(move |i| GpuId {
                            node: node0 + 2 * r,
                            idx: idx0 + i,
                        })
                    })
                    .collect(),
            };
            let a = build(rng.range(0, 3), 0);
            let b = build(rng.range(8, 11), rng.range(0, 4));
            let n_jobs = rng.range(1, 3);
            let jobs: Vec<JobSpec> = (0..n_jobs)
                .map(|i| {
                    job(
                        trial * 10 + i as u64,
                        [2, 4, 8, 16][rng.range(0, 3)],
                        [1, 2, 4][rng.range(0, 2)],
                        [256, 512][rng.range(0, 1)],
                        1,
                    )
                })
                .collect();
            let mut warm =
                Predictor::new(spec.clone(), PlanOptions::default());
            let mut cold =
                Predictor::new(spec.clone(), PlanOptions::default());
            cold.set_shape_cache(false);
            let pa = warm.group_perf(&jobs, &a);
            let pb = warm.group_perf(&jobs, &b);
            let pc = cold.group_perf(&jobs, &b);
            assert_eq!(pa, pb, "trial {trial}: same shape diverged");
            assert_eq!(
                pb, pc,
                "trial {trial}: cached result differs from cold planner"
            );
        }
    }

    #[test]
    fn node_holes_partition_the_shape_cache_but_not_the_plan() {
        // opening a hole on a touched node re-keys the shape level
        // (forcing planner runs), but a plan for a *given* allocation
        // shape is hole-independent, so the result is bit-identical;
        // closing the hole returns to the original, still-cached
        // entries. Same-shape allocations on different nodes keep the
        // queries off the exact level (whose keys carry physical node
        // ids) so every probe genuinely consults the shape cache.
        use crate::cluster::GpuId;
        let (mut p, _) = predictor();
        let jobs = vec![job(0, 8, 4, 512, 1), job(1, 4, 2, 256, 1)];
        let on = |node: usize| Allocation {
            gpus: vec![
                GpuId { node, idx: 0 },
                GpuId { node, idx: 1 },
            ],
        };
        let before = p.group_perf(&jobs, &on(1)).unwrap();
        let probes = p.probes;
        p.set_node_holes(0, 1);
        let holed = p.group_perf(&jobs, &on(0)).unwrap();
        assert!(
            p.probes > probes,
            "hole-era keys aliased hole-free entries"
        );
        assert_eq!(
            before.plan, holed.plan,
            "same allocation shape planned differently under a hole"
        );
        let probes = p.probes;
        p.set_node_holes(0, 0);
        let shape_hits = p.shape_hits;
        let healed = p.group_perf(&jobs, &on(2)).unwrap();
        assert_eq!(p.probes, probes, "heal re-ran the planner");
        assert!(p.shape_hits > shape_hits, "heal missed the shape level");
        assert_eq!(before.plan, healed.plan);
        // holes on nodes the allocation never touches change nothing
        p.set_node_holes(5, 2);
        let elsewhere = p.group_perf(&jobs, &on(3)).unwrap();
        assert_eq!(p.probes, probes, "untouched-node hole re-planned");
        assert_eq!(before.plan, elsewhere.plan);
    }

    #[test]
    fn cold_mode_counts_probes_never_hits() {
        let (mut p, mut a) = predictor();
        p.set_shape_cache(false);
        let alloc = a.allocate(1).unwrap();
        let j = job(0, 8, 4, 512, 1);
        p.residual(&j, &alloc).unwrap();
        p.residual(&j, &alloc).unwrap();
        assert_eq!(p.probes, 2, "cold residuals must re-plan every time");
        assert_eq!(p.shape_hits, 0);
    }

    #[test]
    fn residual_repeat_and_same_shape_are_cache_hits() {
        use crate::cluster::GpuId;
        let (mut p, _) = predictor();
        let j = job(0, 8, 4, 512, 1);
        let a = Allocation {
            gpus: vec![GpuId { node: 0, idx: 0 }],
        };
        p.residual(&j, &a).unwrap();
        let probes = p.probes;
        // identical query: the exact-level residual memo answers
        let exact = p.exact_hits;
        p.residual(&j, &a).unwrap();
        assert_eq!(p.probes, probes, "repeat residual re-ran the planner");
        assert!(p.exact_hits > exact, "repeat missed the exact memo");
        // same shape on another node: exact miss, shape hit
        let b = Allocation {
            gpus: vec![GpuId { node: 7, idx: 3 }],
        };
        let shape = p.shape_hits;
        p.residual(&j, &b).unwrap();
        assert_eq!(p.probes, probes, "same shape re-ran the planner");
        assert!(p.shape_hits > shape, "shape level never consulted");
        assert!(p.shape_hit_rate() > 0.0);
    }

    #[test]
    fn residual_higher_for_smaller_jobs() {
        let (mut p, mut a) = predictor();
        let alloc = a.allocate(1).unwrap();
        let small = p.residual(&job(0, 2, 1, 256, 1), &alloc).unwrap();
        let big = p.residual(&job(1, 16, 8, 1024, 1), &alloc).unwrap();
        assert!(
            small > big,
            "small-job residual {small} <= big-job residual {big}"
        );
    }
}
