//! Throughput predictor T̂(G): composes the Model Fuser, the planner and
//! the Kernel Fuser model into per-group performance estimates, with a
//! memoization cache keyed by (job ids, allocation) so the scheduler's
//! repeated probes are cheap.

use std::collections::HashMap;

use crate::cluster::{Allocation, ClusterSpec};
use crate::planner::{plan, ParallelPlan, PlanError, PlanOptions};
use crate::ssm::Ssm;
use crate::workload::JobSpec;

/// Predicted performance of a fused group.
#[derive(Debug, Clone)]
pub struct GroupPerf {
    /// group step time (all members step together)
    pub step_time_s: f64,
    /// Σ_j batch_j / step_time — cluster-throughput contribution
    pub throughput_samples_s: f64,
    /// per member (job id, Δ_j(G) = isolated progress rate / grouped)
    pub slowdowns: Vec<(u64, f64)>,
    /// compute utilization over the group's GPUs (Fig. 6a metric)
    pub compute_util: f64,
    pub plan: ParallelPlan,
}

impl GroupPerf {
    /// Does every member respect its Δ^max?
    pub fn within_slowdown(&self, jobs: &[JobSpec]) -> bool {
        self.slowdowns.iter().all(|(id, s)| {
            jobs.iter()
                .find(|j| j.id == *id)
                .map_or(true, |j| *s <= j.max_slowdown)
        })
    }
}

/// Memoizing predictor.
pub struct Predictor {
    spec: ClusterSpec,
    opts: PlanOptions,
    iso_cache: HashMap<(u64, Vec<(usize, usize)>), f64>,
    group_cache: HashMap<CacheKey, Option<GroupPerf>>,
    pub probes: u64,
}

type CacheKey = (Vec<u64>, Vec<(usize, usize)>);

fn key_of(jobs: &[JobSpec], alloc: &Allocation) -> CacheKey {
    let mut ids: Vec<u64> = jobs.iter().map(|j| j.id).collect();
    ids.sort_unstable();
    let mut gpus: Vec<(usize, usize)> =
        alloc.gpus.iter().map(|g| (g.node, g.idx)).collect();
    gpus.sort_unstable();
    (ids, gpus)
}

impl Predictor {
    pub fn new(spec: ClusterSpec, opts: PlanOptions) -> Predictor {
        Predictor {
            spec,
            opts,
            iso_cache: HashMap::new(),
            group_cache: HashMap::new(),
            probes: 0,
        }
    }

    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// Step time of `job` running alone on `alloc`.
    pub fn isolated_step_time(
        &mut self,
        job: &JobSpec,
        alloc: &Allocation,
    ) -> Result<f64, PlanError> {
        let gkey: Vec<(usize, usize)> =
            alloc.gpus.iter().map(|g| (g.node, g.idx)).collect();
        if let Some(&t) = self.iso_cache.get(&(job.id, gkey.clone())) {
            return Ok(t);
        }
        self.probes += 1;
        let ssm = Ssm::fuse(std::slice::from_ref(job))
            .map_err(|_| PlanError::NoGpus)?;
        let p = plan(&ssm, alloc, &self.spec, &self.opts)?;
        self.iso_cache.insert((job.id, gkey), p.step_time_s);
        Ok(p.step_time_s)
    }

    /// Residual capacity of `job` on its allocation: 1 - isolated
    /// compute utilization.
    pub fn residual(
        &mut self,
        job: &JobSpec,
        alloc: &Allocation,
    ) -> Result<f64, PlanError> {
        self.probes += 1;
        let ssm = Ssm::fuse(std::slice::from_ref(job))
            .map_err(|_| PlanError::NoGpus)?;
        let p = plan(&ssm, alloc, &self.spec, &self.opts)?;
        Ok((1.0 - p.compute_util).clamp(0.0, 1.0))
    }

    /// Full group performance on a (merged) allocation. `None` when the
    /// group does not fit (mixed base models, OOM, …).
    pub fn group_perf(
        &mut self,
        jobs: &[JobSpec],
        alloc: &Allocation,
    ) -> Option<GroupPerf> {
        let key = key_of(jobs, alloc);
        if let Some(cached) = self.group_cache.get(&key) {
            return cached.clone();
        }
        self.probes += 1;
        let ssm = match Ssm::fuse(jobs) {
            Ok(s) => s,
            Err(_) => {
                self.group_cache.insert(key, None);
                return None;
            }
        };
        let p = match plan(&ssm, alloc, &self.spec, &self.opts) {
            Ok(p) => p,
            Err(_) => {
                self.group_cache.insert(key, None);
                return None;
            }
        };
        let mut slowdowns = vec![];
        for j in jobs {
            // compare against the job's own provisioned allocation
            let iso_alloc = sub_alloc(alloc, j.gpus);
            let iso = self
                .isolated_step_time(j, &iso_alloc)
                .unwrap_or(f64::INFINITY);
            slowdowns.push((j.id, p.step_time_s / iso));
        }
        let throughput = jobs
            .iter()
            .map(|j| j.batch_size as f64)
            .sum::<f64>()
            / p.step_time_s;
        let perf = GroupPerf {
            step_time_s: p.step_time_s,
            throughput_samples_s: throughput,
            slowdowns,
            compute_util: p.compute_util,
            plan: p,
        };
        self.group_cache.insert(key, Some(perf.clone()));
        Some(perf)
    }

    /// Aggregate throughput if each of `groups` runs independently —
    /// the quantity hierarchical grouping tries to beat.
    pub fn sum_throughput(
        &mut self,
        groups: &[(&[JobSpec], &Allocation)],
    ) -> f64 {
        groups
            .iter()
            .filter_map(|(jobs, alloc)| {
                self.group_perf(jobs, alloc)
                    .map(|p| p.throughput_samples_s)
            })
            .sum()
    }
}

/// First `n` GPUs of an allocation (a job's nominal share of a merged
/// gang, used for isolated-baseline comparisons).
fn sub_alloc(alloc: &Allocation, n: usize) -> Allocation {
    Allocation {
        gpus: alloc
            .gpus
            .iter()
            .take(n.max(1).min(alloc.gpus.len()))
            .cloned()
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Allocator;

    fn job(id: u64, rank: usize, batch: usize, seq: usize, gpus: usize)
        -> JobSpec {
        JobSpec {
            id,
            base_model: "llama3-8b".into(),
            rank,
            batch_size: batch,
            seq_len: seq,
            gpus,
            total_steps: 100,
            submit_time: 0.0,
            max_slowdown: 2.0,
        }
    }

    fn predictor() -> (Predictor, Allocator) {
        let spec = ClusterSpec::default_128();
        (
            Predictor::new(spec.clone(), PlanOptions::default()),
            Allocator::new(spec),
        )
    }

    #[test]
    fn isolated_cached() {
        let (mut p, mut a) = predictor();
        let alloc = a.allocate(2).unwrap();
        let j = job(0, 8, 4, 512, 2);
        let t1 = p.isolated_step_time(&j, &alloc).unwrap();
        let probes = p.probes;
        let t2 = p.isolated_step_time(&j, &alloc).unwrap();
        assert_eq!(t1, t2);
        assert_eq!(p.probes, probes, "cache miss on identical query");
    }

    #[test]
    fn group_of_one_matches_isolated() {
        let (mut p, mut a) = predictor();
        let alloc = a.allocate(2).unwrap();
        let j = job(0, 8, 4, 512, 2);
        let iso = p.isolated_step_time(&j, &alloc).unwrap();
        let g = p.group_perf(&[j.clone()], &alloc).unwrap();
        assert!((g.step_time_s - iso).abs() < 1e-12);
        assert!((g.slowdowns[0].1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn complementary_jobs_gain_throughput() {
        // two under-utilized jobs (neither saturates its GPU): fused on
        // the union, the shared backbone pass amortizes the per-wave
        // fixed costs and aggregate throughput beats isolated execution
        let (mut p, mut a) = predictor();
        let small = job(0, 4, 2, 512, 1);
        let big = job(1, 8, 4, 512, 1);
        let a_small = a.allocate(1).unwrap();
        let a_big = a.allocate(1).unwrap();
        let iso_sum = p.sum_throughput(&[
            (std::slice::from_ref(&small), &a_small),
            (std::slice::from_ref(&big), &a_big),
        ]);
        let merged = a_small.union(&a_big);
        let g = p
            .group_perf(&[small.clone(), big.clone()], &merged)
            .unwrap();
        assert!(
            g.throughput_samples_s > iso_sum,
            "grouped {} vs isolated {}",
            g.throughput_samples_s,
            iso_sum
        );
    }

    #[test]
    fn mixed_base_models_unfusable() {
        let (mut p, mut a) = predictor();
        let alloc = a.allocate(2).unwrap();
        let j0 = job(0, 8, 4, 512, 1);
        let mut j1 = job(1, 8, 4, 512, 1);
        j1.base_model = "qwen3-8b".into();
        assert!(p.group_perf(&[j0, j1], &alloc).is_none());
    }

    #[test]
    fn unfusable_result_cached() {
        let (mut p, mut a) = predictor();
        let alloc = a.allocate(2).unwrap();
        let j0 = job(0, 8, 4, 512, 1);
        let mut j1 = job(1, 8, 4, 512, 1);
        j1.base_model = "qwen3-8b".into();
        assert!(p.group_perf(&[j0.clone(), j1.clone()], &alloc).is_none());
        let probes = p.probes;
        assert!(p.group_perf(&[j0, j1], &alloc).is_none());
        assert_eq!(p.probes, probes);
    }

    #[test]
    fn residual_higher_for_smaller_jobs() {
        let (mut p, mut a) = predictor();
        let alloc = a.allocate(1).unwrap();
        let small = p.residual(&job(0, 2, 1, 256, 1), &alloc).unwrap();
        let big = p.residual(&job(1, 16, 8, 1024, 1), &alloc).unwrap();
        assert!(
            small > big,
            "small-job residual {small} <= big-job residual {big}"
        );
    }
}
