//! The Adapter Scheduler (§3.4): residual-capacity-aware online grouping.
//!
//! Efficiency gains come from *complementarity in residual resource
//! usage*: jobs with unused compute/memory pair with resource-hungry
//! jobs; similarly-saturated jobs gain little and often regress. The
//! scheduler implements Algorithm 1:
//!
//! 1. sort runnable jobs by urgency (desc) then residual capacity (asc);
//! 2. pop the most constrained seed, find resource-complementary
//!    partners that maximize predicted joint throughput T̂(G) — a
//!    binary-cut search over the residual-sorted candidates;
//! 3. merge, re-insert, repeat until no merge helps;
//! 4. hierarchically: first within nodes, then across nodes (each merge
//!    tier pays a higher communication price, so cheap tiers go first);
//! 5. reject any grouping that violates a member's progress constraint
//!    Δ_j(G) ≤ Δ_j^max.
//!
//! Complexity: O(K log K) per round — sort + O(log K) predictor probes
//! per merge (see the `sched_scaling` bench).

pub mod estimator;
pub mod predictor;
pub mod grouping;

pub use estimator::{NodeSpeedEstimator, NodeView};
pub use grouping::{schedule, GroupState, ScheduleOutcome};
pub use predictor::{GroupPerf, Predictor};

use crate::config::SchedulerConfig;
use crate::workload::JobSpec;

/// Policy-specific decisions the simulation engine delegates instead of
/// branching on [`crate::config::Policy`] inline. One implementation
/// per baseline lives in [`crate::baselines`]; adding a policy means
/// implementing this trait, not editing the engine.
pub trait PolicyHooks {
    /// One scheduling round: runnable candidates in, executable groups
    /// out (the interface every baseline shares, §4.1).
    fn dispatch(
        &self,
        candidates: Vec<Candidate>,
        predictor: &mut Predictor,
        cfg: &SchedulerConfig,
    ) -> ScheduleOutcome;

    /// Does this policy execute groups with the fused kernel + AIMD
    /// nano-batching?
    fn aimd_enabled(&self) -> bool;

    /// Does this policy consume the straggler-detection signal
    /// ([`NodeView`])? Aware policies keep new placements and elastic
    /// riders off suspected nodes, and the engine migrates their jobs
    /// off nodes whose estimated slowdown crosses
    /// `stragglers.migrate_threshold`. Baselines default to oblivious
    /// — detection-vs-oblivious is a measured axis, not a given.
    fn straggler_aware(&self) -> bool {
        false
    }

    /// Can this policy shrink a running gang in place when a single
    /// GPU inside it fails (`faults.shrink` scenarios)? Capable
    /// policies keep the surviving members training at the shrunken
    /// width — rolled back only to the last checkpoint boundary, no
    /// restart penalty — and regrow when capacity returns; members
    /// whose Δ^max would be violated at the shrunken rate spill
    /// through the normal eviction path. Baselines default to today's
    /// evict-whole-gang semantics; only elastic super-model policies
    /// (tLoRA) override this, mirroring `straggler_aware`.
    fn shrinks_in_place(&self) -> bool {
        false
    }

    /// Elastic shared admission (§3.4): pick the group that should
    /// absorb the queued `job` — an index into `groups` — or `None` to
    /// keep it queued. The engine commits the absorption (perf
    /// refresh, admission bookkeeping); this hook only chooses.
    /// `view` carries the straggler-detection estimates (oblivious
    /// for baselines and detection-disabled runs). Implementations
    /// should return groups whose merge is feasible
    /// (`Predictor::group_perf` is `Some` for members + `job`); if the
    /// commit-time probe fails anyway, the engine leaves the job
    /// queued rather than absorbing it.
    fn elastic_admit(
        &self,
        job: &JobSpec,
        groups: &[(GroupState, GroupPerf)],
        view: &NodeView,
        predictor: &mut Predictor,
        cfg: &SchedulerConfig,
    ) -> Option<usize>;
}

/// A runnable job as the scheduler sees it at a horizon boundary.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub job: JobSpec,
    /// GPUs the job (or its current group) holds
    pub alloc: crate::cluster::Allocation,
    /// urgency u_j: observed slowdown pressure / starvation (higher =
    /// schedule earlier, gets compensated first)
    pub urgency: f64,
    /// residual capacity r_j ∈ [0,1]: unused fraction of its allocation
    /// when running alone (1 = mostly idle)
    pub residual: f64,
}

/// Compute a job's urgency from runtime signals.
///
/// * `slowdown`: current progress-rate slowdown vs isolated execution
/// * `max_slowdown`: the job's Δ^max
/// * `wait_frac`: fraction of its lifetime spent queued (starvation)
pub fn urgency(slowdown: f64, max_slowdown: f64, wait_frac: f64) -> f64 {
    let pressure = (slowdown / max_slowdown).max(0.0);
    pressure + wait_frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn urgency_increases_with_slowdown() {
        assert!(urgency(1.4, 1.5, 0.0) > urgency(1.0, 1.5, 0.0));
    }

    #[test]
    fn urgency_increases_with_starvation() {
        assert!(urgency(1.0, 1.5, 0.5) > urgency(1.0, 1.5, 0.0));
    }

    #[test]
    fn near_violation_dominates() {
        // a job at 95% of its slowdown budget outranks a fresh job
        assert!(urgency(1.425, 1.5, 0.0) > urgency(1.0, 2.0, 0.3));
    }
}
