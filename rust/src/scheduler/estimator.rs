//! Straggler detection: per-node slowdown estimation from *observed*
//! step times.
//!
//! The simulator knows each node's true speed, but a scheduler in
//! production does not — it only sees groups finishing steps slower
//! than the planner predicted. [`NodeSpeedEstimator`] reconstructs a
//! per-node slowdown estimate from exactly that signal: every
//! scheduling round, each running group reports the ratio of its
//! observed step time to its planned (speed-1) step time over the
//! elapsed interval, and the ratio is folded into an EWMA for **every
//! node the group's gang touches**. Attribution is deliberately
//! smeared: a gang spanning a healthy and a degraded node implicates
//! both, and only further observations from disjoint placements
//! separate them — the same ambiguity a real detector faces.
//!
//! The EWMA weight (`stragglers.detect_alpha`, applied once per
//! observed *step*, not per round) is the detection-lag knob: after a
//! node degrades to speed `m`, the estimate moves from ~1 toward `1/m`
//! at rate `alpha` per step, so crossing the suspicion threshold takes
//! `O(log(..)/alpha)` steps. Everything here is a pure deterministic
//! function of the observation stream — no clocks, no RNG — so the
//! sweep engine's bit-determinism contract extends through detection.
//!
//! [`NodeView`] is the read-only facade handed to
//! [`crate::scheduler::PolicyHooks`]: detection-aware policies query
//! `suspected`/`suspects_alloc` to keep new placements and elastic
//! riders off suspected nodes; oblivious baselines simply never look.

use crate::cluster::Allocation;

/// Per-node EWMA of the observed/planned step-time ratio (>= 1 means
/// "running slower than planned"). Estimates start at exactly 1.0
/// (no evidence) and decay back toward 1.0 only through fresh
/// observations — a node nobody runs on keeps its last estimate.
#[derive(Debug, Clone)]
pub struct NodeSpeedEstimator {
    alpha: f64,
    ests: Vec<f64>,
}

impl NodeSpeedEstimator {
    /// `alpha` is the per-step EWMA weight in (0, 1].
    pub fn new(n_nodes: usize, alpha: f64) -> NodeSpeedEstimator {
        assert!(
            alpha > 0.0 && alpha <= 1.0,
            "detect_alpha must be in (0,1], got {alpha}"
        );
        NodeSpeedEstimator {
            alpha,
            ests: vec![1.0; n_nodes],
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.ests.len()
    }

    /// Fold one group's interval observation into every node its gang
    /// touches: `ratio` = observed step time / planned speed-1 step
    /// time, `steps` = how many steps elapsed in the interval. The
    /// closed form `(1-alpha)^steps` applies the per-step EWMA `steps`
    /// times at the constant observed ratio.
    pub fn observe_group(
        &mut self,
        nodes: &[usize],
        ratio: f64,
        steps: f64,
    ) {
        if !(ratio.is_finite() && ratio > 0.0) || steps <= 0.0 {
            return;
        }
        let decay = (1.0 - self.alpha).powf(steps);
        for &node in nodes {
            if let Some(e) = self.ests.get_mut(node) {
                *e = decay * *e + (1.0 - decay) * ratio;
            }
        }
    }

    /// Estimated slowdown factor for `node` (1.0 = running at plan;
    /// unknown nodes report 1.0).
    pub fn slowdown(&self, node: usize) -> f64 {
        self.ests.get(node).copied().unwrap_or(1.0)
    }

    /// Forgiveness: pull every node **not** marked in `observed`
    /// toward healthy by `exp(-dt_s / tau_s)`. Suspicion suppresses
    /// the very placements whose observations would exonerate a node
    /// — an avoided node would otherwise stay blacklisted forever
    /// (restored stragglers, and healthy nodes implicated only by
    /// gang smearing, included). Decay gives them a probation path:
    /// the estimate drifts below the suspicion threshold in `O(tau)`,
    /// placements resume, and genuinely slow nodes are re-convicted
    /// by the very next observations.
    pub fn forgive_idle(
        &mut self,
        observed: &[bool],
        dt_s: f64,
        tau_s: f64,
    ) {
        if dt_s <= 0.0 || tau_s <= 0.0 {
            return;
        }
        let decay = (-dt_s / tau_s).exp();
        for (node, e) in self.ests.iter_mut().enumerate() {
            if !observed.get(node).copied().unwrap_or(false) {
                *e = 1.0 + (*e - 1.0) * decay;
            }
        }
    }
}

/// Read-only detection facade for [`crate::scheduler::PolicyHooks`].
/// `oblivious()` (no estimator) never suspects anything — it is what
/// baselines and detection-disabled runs receive.
#[derive(Debug, Clone, Copy)]
pub struct NodeView<'a> {
    est: Option<&'a NodeSpeedEstimator>,
    threshold: f64,
}

impl<'a> NodeView<'a> {
    /// A view over a live estimator: nodes whose estimated slowdown
    /// exceeds `threshold` are suspected.
    pub fn new(
        est: &'a NodeSpeedEstimator,
        threshold: f64,
    ) -> NodeView<'a> {
        NodeView {
            est: Some(est),
            threshold,
        }
    }

    /// The no-detection view: every query reports healthy.
    pub fn oblivious() -> NodeView<'static> {
        NodeView {
            est: None,
            threshold: f64::INFINITY,
        }
    }

    /// Estimated slowdown for `node` (1.0 without an estimator).
    pub fn slowdown(&self, node: usize) -> f64 {
        self.est.map_or(1.0, |e| e.slowdown(node))
    }

    /// Is `node` a suspected straggler?
    pub fn suspected(&self, node: usize) -> bool {
        self.slowdown(node) > self.threshold
    }

    /// Does `alloc` touch any suspected node?
    pub fn suspects_alloc(&self, alloc: &Allocation) -> bool {
        self.est.is_some()
            && alloc.gpus.iter().any(|g| self.suspected(g.node))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuId;

    #[test]
    fn estimates_start_healthy_and_converge_to_observed_ratio() {
        let mut e = NodeSpeedEstimator::new(4, 0.1);
        assert_eq!(e.slowdown(2), 1.0);
        assert_eq!(e.slowdown(99), 1.0); // out of range: healthy
        for _ in 0..200 {
            e.observe_group(&[1], 4.0, 1.0);
        }
        assert!((e.slowdown(1) - 4.0).abs() < 1e-3, "{}", e.slowdown(1));
        // untouched nodes keep their estimate
        assert_eq!(e.slowdown(0), 1.0);
    }

    #[test]
    fn detection_lag_scales_with_alpha() {
        // a smoother EWMA crosses the suspicion threshold later
        let mut fast = NodeSpeedEstimator::new(1, 0.3);
        let mut slow = NodeSpeedEstimator::new(1, 0.02);
        let steps_to_cross = |e: &mut NodeSpeedEstimator| -> usize {
            for i in 1..10_000 {
                e.observe_group(&[0], 4.0, 1.0);
                if e.slowdown(0) > 1.5 {
                    return i;
                }
            }
            10_000
        };
        let f = steps_to_cross(&mut fast);
        let s = steps_to_cross(&mut slow);
        assert!(f < s, "fast alpha {f} steps vs slow alpha {s}");
    }

    #[test]
    fn closed_form_matches_repeated_single_steps() {
        let mut a = NodeSpeedEstimator::new(1, 0.25);
        let mut b = NodeSpeedEstimator::new(1, 0.25);
        a.observe_group(&[0], 3.0, 8.0);
        for _ in 0..8 {
            b.observe_group(&[0], 3.0, 1.0);
        }
        assert!(
            (a.slowdown(0) - b.slowdown(0)).abs() < 1e-12,
            "{} vs {}",
            a.slowdown(0),
            b.slowdown(0)
        );
    }

    #[test]
    fn attribution_smears_over_gang_nodes() {
        let mut e = NodeSpeedEstimator::new(3, 0.2);
        // a gang spanning nodes 0 and 1 runs slow: both implicated
        for _ in 0..100 {
            e.observe_group(&[0, 1], 3.0, 1.0);
        }
        assert!(e.slowdown(0) > 2.5);
        assert!(e.slowdown(1) > 2.5);
        assert_eq!(e.slowdown(2), 1.0);
        // later, node 0 alone observes healthy: it is exonerated
        for _ in 0..200 {
            e.observe_group(&[0], 1.0, 1.0);
        }
        assert!(e.slowdown(0) < 1.1, "{}", e.slowdown(0));
        assert!(e.slowdown(1) > 2.5);
    }

    #[test]
    fn idle_nodes_are_forgiven_observed_nodes_are_not() {
        let mut e = NodeSpeedEstimator::new(2, 0.5);
        for _ in 0..50 {
            e.observe_group(&[0], 4.0, 1.0);
            e.observe_group(&[1], 4.0, 1.0);
        }
        assert!(e.slowdown(0) > 3.9 && e.slowdown(1) > 3.9);
        // node 0 keeps producing (slow) observations; node 1 goes
        // idle — only node 1 drifts back toward healthy
        for _ in 0..10 {
            e.observe_group(&[0], 4.0, 1.0);
            e.forgive_idle(&[true, false], 300.0, 600.0);
        }
        assert!(e.slowdown(0) > 3.9, "{}", e.slowdown(0));
        // 10 half-ish-lives: 1 + 3*exp(-5) ≈ 1.02
        assert!(e.slowdown(1) < 1.1, "{}", e.slowdown(1));
        assert!(e.slowdown(1) >= 1.0);
        // degenerate intervals are no-ops
        let before = e.slowdown(1);
        e.forgive_idle(&[false, false], 0.0, 600.0);
        e.forgive_idle(&[false, false], -5.0, 600.0);
        assert_eq!(e.slowdown(1), before);
    }

    #[test]
    fn degenerate_observations_are_ignored() {
        let mut e = NodeSpeedEstimator::new(1, 0.5);
        e.observe_group(&[0], f64::INFINITY, 1.0);
        e.observe_group(&[0], f64::NAN, 1.0);
        e.observe_group(&[0], -1.0, 1.0);
        e.observe_group(&[0], 2.0, 0.0);
        assert_eq!(e.slowdown(0), 1.0);
    }

    #[test]
    fn node_view_thresholds_and_oblivious() {
        let mut e = NodeSpeedEstimator::new(2, 0.5);
        for _ in 0..50 {
            e.observe_group(&[1], 2.0, 1.0);
        }
        let v = NodeView::new(&e, 1.5);
        assert!(!v.suspected(0));
        assert!(v.suspected(1));
        let healthy = Allocation {
            gpus: vec![GpuId { node: 0, idx: 0 }],
        };
        let tainted = Allocation {
            gpus: vec![
                GpuId { node: 0, idx: 1 },
                GpuId { node: 1, idx: 0 },
            ],
        };
        assert!(!v.suspects_alloc(&healthy));
        assert!(v.suspects_alloc(&tainted));
        let o = NodeView::oblivious();
        assert!(!o.suspected(1));
        assert_eq!(o.slowdown(1), 1.0);
        assert!(!o.suspects_alloc(&tainted));
    }
}
