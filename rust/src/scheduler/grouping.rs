//! Hierarchical incremental grouping (Algorithm 1, §3.4).

use super::predictor::{GroupPerf, Predictor};
use super::Candidate;
use crate::cluster::Allocation;
use crate::config::SchedulerConfig;
use crate::util::f64_cmp;
use crate::workload::JobSpec;

/// A (possibly singleton) group under construction or finalized.
#[derive(Debug, Clone)]
pub struct GroupState {
    pub jobs: Vec<JobSpec>,
    pub alloc: Allocation,
    pub urgency: f64,
    pub residual: f64,
}

impl GroupState {
    fn from_candidate(c: Candidate) -> GroupState {
        GroupState {
            jobs: vec![c.job],
            alloc: c.alloc,
            urgency: c.urgency,
            residual: c.residual,
        }
    }

    fn merged_with(&self, other: &GroupState, residual: f64) -> GroupState {
        let mut jobs = self.jobs.clone();
        jobs.extend(other.jobs.iter().cloned());
        GroupState {
            jobs,
            alloc: self.alloc.union(&other.alloc),
            urgency: self.urgency.max(other.urgency),
            residual,
        }
    }

    fn nodes(&self) -> Vec<usize> {
        self.alloc.nodes()
    }

    fn shares_node(&self, other: &GroupState) -> bool {
        let mine = self.nodes();
        other.nodes().iter().any(|n| mine.contains(n))
    }
}

/// Result of a scheduling round.
#[derive(Debug)]
pub struct ScheduleOutcome {
    pub groups: Vec<(GroupState, GroupPerf)>,
    /// merges accepted per tier (intra-node, inter-node) — Fig. 6b data
    pub merges_intra: usize,
    pub merges_inter: usize,
    /// planner evaluations this round (shape-level cache misses)
    pub predictor_probes: u64,
    /// predictor queries this round the caches absorbed (exact +
    /// shape level) — probing one group shape on different nodes,
    /// the dominant binary-cut pattern, lands here
    pub plan_cache_hits: u64,
}

/// One round of Algorithm 1 over the runnable jobs.
///
/// Tiers run bottom-up: merges whose members share a node first (cheap
/// NVLink communication), then cross-node merges (IB). Within a tier the
/// incremental pack-and-reinsert loop repeats until no merge improves
/// predicted aggregate throughput by at least `cfg.min_merge_gain` while
/// keeping every member within its Δ^max.
pub fn schedule(
    candidates: Vec<Candidate>,
    predictor: &mut Predictor,
    cfg: &SchedulerConfig,
) -> ScheduleOutcome {
    let probes0 = predictor.probes;
    let hits0 = predictor.cache_hits();
    let mut queue: Vec<GroupState> = candidates
        .into_iter()
        .map(GroupState::from_candidate)
        .collect();

    let mut merges_intra = 0usize;
    let mut merges_inter = 0usize;

    // tier 0: intra-node, tier 1: cross-node ("then across ranks" —
    // our topology has two tiers). Within a tier, a single
    // pack-and-finalize pass: each seed (most urgent / most constrained
    // first) absorbs beneficial partners via binary-cut probes until no
    // merge helps, then is finalized. Every job is absorbed at most
    // once, so the whole round costs O(K log K) predictor probes —
    // the §3.4 complexity claim, measured by the sched_scaling bench.
    for tier in 0..2 {
        // Alg. 1 line 5: sort by urgency desc, residual asc.
        queue.sort_by(|a, b| {
            f64_cmp(b.urgency, a.urgency)
                .then(f64_cmp(a.residual, b.residual))
        });
        let mut seed_idx = 0;
        while seed_idx < queue.len() {
            match try_merge_for_seed(
                &mut queue, seed_idx, predictor, cfg, tier,
            ) {
                true => {
                    if tier == 0 {
                        merges_intra += 1;
                    } else {
                        merges_inter += 1;
                    }
                    // seed absorbed a partner: keep packing this seed
                }
                false => seed_idx += 1, // finalized; lift to next seed
            }
        }
    }

    // finalize: compute per-group perf for the simulator
    let mut groups = vec![];
    for g in queue {
        if let Some(perf) = predictor.group_perf(&g.jobs, &g.alloc) {
            groups.push((g, perf));
        }
    }
    ScheduleOutcome {
        groups,
        merges_intra,
        merges_inter,
        predictor_probes: predictor.probes - probes0,
        plan_cache_hits: predictor.cache_hits() - hits0,
    }
}

/// Attempt the best merge for the seed at `seed_idx` within this tier;
/// `true` if a partner was absorbed (the packed group stays the seed for
/// further absorption), `false` when no beneficial merge exists and the
/// seed is finalized.
fn try_merge_for_seed(
    queue: &mut Vec<GroupState>,
    seed_idx: usize,
    predictor: &mut Predictor,
    cfg: &SchedulerConfig,
    tier: usize,
) -> bool {
    let seed = &queue[seed_idx];
    if seed.jobs.len() >= cfg.max_group_size {
        return false;
    }
    // candidate partners: complementary = large residual first
    // (the binary-cut walks this sorted list, §3.4). Only unfinalized
    // entries (those after the seed) are eligible.
    let mut partners: Vec<usize> = (seed_idx + 1..queue.len())
        .filter(|&i| {
            queue[i].jobs[0].base_model == seed.jobs[0].base_model
        })
        .filter(|&i| {
            queue[i].jobs.len() + seed.jobs.len() <= cfg.max_group_size
        })
        .filter(|&i| match tier {
            0 => queue[i].shares_node(seed),
            _ => true,
        })
        .collect();
    if partners.is_empty() {
        return false;
    }
    partners.sort_by(|&a, &b| {
        f64_cmp(queue[b].residual, queue[a].residual)
    });

    if let Some((best_partner, gain)) =
        binary_cut_best(queue, seed_idx, &partners, predictor, cfg)
    {
        if gain >= cfg.min_merge_gain {
            do_merge(queue, seed_idx, best_partner, predictor);
            return true;
        }
    }
    false
}

/// Binary-cut search (§3.4): on the residual-sorted partner list, probe a
/// logarithmic set of prefixes to locate the cutoff past which adding
/// jobs stops improving efficiency, then return the best single partner
/// in the retained region with the gain it delivers.
///
/// Evaluations are throughput ratios:
/// `gain = T̂(seed ∪ p) / (T̂(seed) + T̂(p))`, constrained to groupings
/// where every member stays within Δ^max.
fn binary_cut_best(
    queue: &[GroupState],
    seed_idx: usize,
    partners: &[usize],
    predictor: &mut Predictor,
    _cfg: &SchedulerConfig,
) -> Option<(usize, f64)> {
    let seed = &queue[seed_idx];
    let seed_tp = predictor
        .group_perf(&seed.jobs, &seed.alloc)?
        .throughput_samples_s;

    let gain_of = |p_idx: usize, predictor: &mut Predictor| -> Option<f64> {
        let partner = &queue[p_idx];
        let p_tp = predictor
            .group_perf(&partner.jobs, &partner.alloc)?
            .throughput_samples_s;
        let merged_alloc = seed.alloc.union(&partner.alloc);
        let mut jobs = seed.jobs.clone();
        jobs.extend(partner.jobs.iter().cloned());
        let g = predictor.group_perf(&jobs, &merged_alloc)?;
        if !g.within_slowdown(&jobs) {
            return None;
        }
        Some(g.throughput_samples_s / (seed_tp + p_tp))
    };

    // binary cut: shrink the candidate window [0, hi) while the midpoint
    // probe is not better than the best seen in the left half
    let mut lo = 0usize;
    let mut hi = partners.len();
    let mut best: Option<(usize, f64)> = None;
    let probe = |i: usize,
                     best: &mut Option<(usize, f64)>,
                     predictor: &mut Predictor| {
        if let Some(g) = gain_of(partners[i], predictor) {
            if best.map_or(true, |(_, bg)| g > bg) {
                *best = Some((partners[i], g));
            }
        }
    };
    probe(0, &mut best, predictor);
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        let before = best;
        probe(mid, &mut best, predictor);
        if best == before {
            // midpoint didn't help: cut the right portion
            hi = mid;
        } else {
            lo = mid;
        }
    }
    best
}

/// Absorb `partner_idx` into the seed in place (the seed keeps its
/// queue position so the pack-and-finalize pass stays single-pass).
fn do_merge(
    queue: &mut Vec<GroupState>,
    seed_idx: usize,
    partner_idx: usize,
    predictor: &mut Predictor,
) {
    debug_assert_ne!(seed_idx, partner_idx);
    let partner = queue.remove(partner_idx);
    let seed_idx = if partner_idx < seed_idx {
        seed_idx - 1
    } else {
        seed_idx
    };
    let seed = queue[seed_idx].clone();
    let merged_alloc = seed.alloc.union(&partner.alloc);
    let mut jobs = seed.jobs.clone();
    jobs.extend(partner.jobs.iter().cloned());
    let residual = predictor
        .group_perf(&jobs, &merged_alloc)
        .map(|p| (1.0 - p.compute_util).clamp(0.0, 1.0))
        .unwrap_or(0.0);
    queue[seed_idx] = seed.merged_with(&partner, residual);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Allocator, ClusterSpec};
    use crate::planner::PlanOptions;

    fn job(id: u64, rank: usize, batch: usize, seq: usize, gpus: usize)
        -> JobSpec {
        JobSpec {
            id,
            base_model: "llama3-8b".into(),
            rank,
            batch_size: batch,
            seq_len: seq,
            gpus,
            total_steps: 1000,
            submit_time: 0.0,
            max_slowdown: 2.0,
        }
    }

    fn mk_candidates(
        jobs: Vec<JobSpec>,
        alloc: &mut Allocator,
        pred: &mut Predictor,
    ) -> Vec<Candidate> {
        jobs.into_iter()
            .map(|j| {
                let a = alloc.allocate(j.gpus).unwrap();
                let residual = pred.residual(&j, &a).unwrap_or(0.5);
                Candidate {
                    job: j,
                    alloc: a,
                    urgency: 0.0,
                    residual,
                }
            })
            .collect()
    }

    fn setup() -> (Predictor, Allocator, SchedulerConfig) {
        let spec = ClusterSpec::default_128();
        (
            Predictor::new(spec.clone(), PlanOptions::default()),
            Allocator::new(spec),
            SchedulerConfig::default(),
        )
    }

    #[test]
    fn groups_complementary_jobs() {
        let (mut pred, mut alloc, cfg) = setup();
        let jobs = vec![
            job(0, 4, 2, 512, 1), // both leave residual capacity:
            job(1, 8, 4, 512, 1), // fusing amortizes the backbone pass
        ];
        let cands = mk_candidates(jobs, &mut alloc, &mut pred);
        let out = schedule(cands, &mut pred, &cfg);
        assert_eq!(out.groups.len(), 1, "should merge into one group");
        assert_eq!(out.groups[0].0.jobs.len(), 2);
        assert_eq!(out.merges_intra + out.merges_inter, 1);
    }

    #[test]
    fn respects_max_group_size() {
        let (mut pred, mut alloc, mut cfg) = setup();
        cfg.max_group_size = 2;
        let jobs: Vec<JobSpec> =
            (0..4).map(|i| job(i, 2, 1, 256, 1)).collect();
        let cands = mk_candidates(jobs, &mut alloc, &mut pred);
        let out = schedule(cands, &mut pred, &cfg);
        for (g, _) in &out.groups {
            assert!(g.jobs.len() <= 2);
        }
    }

    #[test]
    fn never_mixes_base_models() {
        let (mut pred, mut alloc, cfg) = setup();
        let mut j1 = job(1, 2, 1, 256, 1);
        j1.base_model = "qwen3-8b".into();
        let jobs = vec![job(0, 2, 1, 256, 1), j1];
        let cands = mk_candidates(jobs, &mut alloc, &mut pred);
        let out = schedule(cands, &mut pred, &cfg);
        assert_eq!(out.groups.len(), 2);
    }

    #[test]
    fn enforces_slowdown_constraint() {
        let (mut pred, mut alloc, cfg) = setup();
        // two big saturated jobs with a *tight* slowdown budget: a merge
        // would push each past Δ^max, so both must stay isolated
        let mut a = job(0, 16, 8, 1024, 1);
        let mut b = job(1, 16, 8, 1024, 1);
        a.max_slowdown = 1.01;
        b.max_slowdown = 1.01;
        let cands = mk_candidates(vec![a, b], &mut alloc, &mut pred);
        let out = schedule(cands, &mut pred, &cfg);
        for (g, perf) in &out.groups {
            assert!(perf.within_slowdown(&g.jobs));
        }
    }

    #[test]
    fn all_members_within_slowdown_after_scheduling() {
        let (mut pred, mut alloc, cfg) = setup();
        let jobs: Vec<JobSpec> = (0..6)
            .map(|i| {
                job(i, [2, 4, 8, 16][i as usize % 4],
                    [1, 2, 4, 8][(i as usize + 1) % 4], 512, 1)
            })
            .collect();
        let cands = mk_candidates(jobs, &mut alloc, &mut pred);
        let out = schedule(cands, &mut pred, &cfg);
        for (g, perf) in &out.groups {
            assert!(perf.within_slowdown(&g.jobs), "{:?}", perf.slowdowns);
        }
    }

    #[test]
    fn grouping_beats_isolated_aggregate_throughput() {
        let (mut pred, mut alloc, cfg) = setup();
        let jobs: Vec<JobSpec> = vec![
            job(0, 2, 1, 256, 1),
            job(1, 16, 8, 1024, 1),
            job(2, 4, 2, 512, 1),
            job(3, 8, 4, 512, 1),
        ];
        // isolated aggregate
        let mut iso_total = 0.0;
        let mut iso_alloc = Allocator::new(ClusterSpec::default_128());
        for j in &jobs {
            let a = iso_alloc.allocate(j.gpus).unwrap();
            let t = pred.isolated_step_time(j, &a).unwrap();
            iso_total += j.batch_size as f64 / t;
        }
        let cands = mk_candidates(jobs, &mut alloc, &mut pred);
        let out = schedule(cands, &mut pred, &cfg);
        let grouped: f64 = out
            .groups
            .iter()
            .map(|(_, p)| p.throughput_samples_s)
            .sum();
        assert!(
            grouped >= iso_total,
            "grouped {grouped} < isolated {iso_total}"
        );
    }

    #[test]
    fn empty_input() {
        let (mut pred, _, cfg) = setup();
        let out = schedule(vec![], &mut pred, &cfg);
        assert!(out.groups.is_empty());
    }

    #[test]
    fn probe_count_scales_quasilinearly() {
        // O(K log K): probes per job should not explode with K
        let (mut pred, mut alloc, cfg) = setup();
        let jobs: Vec<JobSpec> = (0..24)
            .map(|i| {
                job(i, [2, 4, 8, 16][i as usize % 4],
                    [1, 2, 4, 8][i as usize % 4], 256, 1)
            })
            .collect();
        let k = jobs.len() as f64;
        let cands = mk_candidates(jobs, &mut alloc, &mut pred);
        let out = schedule(cands, &mut pred, &cfg);
        let per_job = out.predictor_probes as f64 / k;
        // generous bound: probes/job stays well under K (quadratic blowup)
        assert!(per_job < k, "probes/job {per_job} vs K {k}");
    }
}
